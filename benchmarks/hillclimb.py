"""Hillclimb driver: re-lower one cell after a code/config change and diff
the roofline terms against a recorded baseline.

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb --arch qwen2.5-14b \
      --shape decode_32k --tag flat_constraints \
      [--baseline results/perf/<file>.json]

Writes results/perf/<arch>_<shape>_<tag>.json and prints the before/after
table used in EXPERIMENTS.md §Perf.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    from repro.configs import registry
    from repro.launch.dryrun import run_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cell = registry.get_cell(args.arch, args.shape)
    rec = run_cell(cell, mesh, "2pod16x16" if args.multi_pod else "pod16x16")
    safe = args.arch.replace(".", "_").replace("-", "_")
    out = f"results/perf/{safe}_{args.shape}_{args.tag}.json"
    os.makedirs("results/perf", exist_ok=True)
    with open(out, "w") as f:
        json.dump([rec], f, indent=1)
    print(f"wrote {out}")
    keys = ("t_compute", "t_memory", "t_collective", "bottleneck",
            "temp_bytes", "roofline_fraction", "model_flops_ratio")
    if not rec.get("ok"):
        print("FAIL:", rec.get("error"))
        return
    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        base = base[0] if isinstance(base, list) else base
        print(f"{'term':<20}{'baseline':>14}{'now':>14}{'delta':>10}")
        for k in keys:
            b, n = base.get(k), rec.get(k)
            if isinstance(b, float) and isinstance(n, float) and b:
                print(f"{k:<20}{b:>14.4e}{n:>14.4e}{n/b:>9.2f}x")
            else:
                print(f"{k:<20}{str(b):>14}{str(n):>14}")
    else:
        for k in keys:
            print(f"{k:<20}{rec.get(k)}")


if __name__ == "__main__":
    main()
