"""Hillclimb driver for the truss benchmarks: re-run one benchmark table
after a code change and diff every row's ``us_per_call`` against a recorded
baseline JSON (e.g. the committed BENCH_peel.json / BENCH_ooc.json, or a
previous hillclimb result).

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb --table peel --tag mychange \
      [--baseline BENCH_peel.json] [--smoke]

Writes results/perf/<table>_<tag>.json and prints a before/after table —
the perf-trajectory workflow DESIGN.md §6 describes, applied to any table
in ``benchmarks.run.TABLES``.
"""

from __future__ import annotations

import argparse
import json
import os


def main(argv=None) -> None:
    from benchmarks import run as runlib

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--table", required=True, choices=sorted(runlib.TABLES),
                    help="benchmark table to re-run")
    ap.add_argument("--tag", required=True,
                    help="label for the results/perf/ output file")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (a BENCH_*.json or a previous "
                         "hillclimb result) to diff against")
    ap.add_argument("--smoke", action="store_true",
                    help="smallest-dataset variant (peel / table4 only)")
    args = ap.parse_args(argv)

    runlib.ROWS.clear()
    fn = runlib.TABLES[args.table]
    print("name,us_per_call,derived")
    if args.table in runlib.SMOKE_TABLES:
        fn(smoke=args.smoke)
    else:
        fn()
    rows = list(runlib.ROWS)

    os.makedirs("results/perf", exist_ok=True)
    out = f"results/perf/{args.table}_{args.tag}.json"
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {len(rows)} records to {out}")

    if not args.baseline:
        return
    with open(args.baseline) as f:
        base = {r["name"]: r for r in json.load(f)}
    print(f"\n{'row':<44}{'baseline_us':>14}{'now_us':>14}{'ratio':>8}")
    for r in rows:
        b = base.get(r["name"])
        if b is None or not b.get("us_per_call"):
            print(f"{r['name']:<44}{'--':>14}{r['us_per_call']:>14.1f}"
                  f"{'--':>8}")
            continue
        ratio = r["us_per_call"] / b["us_per_call"]
        print(f"{r['name']:<44}{b['us_per_call']:>14.1f}"
              f"{r['us_per_call']:>14.1f}{ratio:>7.2f}x")


if __name__ == "__main__":
    main()
