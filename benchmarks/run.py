"""Benchmark harness — one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows (and JSON records with
``--json``, which also carry structured counters such as the frontier
engine's round/frontier-size statistics):

  table3_*  — in-memory decomposition: Alg 1 (TD-inmem) vs Alg 2
              (TD-inmem+) vs the vectorized bulk peel (ours).  The paper's
              headline speedup (2.2–73x) is algorithmic; we report the
              same comparison on power-law graphs.
  table4_*  — out-of-memory regime on the rmat graphs: batched OOC engine
              vs the seed per-part path vs the global-iterate baseline
              (the MapReduce [16] stand-in); ``--only table4 --json
              BENCH_ooc.json`` records the OocStats counters.  The
              ``table4_*_partitioner_*`` rows compare sequential vs
              random vs locality-aware partitioning by counters (rounds,
              scans, batches, compiles, triangle locality) — wall-clock
              is too noisy on shared CPU to compare across runs.  The
              ``table4shard_*`` rows route each round's bucket lanes
              through shard_map over every local device (DESIGN.md §10)
              and record devices / sharded_rounds / padding_waste against
              the single-device batched engine.
  table5_*  — top-down top-t vs bottom-up full decomposition.
  table6_*  — k_max-truss vs c_max-core statistics (sizes, clustering).
  peel_*    — frontier-compacted engine vs the seed dense engine
              (DESIGN.md §3) and skew-aware vs global-D support (§4).
  kernel_*  — Pallas kernel microbenches (interpret mode, correctness-
              scaled shapes; TPU wall-times come from the roofline).

Usage: ``run.py [--json BENCH_peel.json] [--only PREFIX ...] [--smoke]``.
``--smoke`` restricts the peel and table4 comparisons to their smallest
dataset (CI).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


ROWS = []


def emit(name: str, us: float, derived: str = "", **extra):
    ROWS.append({"name": name, "us_per_call": us, "derived": derived, **extra})
    print(f"{name},{us:.1f},{derived}", flush=True)


def _time(fn, repeats=1):
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out


def table3_inmemory():
    from benchmarks.datasets import SMALL, load
    from repro.core.peel import truss_decompose
    from repro.core.serial import alg1_truss, alg2_truss

    for name in SMALL:
        n, edges = load(name)
        us1, phi1 = _time(lambda: alg1_truss(n, edges))
        us2, phi2 = _time(lambda: alg2_truss(n, edges))
        usb, phib = _time(lambda: truss_decompose(n, edges))
        assert (phi1 == phi2).all() and (phi2 == phib).all()
        kmax = int(phi2.max())
        emit(f"table3_{name}_alg1_TDinmem", us1,
             f"m={len(edges)};kmax={kmax}")
        emit(f"table3_{name}_alg2_TDinmem+", us2,
             f"speedup_vs_alg1={us1/us2:.2f}")
        emit(f"table3_{name}_bulkpeel_ours", usb,
             f"speedup_vs_alg1={us1/usb:.2f}")


def table4_bottom_up(smoke: bool = False):
    """Out-of-memory regime: batched OOC engine (DESIGN.md §8) vs the seed
    per-part path vs the global-iterate baseline (MapReduce [16] stand-in).

    The rmat graphs are the paper's web/social shape; the budget (1/32 of
    the graph, the deep out-of-core regime) forces hundreds of partitions
    per round, so the rows measure exactly the regime the batch engine
    targets: the seed path pays one host subgraph build + one freshly
    shaped compile per part, the batched engine a handful of pow2 shapes
    per run.  ``--json BENCH_ooc.json`` captures the OocStats counters
    (rounds, scans, batches, compiles, padding waste, triangle locality,
    stage-2 pipeline depth).  A ``TDtopdown_batched`` row runs the second
    driver at the same budget — both drivers' rows record
    ``stage2_overlapped`` (DESIGN.md §11).
    """
    from benchmarks.datasets import load
    from repro.core.bottom_up import bottom_up_decompose
    from repro.core.graph import build_graph
    from repro.core.peel import peel_recompute
    from repro.core.support import list_triangles_np
    from repro.core.top_down import top_down_decompose

    names = ["hep-like"] if smoke else ["hep-like", "amazon-like", "wiki-like"]
    for name in names:
        # cold-run isolation per graph; the perpart seed rows compile one
        # executable PER PART (thousands of mmap regions), and letting them
        # accumulate across graphs runs into vm.max_map_count
        jax.clear_caches()
        n, edges = load(name)
        budget = max(len(edges) // 32, 1024)  # "memory" = 1/32 of the graph
        usb, res = _time(lambda: bottom_up_decompose(n, edges, budget))
        usp, res_p = _time(
            lambda: bottom_up_decompose(n, edges, budget, engine="perpart"))
        # global-iterate baseline (MapReduce stand-in): recompute supports
        # from scratch every round over the whole graph
        g = build_graph(n, edges)
        tris = list_triangles_np(g)
        if len(tris) == 0:
            tris = np.full((1, 3), g.m, np.int32)
        tj = jnp.asarray(tris)
        usm, phim = _time(
            lambda: np.asarray(peel_recompute(tj, jnp.ones(g.m, bool))))
        # cross-check the three paths against each other (the serial oracle
        # is exercised on these sizes in table3 / tests; python-oracle runs
        # on 300k+ edge graphs would dominate the harness wall time)
        assert (res.phi == phim).all() and (res.phi == res_p.phi).all()
        st, st_p = res.stats, res_p.stats
        emit(f"table4_{name}_TDbottomup_batched", usb,
             f"m={len(edges)};rounds={res.rounds};parts={st.parts};"
             f"batches={st.batches};compiles={st.compiles};"
             f"tri_locality={st.tri_locality:.3f};"
             f"stage2_overlapped={st.stage2_overlapped};"
             f"speedup_vs_perpart={usp/usb:.2f};budget={budget}",
             m=len(edges), budget=budget, rounds=res.rounds,
             scans=res.scans, parts=st.parts, batches=st.batches,
             compiles=st.compiles, max_part_edges=st.max_part_edges,
             padding_waste=st.padding_waste,
             tri_locality=st.tri_locality,
             stage2_overlapped=st.stage2_overlapped,
             tri_est_error=st.tri_est_error,
             speedup_vs_perpart=usp / usb)
        emit(f"table4_{name}_TDbottomup_perpart_seed", usp,
             f"rounds={res_p.rounds};scans={res_p.scans};"
             f"parts={st_p.parts};budget={budget}",
             m=len(edges), budget=budget, rounds=res_p.rounds,
             scans=res_p.scans, parts=st_p.parts)
        emit(f"table4_{name}_globaliter_MRstandin", usm,
             f"slowdown_vs_batched={usm/usb:.2f}",
             slowdown_vs_batched=usm / usb)
        # the second driver at the same deep budget: its per-k candidate
        # peels ride the same stage-2 pipeline (DESIGN.md §11)
        ust, res_t = _time(lambda: top_down_decompose(n, edges,
                                                      budget=budget))
        assert (res_t.phi == res.phi).all()
        st_t = res_t.stats
        emit(f"table4_{name}_TDtopdown_batched", ust,
             f"rounds={st_t.rounds};scans={st_t.scans};"
             f"tri_locality={st_t.tri_locality:.3f};"
             f"stage2_overlapped={st_t.stage2_overlapped};budget={budget}",
             m=len(edges), budget=budget, rounds=st_t.rounds,
             scans=st_t.scans, parts=st_t.parts, batches=st_t.batches,
             compiles=st_t.compiles, tri_locality=st_t.tri_locality,
             stage2_overlapped=st_t.stage2_overlapped,
             tri_est_error=st_t.tri_est_error)


def table4_partitioners(smoke: bool = False):
    """Partitioner comparison at memory = m/32 (DESIGN.md §9): sequential
    vs rebalanced-random vs locality-aware on the rmat graphs.

    Wall-clock on this box is too noisy to compare runs, so the rows
    record the OocStats *counters* — partition rounds, NS/candidate
    scans, device batches, distinct compiles, triangle locality — which
    are deterministic per (graph, partitioner, budget).  phi is asserted
    identical across partitioners (Lemma 1 holds for any partition).
    """
    from benchmarks.datasets import load
    from repro.core.bottom_up import bottom_up_decompose

    names = ["hep-like"] if smoke else ["hep-like", "amazon-like", "wiki-like"]
    for name in names:
        n, edges = load(name)
        budget = max(len(edges) // 32, 1024)
        phi_ref = None
        for part in ("sequential", "random", "locality"):
            us, res = _time(lambda: bottom_up_decompose(
                n, edges, budget, partitioner=part))
            if phi_ref is None:
                phi_ref = res.phi
            else:
                assert (res.phi == phi_ref).all(), part
            st = res.stats
            emit(f"table4_{name}_partitioner_{part}", us,
                 f"rounds={res.rounds};ns_sweeps={st.ns_sweeps};"
                 f"tri_routes={st.tri_routes};scans={res.scans};"
                 f"batches={st.batches};compiles={st.compiles};"
                 f"tri_locality={st.tri_locality:.3f};"
                 f"tri_est_error={st.tri_est_error:.2f};"
                 f"stage2_overlapped={st.stage2_overlapped};"
                 f"overlapped={st.overlapped};budget={budget}",
                 m=len(edges), budget=budget, rounds=res.rounds,
                 ns_sweeps=st.ns_sweeps, tri_routes=st.tri_routes,
                 scans=res.scans, parts=st.parts, batches=st.batches,
                 compiles=st.compiles, tri_total=st.tri_total,
                 tri_assigned=st.tri_assigned,
                 tri_locality=st.tri_locality,
                 tri_est_error=st.tri_est_error,
                 stage2_overlapped=st.stage2_overlapped,
                 overlapped=st.overlapped,
                 max_part_edges=st.max_part_edges,
                 padding_waste=st.padding_waste)


def table4_sharded(smoke: bool = False):
    """Pod-spanning OOC rounds (DESIGN.md §10): the batched bottom-up
    engine with bucket lanes routed through shard_map over every local
    device vs the single-device batched engine.

    On CPU the shards are virtual (forced host devices in CI), so the rows
    record the sharding *counters* — devices spanned, sharded rounds,
    padding waste from the lane-multiple rule — and assert identical phi;
    wall-clock speedups only mean something on a real mesh.

    Timing is ONE cold end-to-end run per row: an out-of-core
    decomposition of a massive graph is a one-shot workload, so trace +
    compile time is part of what the user waits for.  That makes the
    ``compiles`` column load-bearing — the sharded path's shape ladder
    (DESIGN.md §13) pins bucket shapes run-wide so the pod compiles O(1)
    executables, while the single-device path re-traces every pow4
    shape class it meets; ``speedup_vs_1dev`` is dominated by that
    dispatch-chain gap (virtual host devices share the physical cores,
    so lane parallelism itself cannot show up in CPU wall-clock).
    """
    from benchmarks.datasets import load
    from repro.core.bottom_up import bottom_up_decompose

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    names = ["hep-like"] if smoke else ["hep-like", "amazon-like",
                                        "wiki-like"]
    for name in names:
        jax.clear_caches()      # per-graph cold-run isolation
        n, edges = load(name)
        budget = max(len(edges) // 32, 1024)
        uss, res_s = _time(lambda: bottom_up_decompose(
            n, edges, budget, mesh=mesh))
        usb, res_b = _time(lambda: bottom_up_decompose(n, edges, budget))
        assert (res_s.phi == res_b.phi).all()
        st = res_s.stats
        emit(f"table4shard_{name}_TDbottomup_sharded", uss,
             f"devices={st.devices};sharded_rounds={st.sharded_rounds};"
             f"rounds={res_s.rounds};batches={st.batches};"
             f"compiles={st.compiles};padding_waste={st.padding_waste:.3f};"
             f"speedup_vs_1dev={usb/uss:.2f};budget={budget}",
             m=len(edges), budget=budget, devices=st.devices,
             sharded_rounds=st.sharded_rounds, rounds=res_s.rounds,
             scans=res_s.scans, batches=st.batches, compiles=st.compiles,
             overlapped=st.overlapped, padding_waste=st.padding_waste,
             speedup_vs_1dev=usb / uss)
        emit(f"table4shard_{name}_TDbottomup_1dev", usb,
             f"rounds={res_b.rounds};"
             f"padding_waste={res_b.stats.padding_waste:.3f}",
             m=len(edges), budget=budget, rounds=res_b.rounds,
             compiles=res_b.stats.compiles,
             padding_waste=res_b.stats.padding_waste)


def table4_kernel(smoke: bool = False):
    """Fused frontier-peel kernel rows (DESIGN.md §13).

    Two row kinds:

    * ``table4kernel_micro_*`` — one pow2-padded bucket of R-MAT lanes
      peeled by the fused Pallas kernel (interpret mode off-TPU —
      correctness-scaled, NOT a TPU wall-time) vs the XLA vmapped
      frontier engine on identical lanes, phi asserted equal.
    * ``table4kernel_rmat_*`` — the batched bottom-up driver on an R-MAT
      graph small enough for the python serial oracle: single device vs
      the full local device mesh — one single-axis row and, when the
      device count factors, one multi-axis (lane, tri) row (DESIGN.md
      §13) — phi pinned to ``alg2_truss``, with ``speedup_vs_1dev``,
      ``compiles`` and ``padding_waste`` recorded per mesh row.
    """
    from repro.core import graph as glib
    from repro.core.bottom_up import bottom_up_decompose
    from repro.core.peel import _peel_classes_vmapped
    from repro.core.serial import alg2_truss
    from repro.core.support import (list_triangles_np,
                                    support_from_triangle_list,
                                    triangle_incidence_np)
    from repro.data import graphgen
    from repro.kernels.frontier_peel import ops as fops

    # --- micro bucket: fused (interpret) vs the XLA frontier engine
    cap_e, B = 512, 4
    sup_b = np.zeros((B, cap_e), np.int32)
    alive_b = np.zeros((B, cap_e), np.int32)
    tris_l, incs = [], []
    for i in range(B):
        n_l, e_l = graphgen.rmat(6, 3, seed=20 + i)
        ce = glib.canonical_edges(e_l, n_l)[: cap_e]
        m = len(ce)
        g = glib.build_graph(n_l, ce)
        tris = np.asarray(list_triangles_np(g), np.int64).reshape(-1, 3)
        sup_b[i, :m] = support_from_triangle_list(tris, m)
        alive_b[i, :m] = 1
        tris_l.append(np.asarray(tris, np.int32))
    t_max = max(max(len(t) for t in tris_l), 1)
    tris_b = np.full((B, t_max, 3), cap_e, np.int32)
    for i, t in enumerate(tris_l):
        tris_b[i, : len(t)] = t
        incs.append(triangle_incidence_np(tris_b[i], cap_e))
    indptr_b = np.stack([ip for ip, _ in incs])
    l_max = max(max(len(ti) for _, ti in incs), 1)
    tids_b = np.zeros((B, l_max), np.int32)
    for i, (_, ti) in enumerate(incs):
        tids_b[i, : len(ti)] = ti
    cap_t = 1
    while cap_t < 3 * t_max:
        cap_t *= 2

    bt = fops.resolve_tile(cap_e, t_max, "auto", True)
    us_f, (phi_f, _) = _time(
        lambda: jax.block_until_ready(
            fops.peel_classes_fused(sup_b, tris_b, alive_b,
                                    bt=bt, interpret=True)),
        repeats=2)
    us_x, (phi_x, _) = _time(
        lambda: jax.block_until_ready(_peel_classes_vmapped(
            jnp.asarray(sup_b), jnp.asarray(tris_b), jnp.asarray(indptr_b),
            jnp.asarray(tids_b), jnp.asarray(alive_b),
            cap_f=cap_e, cap_t=cap_t)),
        repeats=2)
    assert (np.asarray(phi_f) == np.asarray(phi_x)).all()
    interp = jax.default_backend() != "tpu"
    emit("table4kernel_micro_fused" + ("_interp" if interp else ""), us_f,
         f"B={B};cap_e={cap_e};T={t_max};bt={bt};"
         f"fused_vs_xla={us_x/us_f:.3f}",
         B=B, cap_e=cap_e, triangles=t_max, bt=bt, interpret=interp,
         fused_vs_xla=us_x / us_f)
    emit("table4kernel_micro_xla_frontier", us_x,
         f"cap_f={cap_e};cap_t={cap_t}", B=B, cap_e=cap_e, cap_t=cap_t)

    # --- driver rows: 1dev vs the local mesh, phi vs the serial oracle
    n, edges = graphgen.rmat(10, 6, seed=7)
    ce = glib.canonical_edges(edges, n)
    oracle = alg2_truss(n, ce)
    budget = max(len(ce) // 32, 256)
    n_dev = len(jax.devices())
    meshes = [(jax.make_mesh((n_dev,), ("data",)), "data", f"mesh{n_dev}")]
    if n_dev >= 4 and n_dev % 2 == 0:
        meshes.append((jax.make_mesh((2, n_dev // 2), ("data", "tri")),
                       ("data", "tri"), f"mesh2x{n_dev // 2}"))
    # one COLD end-to-end run per row (same contract as table4shard): the
    # OOC workload is one-shot, so the single-device trace/compile churn
    # vs the sharded shape ladder's O(1) executables is exactly what
    # speedup_vs_1dev should see
    us1, r1 = _time(lambda: bottom_up_decompose(n, ce, budget))
    assert (r1.phi == oracle).all()
    for mesh, axes, kind in meshes:
        uss, rs = _time(lambda: bottom_up_decompose(
            n, ce, budget, mesh=mesh, mesh_axis=axes))
        assert (rs.phi == oracle).all()
        st = rs.stats
        emit(f"table4kernel_rmat10_TDbottomup_{kind}", uss,
             f"devices={st.devices};sharded_rounds={st.sharded_rounds};"
             f"compiles={st.compiles};"
             f"padding_waste={st.padding_waste:.3f};"
             f"speedup_vs_1dev={us1/uss:.2f};budget={budget}",
             m=len(ce), budget=budget, devices=st.devices,
             sharded_rounds=st.sharded_rounds, compiles=st.compiles,
             padding_waste=st.padding_waste, speedup_vs_1dev=us1 / uss)
    emit("table4kernel_rmat10_TDbottomup_1dev", us1,
         f"rounds={r1.rounds};"
         f"padding_waste={r1.stats.padding_waste:.3f}",
         m=len(ce), budget=budget, rounds=r1.rounds,
         padding_waste=r1.stats.padding_waste)


def table4_resilience(smoke: bool = False):
    """Crash-safety cost model (DESIGN.md §12): the batched bottom-up
    engine with round journaling at ``checkpoint_every=1`` (every completed
    partition round and class level snapshotted) vs the unjournaled run,
    plus a fault-injected run (one device OOM in each stage) exercising the
    retry ladder.

    The ``checkpoint_overhead`` column is the journaled run's wall-clock
    overhead fraction — the acceptance target is < 0.15 at every-round
    granularity on the smoke rows; ``retries`` / ``degraded`` /
    ``checkpoints`` record the recovery counters.  phi is asserted
    identical across all three runs.
    """
    import shutil
    import tempfile

    from benchmarks.datasets import load
    from repro.core import faults
    from repro.core.bottom_up import bottom_up_decompose

    names = ["hep-like"] if smoke else ["hep-like", "amazon-like",
                                        "wiki-like"]
    for name in names:
        n, edges = load(name)
        budget = max(len(edges) // 32, 1024)
        usb, res = _time(lambda: bottom_up_decompose(n, edges, budget),
                         repeats=2)

        def journaled():
            d = tempfile.mkdtemp(prefix="bench_ckpt_")
            try:
                return bottom_up_decompose(n, edges, budget,
                                           checkpoint_dir=d,
                                           checkpoint_every=1)
            finally:
                shutil.rmtree(d, ignore_errors=True)

        usj, res_j = _time(journaled, repeats=2)
        assert (res_j.phi == res.phi).all()
        overhead = max(usj - usb, 0.0) / usb
        st = res_j.stats
        emit(f"table4resil_{name}_TDbottomup_journaled", usj,
             f"checkpoint_overhead={overhead:.3f};"
             f"checkpoints={st.checkpoints};rounds={res_j.rounds};"
             f"budget={budget}",
             m=len(edges), budget=budget, rounds=res_j.rounds,
             checkpoints=st.checkpoints, checkpoint_overhead=overhead,
             retries=st.retries, degraded=st.degraded)

        def faulted():
            plan = faults.FaultPlan([
                faults.FaultRule(site=faults.DISPATCH, kind="oom",
                                 where={"stage": 1}, times=1),
                faults.FaultRule(site=faults.DISPATCH, kind="oom",
                                 where={"stage": 2}, times=1),
            ])
            with faults.active(plan):
                return bottom_up_decompose(n, edges, budget)

        usf, res_f = _time(faulted)
        assert (res_f.phi == res.phi).all()
        st_f = res_f.stats
        assert st_f.retries >= 2, st_f
        emit(f"table4resil_{name}_TDbottomup_oom_injected", usf,
             f"retries={st_f.retries};degraded={st_f.degraded};"
             f"slowdown_vs_clean={usf/usb:.2f};budget={budget}",
             m=len(edges), budget=budget, retries=st_f.retries,
             degraded=st_f.degraded, checkpoints=st_f.checkpoints,
             slowdown_vs_clean=usf / usb)


def table4_disk(smoke: bool = False):
    """Out-of-core graph STORAGE rows (DESIGN.md §15): the batched
    bottom-up engine with every graph array behind a ChunkedDiskStore
    capped at 1/8 of the packed graph's bytes, vs the same run with the
    graph host-resident.

    The acceptance row: phi bit-identical, store-resident graph bytes
    never exceed the budget, bytes actually spilled (the chunk-wise
    ``remove_edges`` makes aliased chunks free), and the background
    prefetcher serving at least half of all chunk requests — the counters
    land in the ``table4disk`` rows of ``BENCH_ooc.json``.
    """
    import shutil
    import tempfile

    from benchmarks.datasets import load
    from repro.core.bottom_up import bottom_up_decompose
    from repro.core.graph import build_graph
    from repro.core.store import ChunkedDiskStore

    names = ["hep-like"] if smoke else ["hep-like", "amazon-like",
                                        "wiki-like"]
    for name in names:
        jax.clear_caches()      # per-graph cold-run isolation
        n, edges = load(name)
        budget = max(len(edges) // 32, 1024)
        g = build_graph(n, edges)
        graph_bytes = sum(
            int(getattr(g, a).nbytes)
            for a in ("edges", "deg", "rank", "src", "dst", "indptr",
                      "nbrs", "nbr_eid"))
        host_budget = graph_bytes // 8          # the paper's regime: RAM
        chunk_bytes = max(host_budget // 16, 4096)   # keep a real window
        usb, res_b = _time(lambda: bottom_up_decompose(n, edges, budget))
        d = tempfile.mkdtemp(prefix="bench_store_")
        try:
            store = ChunkedDiskStore(d, host_memory_budget=host_budget,
                                     chunk_bytes=chunk_bytes)
            with store:
                usd, res_d = _time(lambda: bottom_up_decompose(
                    n, edges, budget, store=store))
                peak = store.stats.peak_resident_bytes
        finally:
            shutil.rmtree(d, ignore_errors=True)
        assert (res_d.phi == res_b.phi).all()
        st = res_d.stats
        hit_rate = st.prefetch_hit_rate
        assert st.bytes_spilled > 0, st
        assert peak <= host_budget, (peak, host_budget)
        assert hit_rate >= 0.5, (hit_rate, st)
        emit(f"table4disk_{name}_TDbottomup_diskstore", usd,
             f"graph_bytes={graph_bytes};host_budget={host_budget};"
             f"spilled={st.bytes_spilled};reads={st.chunk_reads};"
             f"writes={st.chunk_writes};hit_rate={hit_rate:.3f};"
             f"peak_resident={peak};slowdown_vs_inmem={usd/usb:.2f};"
             f"budget={budget}",
             m=len(edges), budget=budget, graph_bytes=graph_bytes,
             host_memory_budget=host_budget, chunk_bytes=chunk_bytes,
             chunk_reads=st.chunk_reads, chunk_writes=st.chunk_writes,
             bytes_spilled=st.bytes_spilled,
             prefetch_hits=st.prefetch_hits,
             prefetch_misses=st.prefetch_misses,
             prefetch_hit_rate=hit_rate, peak_resident_bytes=peak,
             rounds=res_d.rounds, checkpoints=st.checkpoints,
             slowdown_vs_inmem=usd / usb)
        emit(f"table4disk_{name}_TDbottomup_inmem_ref", usb,
             f"rounds={res_b.rounds};graph_bytes={graph_bytes}",
             m=len(edges), budget=budget, graph_bytes=graph_bytes,
             rounds=res_b.rounds)


def table5_top_down():
    from benchmarks.datasets import MEDIUM, load
    from repro.core.bottom_up import bottom_up_decompose
    from repro.core.top_down import top_down_decompose

    for name in MEDIUM:
        n, edges = load(name)
        budget = max(len(edges) // 8, 1024)
        ust, res_t = _time(lambda: top_down_decompose(n, edges, t=5))
        usa, res_a = _time(lambda: top_down_decompose(n, edges))
        usb, res_b = _time(lambda: bottom_up_decompose(n, edges, budget))
        for k in res_t.classes:
            assert (res_t.phi == k).sum() == (res_b.phi == k).sum()
        emit(f"table5_{name}_TDtopdown_top5", ust,
             f"classes={res_t.classes};cand={max(res_t.candidate_sizes or [0])}")
        emit(f"table5_{name}_TDtopdown_all", usa,
             f"kmax={res_a.kmax};pruned={res_a.pruned}")
        emit(f"table5_{name}_TDbottomup_all", usb,
             f"top5_speedup_vs_bottomup={usb/ust:.2f}")


def table5_maintenance(smoke: bool = False):
    """Incremental maintenance vs full recompute (DESIGN.md §16).

    For each rmat benchmark graph and edit-batch size b, a random batch of
    b edits (half deletions of existing edges, the rest insertions of new
    ones; b=1 is the paper's streaming single-insert case) is applied with
    :func:`truss_maintain` against a precomputed phi, and the wall-clock is
    compared with the fastest recompute available (the in-memory bulk
    peel) on the final edge set.  phi is asserted bit-identical to the
    recompute — the differential suite pins the same equality across the
    conformance corpus, this row pins it at benchmark scale and prices it.

    The acceptance row: ``speedup_vs_recompute >= 5`` at b=1 (gated in
    CI from ``BENCH_maint.json``).  Speedup decays with b — maintenance
    is sequential-exact, so cost is linear in b while the recompute is
    flat — and the crossover batch size is exactly what the column
    communicates.
    """
    from benchmarks.datasets import load
    from repro.core.maintain import truss_maintain
    from repro.core.peel import truss_decompose

    names = ["hep-like"] if smoke else ["hep-like", "amazon-like"]
    batches = (1, 8) if smoke else (1, 8, 64)
    for name in names:
        jax.clear_caches()
        n, edges = load(name)
        # the maintained state: NOT timed into either side of the row
        phi0 = truss_decompose(n, edges)
        present = {tuple(e) for e in np.asarray(edges).tolist()}
        rng = np.random.default_rng(9)
        for b in batches:
            n_del = b // 2
            steps = [("delete", int(u), int(v))
                     for u, v in (edges[i] for i in rng.choice(
                         len(edges), n_del, replace=False))]
            while len(steps) < b:
                u, v = (int(x) for x in rng.integers(0, n, 2))
                lo, hi = min(u, v), max(u, v)
                if lo == hi or (lo, hi) in present:
                    continue
                present.add((lo, hi))
                steps.append(("insert", lo, hi))
            us_m, res = _time(lambda: truss_maintain((n, edges), phi0,
                                                     steps))
            us_r, phi_r = _time(
                lambda: truss_decompose(res.graph.n, res.graph.edges))
            assert (res.phi == phi_r).all()
            st = res.stats
            emit(f"table5maint_{name}_maintain_b{b}", us_m,
                 f"m={len(edges)};edits={st.edits_applied};"
                 f"levels={st.maintain_levels};"
                 f"affected={st.affected_edges};"
                 f"speedup_vs_recompute={us_r/us_m:.2f}",
                 m=len(edges), batch=b, edits_applied=st.edits_applied,
                 maintain_levels=st.maintain_levels,
                 affected_edges=st.affected_edges,
                 speedup_vs_recompute=us_r / us_m)
            emit(f"table5maint_{name}_recompute_b{b}", us_r,
                 f"m={res.graph.m}", m=res.graph.m, batch=b)


def table6_truss_vs_core():
    from benchmarks.datasets import MEDIUM, SMALL, load
    from repro.core.graph import clustering_coefficient, incident_vertices
    from repro.core.kcore import cmax_core
    from repro.core.peel import kmax_truss

    for name in list(SMALL) + list(MEDIUM):
        n, edges = load(name)
        us, (kmax, t_edges) = _time(lambda: kmax_truss(n, edges))
        cmax, c_edges = cmax_core(n, edges)
        vt = len(incident_vertices(t_edges))
        vc = len(incident_vertices(c_edges))
        cct = clustering_coefficient(n, t_edges) if len(t_edges) else 0.0
        ccc = clustering_coefficient(n, c_edges) if len(c_edges) else 0.0
        emit(f"table6_{name}_kmaxtruss_vs_cmaxcore", us,
             f"VT/VC={vt}/{vc};ET/EC={len(t_edges)}/{len(c_edges)};"
             f"kmax/cmax={kmax}/{cmax};CCT/CCC={cct:.2f}/{ccc:.2f}")


def peel_engines(smoke: bool = False):
    """Frontier-compacted engine vs the seed dense engine (DESIGN.md §3).

    Same supports, same triangle list, identical phi asserted; the emitted
    counters show scatter work scaling with the frontier (gathered == 3T)
    instead of with rounds * 3T.
    """
    from benchmarks.datasets import MEDIUM, SMALL, load
    from repro.core.graph import build_graph
    from repro.core.peel import (_pick_engine, peel_classes,
                                 peel_classes_dense)
    from repro.core.support import (edge_support_jax, list_triangles_np,
                                    support_from_triangle_list,
                                    triangle_incidence_np)

    names = ["p2p-like"] if smoke else list(SMALL) + list(MEDIUM)
    for name in names:
        n, edges = load(name)
        g = build_graph(n, edges)
        tris = list_triangles_np(g)
        sup = support_from_triangle_list(tris, g.m).astype(np.int32)
        if len(tris) == 0:
            tris = np.full((1, 3), g.m, np.int32)
        supj = jnp.asarray(sup)
        trisj = jnp.asarray(tris)
        alivej = jnp.ones(g.m, bool)

        t0 = time.perf_counter()
        inc = triangle_incidence_np(tris, g.m)
        inc_us = (time.perf_counter() - t0) * 1e6

        def dense():
            phi, _ = peel_classes_dense(supj, trisj, alivej)
            return jax.block_until_ready(phi)

        def frontier():
            phi, _, st = peel_classes(supj, trisj, alivej, incidence=inc,
                                      with_stats=True)
            return jax.block_until_ready(phi), st

        us_d, phi_d = _time(dense, repeats=2)
        us_f, (phi_f, st) = _time(frontier, repeats=2)
        assert (np.asarray(phi_f) == np.asarray(phi_d)).all()
        # what the production entry points would pick
        auto = _pick_engine("auto", tris, g.m, with_stats=False)
        emit(f"peel_{name}_dense_seed", us_d,
             f"m={g.m};T={len(tris)}", m=g.m, triangles=int(len(tris)))
        emit(f"peel_{name}_frontier", us_f,
             f"speedup_vs_dense={us_d/us_f:.2f};rounds={st.rounds};"
             f"gathered={st.gathered};auto_picks={auto}",
             m=g.m, triangles=int(len(tris)),
             speedup_vs_dense=us_d / us_f, rounds=st.rounds,
             removed=st.removed, gathered=st.gathered,
             max_frontier=st.max_frontier, cap_f=st.cap_f, cap_t=st.cap_t,
             resumes=st.resumes, incidence_build_us=inc_us,
             auto_picks=auto)

        # skew-aware support vs the seed global-D wedge scan (§4)
        def sup_global():
            return jax.block_until_ready(edge_support_jax(g, bucketed=False))

        def sup_bucketed():
            return jax.block_until_ready(edge_support_jax(g, bucketed=True))

        us_g, s_g = _time(sup_global, repeats=2)
        us_b, s_b = _time(sup_bucketed, repeats=2)
        assert (np.asarray(s_g) == np.asarray(s_b)).all()
        emit(f"support_{name}_globalD_seed", us_g, f"D={g.max_out_deg}")
        emit(f"support_{name}_bucketed", us_b,
             f"speedup_vs_globalD={us_g/us_b:.2f}",
             speedup_vs_globalD=us_g / us_b)


def kernel_micro():
    from repro.core.graph import canonical_edges
    from repro.data import graphgen
    from repro.kernels.triangle_count.ops import (adjacency_from_edges,
                                                  dense_support)
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.embedding_bag.ops import embedding_bag

    rng = np.random.default_rng(0)
    n = 256
    edges = graphgen.erdos_renyi(n, 4000, seed=5)
    A = jnp.asarray(adjacency_from_edges(n, edges))
    us, S = _time(lambda: jax.block_until_ready(
        dense_support(A, block=128, interpret=True)), repeats=2)
    emit("kernel_triangle_count_256", us,
         f"triangles={float(np.asarray(S).sum())/6:.0f}")

    q = jnp.asarray(rng.standard_normal((1, 4, 256, 64)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)).astype(np.float32))
    us, _ = _time(lambda: jax.block_until_ready(
        flash_attention(q, k, k, bq=128, bk=128, interpret=True)), repeats=2)
    emit("kernel_flash_attention_256", us, "GQA4:2,d64")

    tbl = jnp.asarray(rng.standard_normal((4096, 18)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 4096, (64, 100)).astype(np.int32))
    us, _ = _time(lambda: jax.block_until_ready(
        embedding_bag(tbl, idx, interpret=True)), repeats=2)
    emit("kernel_embedding_bag_64x100", us, "din bag shape")


def roofline_summary():
    """Read dry-run results if present (launch/dryrun.py --out)."""
    import json
    import os
    path = os.environ.get("DRYRUN_JSON", "results/dryrun_all.json")
    if not os.path.exists(path):
        emit("roofline_summary_skipped", 0.0, f"no {path}")
        return
    with open(path) as f:
        recs = json.load(f)
    for r in recs:
        if not r.get("ok"):
            continue
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        t = max(r["t_compute"], r["t_memory"], r["t_collective"])
        emit(name, t * 1e6,
             f"bottleneck={r['bottleneck']};frac={r['roofline_fraction']:.3f}")


TABLES = {
    "table3": table3_inmemory,
    "table4": table4_bottom_up,
    "table4part": table4_partitioners,
    "table4shard": table4_sharded,
    "table4kernel": table4_kernel,
    "table4resil": table4_resilience,
    "table4disk": table4_disk,
    "table5": table5_top_down,
    "table5maint": table5_maintenance,
    "table6": table6_truss_vs_core,
    "peel": peel_engines,
    "kernel": kernel_micro,
    "roofline": roofline_summary,
}

# tables that accept smoke= (smallest-dataset variant); shared with hillclimb
SMOKE_TABLES = ("peel", "table4", "table4part", "table4shard",
                "table4kernel", "table4resil", "table4disk", "table5maint")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write records as a JSON array (BENCH_*.json)")
    ap.add_argument("--only", action="append", default=None, metavar="PREFIX",
                    help="run only tables whose key starts with PREFIX "
                         "(repeatable); default: all")
    ap.add_argument("--smoke", action="store_true",
                    help="smallest-dataset smoke run of the peel and "
                         "table4 (OOC engine) comparisons")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    if args.smoke and args.only is None:
        args.only = ["peel"]
    for key, fn in TABLES.items():
        if args.only is not None and not any(key.startswith(p)
                                             for p in args.only):
            continue
        if key in SMOKE_TABLES:
            fn(smoke=args.smoke)
        else:
            fn()
        # every row means to time a COLD one-shot run, so drop the compiled
        # executables between tables — it also keeps the process under
        # vm.max_map_count on full multi-graph sweeps (each XLA executable
        # holds tens of mappings; the per-part seed rows alone compile
        # thousands)
        jax.clear_caches()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(ROWS, f, indent=1)
        print(f"# wrote {len(ROWS)} records to {args.json}", flush=True)


if __name__ == "__main__":
    main()
