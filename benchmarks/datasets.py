"""Benchmark graphs: synthetic stand-ins scaled to this container.

The paper's datasets (P2P .. Web, Table 2) are not redistributable here; the
benchmarks use R-MAT (power-law, the paper's web/social shape) and
Erdős–Rényi graphs at sizes that exercise the same algorithmic regimes.
Names record the analogy.
"""

from __future__ import annotations

from repro.data import graphgen

# name -> (kind, params); sizes chosen for single-core CPU wall times
SMALL = {
    "p2p-like": ("er", dict(n=6_000, m=42_000, seed=1)),
    "hep-like": ("rmat", dict(scale=13, edge_factor=6, seed=2)),
}
MEDIUM = {
    "amazon-like": ("rmat", dict(scale=14, edge_factor=6, seed=3)),
    "wiki-like": ("rmat", dict(scale=15, edge_factor=4, seed=4)),
}


def load(name):
    for group in (SMALL, MEDIUM):
        if name in group:
            kind, kw = group[name]
            if kind == "er":
                n = kw["n"]
                return n, graphgen.erdos_renyi(n, kw["m"], kw["seed"])
            n, e = graphgen.rmat(kw["scale"], kw["edge_factor"], kw["seed"])
            return n, e
    raise KeyError(name)
