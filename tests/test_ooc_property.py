"""Hypothesis property sweep for the batched out-of-core engines:
``bottom_up_decompose`` and ``top_down_decompose`` vs the ``alg2_truss``
oracle across random graphs × partitioners × budget fractions
(DESIGN.md §8).  The deterministic subset runs in test_ooc_batch.py."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import graph as glib
from repro.core.bottom_up import bottom_up_decompose, partitioned_support
from repro.core.serial import alg2_truss
from repro.core.support import edge_support_np
from repro.core.top_down import top_down_decompose


@st.composite
def graphs(draw, max_n=26):
    n = draw(st.integers(4, max_n))
    density = draw(st.floats(0.1, 0.6))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    iu = np.triu_indices(n, 1)
    keep = rng.random(len(iu[0])) < density
    return n, np.stack(iu, 1)[keep]


@settings(max_examples=12, deadline=None)
@given(graphs(), st.sampled_from(["sequential", "random", "locality"]),
       st.sampled_from([0.15, 0.35, 0.6]))
def test_bottom_up_batched_matches_oracle(g, partitioner, budget_frac):
    n, edges = g
    ce = glib.canonical_edges(edges, n)
    if len(ce) < 3:
        return
    oracle = alg2_truss(n, ce)
    budget = max(4, int(len(ce) * budget_frac))
    res = bottom_up_decompose(n, ce, budget, partitioner=partitioner)
    assert (res.phi == oracle).all()
    assert res.stats is not None and res.stats.parts >= 1


@settings(max_examples=12, deadline=None)
@given(graphs(), st.sampled_from(["sequential", "random", "locality"]),
       st.sampled_from([0.15, 0.35, 0.6]))
def test_top_down_batched_matches_oracle(g, partitioner, budget_frac):
    n, edges = g
    ce = glib.canonical_edges(edges, n)
    if len(ce) < 3:
        return
    oracle = alg2_truss(n, ce)
    budget = max(4, int(len(ce) * budget_frac))
    td = top_down_decompose(n, ce, budget=budget, partitioner=partitioner)
    assert (td.phi == oracle).all()


@settings(max_examples=10, deadline=None)
@given(graphs(), st.sampled_from([0.2, 0.5]))
def test_partitioned_support_batched_exact(g, budget_frac):
    n, edges = g
    ce = glib.canonical_edges(edges, n)
    if len(ce) < 3:
        return
    sup = edge_support_np(glib.build_graph(n, ce))
    budget = max(4, int(len(ce) * budget_frac))
    ps, stats = partitioned_support(n, ce, budget, with_stats=True)
    assert (ps == sup).all()
    assert stats.rounds >= 1


@settings(max_examples=12, deadline=None)
@given(graphs(), st.sampled_from([0.15, 0.35, 0.6]))
def test_partitioner_equivalence(g, budget_frac):
    """Lemma 1 holds for ANY valid (possibly zoned) partition: sequential,
    (rebalanced) random and triangle-aware locality rounds must all
    produce identical phi."""
    n, edges = g
    ce = glib.canonical_edges(edges, n)
    if len(ce) < 3:
        return
    budget = max(4, int(len(ce) * budget_frac))
    results = {
        p: bottom_up_decompose(n, ce, budget, partitioner=p)
        for p in ("sequential", "random", "locality")
    }
    phi_ref = results["sequential"].phi
    assert (phi_ref == alg2_truss(n, ce)).all()
    for p, res in results.items():
        assert (res.phi == phi_ref).all(), p
        assert 0.0 <= res.stats.tri_locality <= 1.0


@settings(max_examples=10, deadline=None)
@given(graphs(), st.sampled_from(["sequential", "locality"]),
       st.sampled_from([0.2, 0.5]))
def test_stage2_pipeline_property(g, partitioner, budget_frac):
    """The stage-2 candidate pipeline (DESIGN.md §11): prebuilt superset
    candidates + alive-mask fixups never change phi on either driver, and
    the counters stay consistent."""
    n, edges = g
    ce = glib.canonical_edges(edges, n)
    if len(ce) < 3:
        return
    oracle = alg2_truss(n, ce)
    budget = max(4, int(len(ce) * budget_frac))
    res = bottom_up_decompose(n, ce, budget, partitioner=partitioner)
    assert (res.phi == oracle).all()
    assert 0 <= res.stats.stage2_overlapped <= res.stats.scans
    td = top_down_decompose(n, ce, budget=budget, partitioner=partitioner)
    assert (td.phi == oracle).all()
    assert 0 <= td.stats.stage2_overlapped <= td.stats.scans
    assert res.stats.tri_assigned <= res.stats.tri_total
    assert res.stats.tri_est_error >= 0.0


@settings(max_examples=10, deadline=None)
@given(graphs(), st.sampled_from([0.15, 0.4]),
       st.sampled_from([1 << 9, 1 << 12, 1 << 16, None]),
       st.sampled_from([1 << 8, 1 << 11]))
def test_disk_store_budget_sweep(g, budget_frac, host_budget, chunk_bytes):
    """DESIGN.md §15: for ANY host_memory_budget (down to refusing every
    chunk admission) and chunk size, the disk-backed driver reproduces the
    oracle bit-for-bit, the store never retains more than the budget, and
    the prefetch counters stay consistent."""
    import tempfile

    from repro.core.store import ChunkedDiskStore

    n, edges = g
    ce = glib.canonical_edges(edges, n)
    if len(ce) < 3:
        return
    oracle = alg2_truss(n, ce)
    budget = max(4, int(len(ce) * budget_frac))
    with tempfile.TemporaryDirectory() as d:
        with ChunkedDiskStore(d, host_memory_budget=host_budget,
                              chunk_bytes=chunk_bytes) as store:
            res = bottom_up_decompose(n, ce, budget, store=store)
            peak = store.stats.peak_resident_bytes
        assert (res.phi == oracle).all()
        s = res.stats
        assert s.chunk_writes > 0 and s.chunk_reads > 0
        assert s.bytes_spilled > 0
        assert s.prefetch_hits + s.prefetch_misses > 0
        assert 0.0 <= s.prefetch_hit_rate <= 1.0
        if host_budget is not None:
            assert peak <= host_budget


@settings(max_examples=8, deadline=None)
@given(graphs(), st.sampled_from([0.2, 0.5]), st.integers(0, 2**31 - 1))
def test_wrong_triangle_estimate_keeps_phi(g, budget_frac, est_seed):
    """The triangle cost model steers locality only: a garbage estimator
    must never change phi (regression for the DESIGN.md §11 contract)."""
    import repro.core.partition as plib

    n, edges = g
    ce = glib.canonical_edges(edges, n)
    if len(ce) < 3:
        return
    budget = max(4, int(len(ce) * budget_frac))
    real = plib.closed_wedge_estimate

    def wrong(graph):
        rng = np.random.default_rng(est_seed)
        return rng.integers(0, 10**9, size=graph.n)

    plib.closed_wedge_estimate = wrong
    try:
        res = bottom_up_decompose(n, ce, budget, partitioner="locality")
    finally:
        plib.closed_wedge_estimate = real
    assert (res.phi == alg2_truss(n, ce)).all()
