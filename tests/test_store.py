"""GraphStore unit contract (DESIGN.md §15): chunk roundtrips, filter
aliasing, the shared I/O budget account, prefetch accounting, refcounted
file lifecycle, the chunk-I/O fault sites, and the wall-clock checkpoint
gate with an injected monotonic clock.

End-to-end store-backed decomposition lives in the conformance matrix
(test_conformance.py) and the hypothesis sweep (test_ooc_property.py);
this file pins the store's own invariants in isolation.
"""

import glob
import os

import numpy as np
import pytest

from repro.core import faults
from repro.core import graph as glib
from repro.core.bottom_up import OocStats, RoundJournal, _parse_every
from repro.core.store import (ChunkedDiskStore, InMemoryStore, IoAccount,
                              StoreError, StoreStats)


def _disk(tmp_path, **kw):
    kw.setdefault("chunk_bytes", 256)   # many chunks even for tiny arrays
    return ChunkedDiskStore(str(tmp_path / "store"), **kw)


# ---------------------------------------------------------------------------
# roundtrips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [InMemoryStore, _disk],
                         ids=["memory", "disk"])
def test_put_get_roundtrip(tmp_path, make):
    store = make(tmp_path) if make is _disk else make()
    with store:
        cases = {
            "g1/edges": np.arange(1000, dtype=np.int64).reshape(-1, 2),
            "g1/deg": np.arange(37, dtype=np.int32),
            "g1/flags": np.array([True, False, True]),
            "g1/tris": np.arange(99, dtype=np.int64).reshape(-1, 3),
            "g1/empty": np.zeros((0, 2), dtype=np.int64),
        }
        for key, arr in cases.items():
            store.put(key, arr)
        for key, arr in cases.items():
            got = store.get(key)
            assert got.dtype == arr.dtype, key
            assert got.shape == arr.shape, key
            assert (got == arr).all(), key


def test_disk_get_unknown_key_raises(tmp_path):
    with _disk(tmp_path) as store:
        with pytest.raises(StoreError, match="unknown"):
            store.get("g1/edges")


def test_put_overwrites_and_frees_old_chunks(tmp_path):
    with _disk(tmp_path) as store:
        store.put("g1/x", np.arange(500, dtype=np.int64))
        first = set(glob.glob(str(tmp_path / "store" / "*.bin")))
        store.put("g1/x", np.arange(5, dtype=np.int64))
        assert (store.get("g1/x") == np.arange(5)).all()
        # the overwritten chunks are gone from disk
        assert not (first & set(glob.glob(str(tmp_path / "store"
                                              / "*.bin"))))


def test_inmemory_counters_stay_zero(tmp_path):
    with InMemoryStore() as store:
        store.put("g1/x", np.arange(100))
        store.get("g1/x")
        store.prefetch(["g1/x"])
        store.release("g1/x")
        assert store.stats.as_dict() == StoreStats().as_dict()


# ---------------------------------------------------------------------------
# chunk-wise filter + aliasing (the remove_edges spill path)
# ---------------------------------------------------------------------------

def test_put_filtered_rewrites_only_touched_chunks(tmp_path):
    with _disk(tmp_path, chunk_bytes=800) as store:   # 100 i64 rows/chunk
        src = np.arange(400, dtype=np.int64)
        store.put("g1/x", src)
        spilled0 = store.stats.bytes_spilled
        writes0 = store.stats.chunk_writes
        # drop rows only from the second chunk: chunks 0, 2, 3 are aliased
        keep = np.ones(400, dtype=bool)
        keep[150:160] = False
        store.put_filtered("g2/x", "g1/x", keep, src[keep])
        assert (store.get("g2/x") == src[keep]).all()
        assert store.stats.chunk_writes == writes0 + 1
        assert store.stats.bytes_spilled == spilled0 + 90 * 8
        # the filtered key survives release of its source (refcounts)
        store.release("g1/x")
        assert (store.get("g2/x") == src[keep]).all()
        store.release("g2/x")
        assert not glob.glob(str(tmp_path / "store" / "*.bin"))


def test_alias_costs_zero_write_io(tmp_path):
    with _disk(tmp_path) as store:
        rank = np.arange(1000, dtype=np.int64)
        store.put("g1/rank", rank)
        spilled = store.stats.bytes_spilled
        store.alias("g2/rank", "g1/rank", rank)
        assert store.stats.bytes_spilled == spilled
        store.release("g1/rank")
        assert (store.get("g2/rank") == rank).all()


def test_put_filtered_mask_mismatch_raises(tmp_path):
    with _disk(tmp_path) as store:
        src = np.arange(100, dtype=np.int64)
        store.put("g1/x", src)
        keep = np.ones(100, dtype=bool)
        keep[:10] = False
        with pytest.raises(StoreError, match="keeps"):
            store.put_filtered("g2/x", "g1/x", keep, src)  # wrong length


def test_put_filtered_without_source_falls_back_to_put(tmp_path):
    with _disk(tmp_path) as store:
        arr = np.arange(50, dtype=np.int64)
        store.put_filtered("g2/x", "g1/x", np.ones(99, bool), arr)
        assert (store.get("g2/x") == arr).all()


# ---------------------------------------------------------------------------
# budget + prefetch accounting
# ---------------------------------------------------------------------------

def test_prefetch_hits_on_streamed_get(tmp_path):
    with _disk(tmp_path, lookahead=4) as store:
        store.put("g1/x", np.arange(2000, dtype=np.int64))
        n_chunks = len(store._manifests["g1/x"].chunks)
        assert n_chunks > 4
        store.prefetch(["g1/x"])
        store.get("g1/x")
        s = store.stats
        assert s.prefetch_hits + s.prefetch_misses == n_chunks
        # head was warmed and the window stays ahead: everything hits
        assert s.prefetch_misses == 0
        assert s.prefetch_hit_rate == 1.0


def test_cold_get_first_chunk_misses(tmp_path):
    with _disk(tmp_path) as store:
        store.put("g1/x", np.arange(2000, dtype=np.int64))
        store.get("g1/x")     # no prefetch hint: chunk 0 reads sync
        assert store.stats.prefetch_misses >= 1
        assert store.stats.prefetch_hits >= 1


def test_budget_caps_resident_bytes(tmp_path):
    budget = 600
    with _disk(tmp_path, host_memory_budget=budget, chunk_bytes=256,
               lookahead=8) as store:
        arr = np.arange(4000, dtype=np.int64)
        store.put("g1/x", arr)
        assert (store.get("g1/x") == arr).all()
        assert store.stats.peak_resident_bytes <= budget
        assert store.io_account.peak <= budget
        assert store.resident_bytes == 0    # read-once: drained after get


def test_tight_budget_still_correct(tmp_path):
    # budget below one chunk: every admission is refused, every read is a
    # synchronous miss, the data still comes back bit-identical (1024 rows
    # chunk evenly, so no undersized tail chunk slips under the budget)
    with _disk(tmp_path, host_memory_budget=64, chunk_bytes=256) as store:
        arr = np.arange(1024, dtype=np.int64)
        store.put("g1/x", arr)
        assert (store.get("g1/x") == arr).all()
        assert store.stats.prefetch_hits == 0
        assert store.stats.prefetch_misses > 0


def test_io_account_shared_with_checkpoint_hold(tmp_path):
    account = IoAccount(budget_bytes=512)
    with _disk(tmp_path, io_account=account, chunk_bytes=256) as store:
        store.put("g1/x", np.arange(500, dtype=np.int64))
        with account.hold(512, "checkpoint"):
            # a checkpoint in flight fills the budget: no chunk admitted
            store.prefetch(["g1/x"])
            assert store.resident_bytes == 0
            arr = store.get("g1/x")     # all synchronous misses
        assert (arr == np.arange(500)).all()
        assert store.stats.prefetch_hits == 0
        assert account.checkpoint_bytes_total == 512
        assert account.reserved == 0


def test_ctor_validation(tmp_path):
    for bad in ({"host_memory_budget": 0}, {"host_memory_budget": -1},
                {"chunk_bytes": 0}, {"lookahead": 0}):
        with pytest.raises(ValueError):
            ChunkedDiskStore(str(tmp_path / "s"), **bad)


def test_init_sweeps_stale_spill_files(tmp_path):
    d = tmp_path / "store"
    d.mkdir()
    (d / "dead-00000001.bin").write_bytes(b"x" * 64)
    (d / "dead-00000002.bin.tmp").write_bytes(b"y")
    (d / "keep.npz").write_bytes(b"z")      # not a spill artifact
    with ChunkedDiskStore(str(d)):
        pass
    assert sorted(os.listdir(d)) == ["keep.npz"]


# ---------------------------------------------------------------------------
# fault sites
# ---------------------------------------------------------------------------

def test_chunk_write_fault_injects(tmp_path):
    plan = faults.FaultPlan([faults.FaultRule(
        site=faults.CHUNK_WRITE, kind="error", nth=2)])
    with _disk(tmp_path) as store, faults.active(plan):
        with pytest.raises(faults.InjectedFault):
            store.put("g1/x", np.arange(500, dtype=np.int64))
    assert len(plan.log) == 1


def test_chunk_read_fault_injects_with_context(tmp_path):
    with _disk(tmp_path) as store:
        store.put("g1/x", np.arange(500, dtype=np.int64))
        plan = faults.FaultPlan([faults.FaultRule(
            site=faults.CHUNK_READ, kind="error",
            where={"key": "g1/x"}, nth=1)])
        with faults.active(plan):
            with pytest.raises(faults.InjectedFault):
                store.get("g1/x")
        assert len(plan.log) == 1


def test_torn_chunk_detected(tmp_path):
    with _disk(tmp_path) as store:
        store.put("g1/x", np.arange(500, dtype=np.int64))
        chunk = store._manifests["g1/x"].chunks[1]
        with open(chunk.path, "wb") as f:
            f.write(b"\0" * (chunk.nbytes - 8))     # truncated payload
        with pytest.raises(StoreError, match="torn"):
            store.get("g1/x")


# ---------------------------------------------------------------------------
# Graph integration + counter absorption
# ---------------------------------------------------------------------------

def test_graph_spill_roundtrip_and_release(tmp_path):
    rng = np.random.default_rng(7)
    n = 40
    iu = np.triu_indices(n, 1)
    keep = rng.random(len(iu[0])) < 0.3
    ce = glib.canonical_edges(np.stack(iu, 1)[keep], n)
    ref = glib.build_graph(n, ce)
    with _disk(tmp_path) as store:
        g = glib.build_graph(n, ce, store=store)
        g.spill()
        g2 = g.remove_edges(np.arange(g.m) % 3 == 0)
        g2.spill()
        g.release()
        ref2 = ref.remove_edges(np.arange(ref.m) % 3 == 0)
        for name in ("edges", "deg", "rank", "src", "dst", "indptr",
                     "nbrs", "nbr_eid"):
            assert (getattr(g2, name) == getattr(ref2, name)).all(), name
        g2.release()
        assert not glob.glob(str(tmp_path / "store" / "*.bin"))


def test_absorb_into_is_delta_based(tmp_path):
    with _disk(tmp_path) as store:
        store.put("g1/x", np.arange(500, dtype=np.int64))
        stats = OocStats()
        store.absorb_into(stats)
        mid = stats.chunk_writes
        assert mid == store.stats.chunk_writes > 0
        store.absorb_into(stats)                 # no new I/O: no change
        assert stats.chunk_writes == mid
        store.get("g1/x")
        store.absorb_into(stats)
        assert stats.chunk_reads == store.stats.chunk_reads > 0


# ---------------------------------------------------------------------------
# wall-clock checkpoint gate (_parse_every + injected clock)
# ---------------------------------------------------------------------------

def test_parse_every_accepts_counts_and_durations():
    assert _parse_every(3) == ("events", 3)
    assert _parse_every("30s") == ("time", 30.0)
    assert _parse_every("500ms") == ("time", 0.5)
    assert _parse_every("2m") == ("time", 120.0)
    assert _parse_every("1.5h") == ("time", 5400.0)
    for bad in ("", "30", "s", "30 sec", "-5s", "0s"):
        with pytest.raises(ValueError):
            _parse_every(bad)


def test_round_journal_wall_clock_gate(tmp_path):
    now = [0.0]
    journal = RoundJournal(str(tmp_path / "ckpt"), "rk", every="30s",
                           clock=lambda: now[0])
    stats = OocStats()
    arrays = {"phi": np.arange(8, dtype=np.int64)}
    assert not journal.record("s1", 0, arrays, stats)     # t=0: not due
    now[0] = 29.9
    assert not journal.record("s1", 1, arrays, stats)
    now[0] = 31.0
    assert journal.record("s1", 2, arrays, stats)         # 31s elapsed
    assert not journal.record("s1", 3, arrays, stats)     # window reset
    now[0] = 62.0
    assert journal.record("s1", 4, arrays, stats)
    assert stats.checkpoints == 2


def test_round_journal_charges_store_account(tmp_path):
    with _disk(tmp_path) as store:
        store.put("g1/x", np.arange(64, dtype=np.int64))
        journal = RoundJournal(str(tmp_path / "ckpt"), "rk", every=1,
                               store=store)
        stats = OocStats()
        assert journal.record("s1", 0,
                              {"phi": np.arange(8, dtype=np.int64)}, stats)
        assert store.io_account.checkpoint_bytes_total > 0
        assert store.io_account.reserved == 0       # released after save
        # the journal absorbed the store counters into the snapshot stats
        assert stats.chunk_writes == store.stats.chunk_writes > 0


# ---------------------------------------------------------------------------
# insertion splice + chunk streaming (the add_edges / maintenance spill path)
# ---------------------------------------------------------------------------

def test_put_inserted_aliases_untouched_chunks(tmp_path):
    with _disk(tmp_path, chunk_bytes=800) as store:   # 100 i64 rows/chunk
        src = np.arange(400, dtype=np.int64)
        store.put("g1/x", src)
        writes0 = store.stats.chunk_writes
        spilled0 = store.stats.bytes_spilled
        # splice 10 new rows into the middle of the second chunk: chunks
        # 0, 2 and 3 have no interior insertion point and must alias
        is_new = np.zeros(410, dtype=bool)
        is_new[150:160] = True
        arr = np.insert(src, 150, 10_000 + np.arange(10, dtype=np.int64))
        assert (arr[~is_new] == src).all()
        store.put_inserted("g2/x", "g1/x", is_new, arr)
        assert (store.get("g2/x") == arr).all()
        writes = store.stats.chunk_writes - writes0
        assert 1 <= writes < 5            # a full rewrite would be 5 chunks
        assert store.stats.bytes_spilled - spilled0 < arr.nbytes
        # the spliced key survives release of its source (refcounts)
        store.release("g1/x")
        assert (store.get("g2/x") == arr).all()
        store.release("g2/x")
        assert not glob.glob(str(tmp_path / "store" / "*.bin"))


def test_put_inserted_mismatch_falls_back_to_put(tmp_path):
    with _disk(tmp_path) as store:
        store.put("g1/x", np.arange(100, dtype=np.int64))
        arr = np.arange(50, dtype=np.int64)
        # is_new inconsistent with the source row count: plain put
        store.put_inserted("g2/x", "g1/x", np.ones(50, dtype=bool), arr)
        assert (store.get("g2/x") == arr).all()
        # unknown source key: plain put as well
        store.put_inserted("g3/x", "nope/x", np.zeros(50, dtype=bool), arr)
        assert (store.get("g3/x") == arr).all()


def test_get_chunks_bounds_peak_to_one_chunk(tmp_path):
    with _disk(tmp_path) as store:        # 256 B chunks = 32 i64 rows
        arr = np.arange(2000, dtype=np.int64)
        store.put("g1/x", arr)
        parts = []
        for part in store.get_chunks("g1/x"):
            assert len(part) <= 32        # never the whole key
            assert not part.flags.writeable
            parts.append(np.asarray(part))
        assert len(parts) > 4
        assert (np.concatenate(parts) == arr).all()
        with pytest.raises(StoreError, match="unknown"):
            list(store.get_chunks("nope/x"))


def test_stream_put_flushes_incrementally(tmp_path):
    with _disk(tmp_path) as store:        # 256 B chunks = 10 (3,)-rows
        rows = np.arange(300, dtype=np.int64).reshape(-1, 3)
        files0 = len(glob.glob(str(tmp_path / "store" / "*.bin")))
        with store.stream_put("g1/tris", np.int64, (3,)) as w:
            for lo in range(0, 100, 7):   # odd-sized appends
                w.append(rows[lo:lo + 7])
                assert w.rows == min(lo + 7, 100)
            # full chunks are already on disk before close
            assert len(glob.glob(str(tmp_path / "store" / "*.bin"))) > files0
            with pytest.raises(StoreError, match="unknown"):
                store.get("g1/tris")      # registered only at close
        assert (store.get("g1/tris") == rows).all()


def test_stream_put_same_key_keeps_old_until_close(tmp_path):
    with _disk(tmp_path) as store:
        old = np.arange(60, dtype=np.int64).reshape(-1, 3)
        store.put("g1/tris", old)
        w = store.stream_put("g1/tris", np.int64, (3,))
        w.append(old[:5] * 2)
        assert (store.get("g1/tris") == old).all()    # still the old rows
        w.close()
        assert (store.get("g1/tris") == old[:5] * 2).all()


def test_stream_put_empty_registers_empty_key(tmp_path):
    with _disk(tmp_path) as store:
        with store.stream_put("g1/tris", np.int64, (3,)) as w:
            assert w.rows == 0
        got = store.get("g1/tris")
        assert got.shape == (0, 3) and got.dtype == np.int64
