"""Batched out-of-core engine: partition batches, incremental maintenance,
batched local peels (DESIGN.md §8) — against the serial oracle and the seed
per-part path."""

import numpy as np
import pytest

from repro.core import graph as glib
from repro.core.bottom_up import (OocStats, _local_truss,
                                  bottom_up_decompose, lower_bounding,
                                  partitioned_support)
from repro.core.partition import (build_partition_batch, ns_edge_lists,
                                  sequential_partition)
from repro.core.peel import (PendingPeel, estimate_working_set,
                             local_threshold_peel, peel_classes_batched,
                             truss_decompose)
from repro.core.serial import alg2_truss
from repro.core.support import edge_support_np, list_triangles, list_triangles_np
from tests.conftest import clique_edges, random_graph


# ---------------------------------------------------------------------------
# deterministic oracle corpus (the hypothesis sweep lives in
# test_ooc_property.py; this subset runs without hypothesis installed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("partitioner", ["sequential", "random"])
@pytest.mark.parametrize("budget_frac", [0.15, 0.5])
def test_batched_engines_match_oracle(rng, partitioner, budget_frac):
    from repro.core.top_down import top_down_decompose

    for trial in range(3):
        n = 20 + 6 * trial
        ce = glib.canonical_edges(random_graph(rng, n, 0.3), n)
        if len(ce) < 3:
            continue
        oracle = alg2_truss(n, ce)
        budget = max(4, int(len(ce) * budget_frac))
        res = bottom_up_decompose(n, ce, budget, partitioner=partitioner)
        assert (res.phi == oracle).all()
        assert res.stats is not None and res.stats.parts >= 1
        td = top_down_decompose(n, ce, budget=budget, partitioner=partitioner)
        assert (td.phi == oracle).all()
        sup = edge_support_np(glib.build_graph(n, ce))
        ps, stats = partitioned_support(n, ce, budget,
                                        partitioner=partitioner,
                                        with_stats=True)
        assert (ps == sup).all()
        assert stats.rounds >= 1


# ---------------------------------------------------------------------------
# batch construction: compaction, bucketing, padding
# ---------------------------------------------------------------------------

def test_ns_edge_lists_matches_reference(rng):
    n = 48
    ce = glib.canonical_edges(random_graph(rng, n, 0.25), n)
    g = glib.build_graph(n, ce)
    parts = sequential_partition(g, budget=max(8, len(ce) // 5))
    assert len(parts) >= 3
    lists = ns_edge_lists(g, parts)
    for P, (ids, internal) in zip(parts, lists):
        ids_ref, _, int_ref = glib.neighborhood_subgraph(g, P)
        assert (ids == ids_ref).all()
        assert (internal == int_ref).all()


def test_bucket_padding_never_contributes_support(rng):
    """Padded lanes and padded edge slots are inert: zero support in, zero
    phi out; every packed part slice reproduces the seed per-part local
    peel exactly."""
    n = 40
    ce = glib.canonical_edges(random_graph(rng, n, 0.3), n)
    g = glib.build_graph(n, ce)
    parts = sequential_partition(g, budget=max(8, len(ce) // 4))
    batch = build_partition_batch(g, parts)
    assert batch.n_parts == len(parts)
    assert batch.real_edges <= batch.padded_slots
    seen_parts = set()
    for bucket in batch.buckets:
        B = bucket.n_lanes
        # padded lanes are fully dead
        for lane in range(bucket.n_real_lanes, B):
            assert not bucket.alive[lane].any()
            assert (bucket.edge_ids[lane] == -1).all()
            assert (bucket.tris[lane] == bucket.cap_e).all()
            assert (bucket.sup[lane] == 0).all()
        phi_b, _, _ = peel_classes_batched(
            bucket.sup, bucket.tris, bucket.indptr, bucket.tids, bucket.alive)
        assert (phi_b[bucket.n_real_lanes:] == 0).all()
        for lane in range(bucket.n_real_lanes):
            real = bucket.edge_ids[lane] >= 0
            assert (real == (bucket.part_of[lane] >= 0)).all()
            # padded edge slots: dead, zero support, zero phi
            assert not bucket.alive[lane][~real].any()
            assert (bucket.sup[lane][~real] == 0).all()
            assert (phi_b[lane][~real] == 0).all()
            # padding triangles all point at the drop slot; support totals
            # 3 * (real triangle count) — padding contributed nothing
            n_tri = int((bucket.tris[lane][:, 0] < bucket.cap_e).sum())
            assert int(bucket.sup[lane].sum()) == 3 * n_tri
            # every part slice packed into the lane equals the seed
            # per-part local peel of that NS
            for p in np.unique(bucket.part_of[lane][real]):
                sl = bucket.part_of[lane] == p
                ref = _local_truss(g.edges[bucket.edge_ids[lane][sl]], g.n)
                assert (phi_b[lane][sl] == ref).all()
                seen_parts.add(int(p))
    assert len(seen_parts) == batch.n_parts


def test_local_threshold_peel_matches_dense(rng):
    """Pow2-padded compacted threshold peel == dense full-shape peel."""
    import jax.numpy as jnp

    from repro.core.peel import peel_threshold_dense
    from repro.core.support import support_from_triangle_list

    n = 24
    ce = glib.canonical_edges(random_graph(rng, n, 0.4), n)
    g = glib.build_graph(n, ce)
    tris = list_triangles_np(g)
    sup = support_from_triangle_list(tris, g.m).astype(np.int32)
    removable = rng.random(g.m) < 0.7
    for thresh in (0, 1, 2, 4):
        cache: set = set()
        alive, removed, _ = local_threshold_peel(
            sup, tris, removable, thresh, shape_cache=cache)
        tris_j = jnp.asarray(tris if len(tris) else
                             np.full((1, 3), g.m, np.int32))
        a_ref, _, r_ref = peel_threshold_dense(
            jnp.asarray(sup), tris_j, jnp.ones(g.m, bool),
            jnp.asarray(removable), jnp.int32(thresh))
        assert (alive == np.asarray(a_ref)).all()
        assert (removed == np.asarray(r_ref)).all()


# ---------------------------------------------------------------------------
# incremental graph maintenance
# ---------------------------------------------------------------------------

def test_remove_edges_equivalent_to_rebuild(rng):
    n = 45
    ce = glib.canonical_edges(random_graph(rng, n, 0.25), n)
    g = glib.build_graph(n, ce)
    edges = ce
    for _ in range(5):
        if g.m == 0:
            break
        rm = rng.random(g.m) < 0.35
        g = g.remove_edges(rm)
        edges = edges[~rm]
        ref = glib.build_graph(n, edges)
        assert (g.edges == ref.edges).all()
        assert (g.deg == ref.deg).all()
        assert g.indptr[-1] == g.m
        # orientation may differ (ranks are reused, not recomputed), but
        # wedge enumeration must see the same triangles/supports
        assert (edge_support_np(g) == edge_support_np(ref)).all()
        s_inc = np.zeros(g.m, np.int64)
        tl = list_triangles(g)
        if len(tl):
            np.add.at(s_inc, tl.reshape(-1), 1)
        assert (s_inc == edge_support_np(ref)).all()


def test_remove_all_edges(rng):
    ce = glib.canonical_edges(random_graph(rng, 10, 0.5), 10)
    g = glib.build_graph(10, ce)
    g2 = g.remove_edges(np.ones(g.m, bool))
    assert g2.m == 0 and g2.max_out_deg == 0
    assert (g2.deg == 0).all()


# ---------------------------------------------------------------------------
# stage-2 class-k skip + dispatch
# ---------------------------------------------------------------------------

def test_stage2_skips_empty_classes():
    """Disjoint K12 + K5 + a path: the only classes are {2, 5, 12}, and the
    lower bounds are exact, so stage 2 must probe exactly two k values (5
    then 12) instead of every k in [2, 12] as the seed did."""
    edges = np.concatenate([
        clique_edges(0, 12), clique_edges(12, 5),
        np.array([[17, 18], [18, 19], [19, 20]]),
    ])
    n = 21
    ce = glib.canonical_edges(edges, n)
    budget = 4 * len(ce)                 # one part: exact lower bounds
    lbres = lower_bounding(n, ce, budget)
    assert lbres.in_gnew.any()
    assert int(lbres.lb[lbres.in_gnew].min()) == 5
    res = bottom_up_decompose(n, ce, budget)
    assert (res.phi == alg2_truss(n, ce)).all()
    assert res.kmax == 12
    stage2_iters = res.scans - lbres.scans
    assert stage2_iters == 2             # seed would have probed 11 k values


def test_truss_decompose_ooc_dispatch(rng):
    n = 40
    ce = glib.canonical_edges(random_graph(rng, n, 0.3), n)
    oracle = alg2_truss(n, ce)
    g = glib.build_graph(n, ce)
    est = estimate_working_set(g)
    assert est > 4 * g.m
    # small budget -> auto routes out of core and returns OocStats
    phi, stats = truss_decompose(n, ce, engine="auto", memory_budget=64,
                                 with_stats=True)
    assert (phi == oracle).all()
    assert isinstance(stats, OocStats) and stats.rounds >= 1
    # a budget below the working set but above 2m must still partition:
    # the NS budget is rescaled from working-set entries to edge cost
    mid = max(2 * len(ce) + 1, est // 2)
    if mid < est:
        phi_mid, stats_mid = truss_decompose(
            n, ce, engine="auto", memory_budget=mid, with_stats=True)
        assert (phi_mid == oracle).all()
        assert stats_mid.parts > 1
    # large budget -> stays in memory
    phi2 = truss_decompose(n, ce, engine="auto", memory_budget=10 * est)
    assert (phi2 == oracle).all()
    # forced engines
    for eng in ("bottom-up", "top-down"):
        phi3 = truss_decompose(n, ce, engine=eng, memory_budget=48)
        assert (phi3 == oracle).all(), eng


def test_pending_peel_result_not_retried_after_error():
    """Regression (ISSUE 4): if finalize raises, the handle must be
    cleared/poisoned — a retry must NOT re-invoke the kernel, whose support
    buffers were donated at dispatch and no longer exist."""
    calls = []

    def finalize():
        calls.append(1)
        raise ValueError("boom")

    handle = PendingPeel(finalize, new_compile=False)
    with pytest.raises(ValueError, match="boom"):
        handle.result()
    # the poisoned handle re-raises WITHOUT running finalize again
    with pytest.raises(RuntimeError, match="cannot be retried") as exc:
        handle.result()
    assert len(calls) == 1
    assert isinstance(exc.value.__cause__, ValueError)


def test_pending_peel_result_cached_on_success():
    calls = []

    def finalize():
        calls.append(1)
        return ("phi", "st")

    handle = PendingPeel(finalize, new_compile=True)
    assert handle.result() == ("phi", "st")
    assert handle.result() is handle.result()
    assert calls == [1]
    assert handle.new_compile and not handle.sharded


def test_batched_equals_perpart_engine(rng):
    n = 36
    ce = glib.canonical_edges(random_graph(rng, n, 0.3), n)
    budget = max(8, len(ce) // 4)
    res_b = bottom_up_decompose(n, ce, budget)
    res_p = bottom_up_decompose(n, ce, budget, engine="perpart")
    assert (res_b.phi == res_p.phi).all()
    sup_b = partitioned_support(n, ce, budget)
    sup_p = partitioned_support(n, ce, budget, engine="perpart")
    assert (sup_b == sup_p).all()


# ---------------------------------------------------------------------------
# spilled-triangle streaming: reload peak bounded below the spilled total
# ---------------------------------------------------------------------------

def test_spilled_triangle_reload_peak_bounded(tmp_path, rng):
    """Satellite-2 regression (DESIGN.md §16): rounds over a disk-spilled
    triangle list must stream it chunk-wise — the recorded reload peak has
    to stay strictly below the largest spilled list, which the old
    load-it-whole path could never satisfy."""
    import warnings

    from repro.core.store import ChunkedDiskStore

    n = 300
    ce = glib.canonical_edges(random_graph(rng, n, 0.05), n)
    oracle = alg2_truss(n, ce)
    with ChunkedDiskStore(str(tmp_path / "store"),
                          chunk_bytes=1 << 10) as store:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            res = bottom_up_decompose(n, ce, budget=80, store=store)
    assert (res.phi == oracle).all()
    s = res.stats
    assert s.tri_rescans_avoided > 0          # spilled rounds actually ran
    assert s.tri_spill_rows > 0
    assert 0 < s.tri_reload_peak_rows < s.tri_spill_rows
