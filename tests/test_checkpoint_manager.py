"""checkpoint.manager: atomicity, integrity checksums, keep-k pruning,
AsyncWriter error surfacing, exotic-dtype roundtrip and elastic restore
validation (DESIGN.md §12 checkpoint contract).

Crash and torn-write cases are driven through the deterministic
``"checkpoint-write"`` fault-injection site, which sits exactly between the
payload write and the manifest/rename commit point — the window the atomic
tmp+rename protocol must make safe.
"""

import os

import ml_dtypes
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.core import faults


def _tree(step):
    rng = np.random.default_rng(step)
    return {"phi": rng.integers(0, 9, 50).astype(np.int64),
            "alive": rng.random(50) < 0.5}


# ----------------------------------------------------------------- atomicity

def test_crash_mid_write_leaves_previous_snapshot_intact(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree(1), metadata={"stage": "lb"})
    plan = faults.FaultPlan([faults.FaultRule(
        site=faults.CHECKPOINT_WRITE, kind="crash")])
    with faults.active(plan):
        with pytest.raises(OSError, match="injected crash"):
            ckpt.save(d, 2, _tree(2))
    # step 2 never committed: only a .tmp remains, and restore still finds
    # the intact step 1
    assert ckpt.all_steps(d) == [1]
    assert os.path.isdir(os.path.join(d, "step_0000000002.tmp"))
    tree, meta = ckpt.restore(d)
    assert meta == {"stage": "lb"}
    np.testing.assert_array_equal(tree["phi"], _tree(1)["phi"])
    # a later save of the same step clears the stale .tmp and commits
    ckpt.save(d, 2, _tree(2))
    assert ckpt.all_steps(d) == [1, 2]
    assert not os.path.exists(os.path.join(d, "step_0000000002.tmp"))


def test_truncated_payload_detected_and_fallback(tmp_path):
    """A snapshot torn AFTER the rename (checksum mismatch) is skipped by
    restore(step=None) with a warning; an explicit step raises."""
    d = str(tmp_path)
    ckpt.save(d, 1, _tree(1), metadata={"idx": 1})
    plan = faults.FaultPlan([faults.FaultRule(
        site=faults.CHECKPOINT_WRITE, kind="truncate")])
    with faults.active(plan):
        ckpt.save(d, 2, _tree(2), metadata={"idx": 2})  # commits corrupted
    assert ckpt.all_steps(d) == [1, 2]
    with pytest.warns(UserWarning, match="skipping corrupt"):
        tree, meta = ckpt.restore(d)
    assert meta == {"idx": 1}                 # fell back to step 1
    with pytest.raises(ckpt.CheckpointCorruptionError, match="sha256"):
        ckpt.restore(d, step=2)


def test_all_snapshots_corrupt_raises_corruption_error(tmp_path):
    d = str(tmp_path)
    plan = faults.FaultPlan([faults.FaultRule(
        site=faults.CHECKPOINT_WRITE, kind="truncate", times=3)])
    with faults.active(plan):
        for s in (1, 2, 3):
            ckpt.save(d, s, _tree(s))
    with pytest.warns(UserWarning), \
            pytest.raises(ckpt.CheckpointCorruptionError, match="no intact"):
        ckpt.restore(d)


def test_missing_dir_raises_file_not_found(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "nope"))


# ------------------------------------------------------------ keep-k pruning

def test_keep_k_prunes_oldest(tmp_path):
    d = str(tmp_path)
    for s in range(1, 6):
        ckpt.save(d, s, _tree(s), keep=2)
    assert ckpt.all_steps(d) == [4, 5]
    assert ckpt.latest_step(d) == 5
    tree, _ = ckpt.restore(d)
    np.testing.assert_array_equal(tree["phi"], _tree(5)["phi"])


def test_keep_nonpositive_keeps_everything(tmp_path):
    d = str(tmp_path)
    for s in range(1, 4):
        ckpt.save(d, s, _tree(s), keep=0)
    assert ckpt.all_steps(d) == [1, 2, 3]


# ------------------------------------------------------ AsyncWriter surfacing

def test_async_writer_surfaces_worker_error_on_next_wait(tmp_path):
    d = str(tmp_path)
    w = ckpt.AsyncWriter(d)
    plan = faults.FaultPlan([faults.FaultRule(
        site=faults.CHECKPOINT_WRITE, kind="crash")])
    with faults.active(plan):
        w.save(1, _tree(1))           # worker thread hits the injected crash
        with pytest.raises(OSError, match="injected crash"):
            w.wait()
    # the error is cleared after surfacing; the writer remains usable
    w.wait()
    w.save(2, _tree(2))
    w.wait()
    assert ckpt.all_steps(d) == [2]


# ------------------------------------------------------------ dtype roundtrip

def test_bf16_roundtrip(tmp_path):
    d = str(tmp_path)
    arr = np.arange(16, dtype=np.float32).astype(ml_dtypes.bfloat16)
    ckpt.save(d, 1, {"w": arr})
    like = {"w": np.zeros(16, dtype=ml_dtypes.bfloat16)}
    tree, _ = ckpt.restore(d, like)
    assert tree["w"].dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(
        tree["w"].astype(np.float32), arr.astype(np.float32))


def test_like_none_returns_plain_named_tree(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, {"sup": np.arange(4), "nested": {"lb": np.ones(2)}})
    tree, _ = ckpt.restore(d)
    assert set(tree) == {"sup", "nested/lb"}


# ------------------------------------------- elastic restore shape validation

def test_restore_wrong_leaf_count_raises_structure_error(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, _tree(1))
    with pytest.raises(ckpt.CheckpointStructureError, match="leaves"):
        ckpt.restore(d, {"phi": np.zeros(50)})


def test_restore_wrong_shape_raises_structure_error(tmp_path):
    """Real exceptions, not bare asserts: these must fire under python -O
    too (the CI matrix runs this file with -O)."""
    d = str(tmp_path)
    ckpt.save(d, 1, _tree(1))
    like = {"phi": np.zeros(49, np.int64), "alive": np.zeros(50, bool)}
    with pytest.raises(ckpt.CheckpointStructureError, match="shape"):
        ckpt.restore(d, like)


def test_structure_error_is_not_swallowed_by_fallback(tmp_path):
    """Only corruption falls back to older snapshots — a structural
    mismatch is a caller bug and must raise even with older steps around."""
    d = str(tmp_path)
    ckpt.save(d, 1, _tree(1))
    ckpt.save(d, 2, _tree(2))
    with pytest.raises(ckpt.CheckpointStructureError):
        ckpt.restore(d, {"phi": np.zeros(50)})
    assert issubclass(ckpt.CheckpointStructureError, ckpt.CheckpointError)
    assert issubclass(ckpt.CheckpointCorruptionError, ckpt.CheckpointError)
