"""I/O-efficient (partitioned) algorithms vs the serial oracle."""

import numpy as np
import pytest

from repro.core import graph as glib
from repro.core import partition as plib
from repro.core.bottom_up import (bottom_up_decompose, lower_bounding,
                                  partitioned_support)
from repro.core.serial import alg2_truss
from repro.core.support import edge_support_np
from repro.core.top_down import top_down_decompose, upper_bounds
from tests.conftest import random_graph


def _graph(rng, n=40, p=0.3):
    return glib.canonical_edges(random_graph(rng, n, p), n), n


@pytest.mark.parametrize("engine", ["batched", "perpart"])
@pytest.mark.parametrize("partitioner", ["sequential", "random", "locality"])
@pytest.mark.parametrize("budget_frac", [0.2, 0.5])
def test_bottom_up_exact(rng, partitioner, budget_frac, engine):
    ce, n = _graph(rng)
    oracle = alg2_truss(n, ce)
    budget = max(8, int(len(ce) * budget_frac))
    res = bottom_up_decompose(n, ce, budget, partitioner=partitioner,
                              engine=engine)
    assert (res.phi == oracle).all()
    assert res.kmax == oracle.max()


def test_lower_bounds_valid(rng):
    ce, n = _graph(rng)
    oracle = alg2_truss(n, ce)
    res = lower_bounding(n, ce, budget=max(8, len(ce) // 3))
    assert (res.lb <= np.maximum(oracle, 2)).all()
    # exact round-1 Phi_2 never mislabels
    assert (oracle[res.phi == 2] == 2).all()


def test_upper_bounds_valid(rng):
    ce, n = _graph(rng)
    oracle = alg2_truss(n, ce)
    sup = edge_support_np(glib.build_graph(n, ce))
    psi = upper_bounds(n, ce, sup)
    assert (psi >= oracle).all()  # Lemma 2


def test_partitioned_support_exact(rng):
    ce, n = _graph(rng)
    sup = edge_support_np(glib.build_graph(n, ce))
    for part in ("sequential", "random"):
        ps = partitioned_support(n, ce, budget=max(8, len(ce) // 4),
                                 partitioner=part)
        assert (ps == sup).all()


def test_top_down_all_classes(rng):
    ce, n = _graph(rng)
    oracle = alg2_truss(n, ce)
    td = top_down_decompose(n, ce)
    assert (td.phi == oracle).all()


def test_top_down_top_t(rng):
    ce, n = _graph(rng)
    oracle = alg2_truss(n, ce)
    td = top_down_decompose(n, ce, t=2)
    assert len(td.classes) <= 2
    for k in td.classes:
        assert set(np.nonzero(td.phi == k)[0]) == \
            set(np.nonzero(oracle == k)[0])
    # undecided edges stay 0 (except Phi_2 which stage 1 decides exactly)
    undecided = td.phi == 0
    assert (oracle[undecided] < min(td.classes, default=3)).all()


def test_top_down_with_budget(rng):
    ce, n = _graph(rng, n=35, p=0.35)
    oracle = alg2_truss(n, ce)
    td = top_down_decompose(n, ce, t=1, budget=max(8, len(ce) // 4))
    k = td.classes[0]
    assert set(np.nonzero(td.phi == k)[0]) == set(np.nonzero(oracle == k)[0])


def test_faithful_proc8_only_overreports(rng):
    """The paper's literal Procedure 8 can only inflate classes (never
    deflate) — the direction predicted by the analysis in DESIGN.md §7."""
    over = under = 0
    for t in range(6):
        ce, n = _graph(rng, n=30, p=0.35)
        oracle = alg2_truss(n, ce)
        tdf = top_down_decompose(n, ce, faithful_proc8=True)
        d = tdf.phi - oracle
        over += int((d > 0).sum())
        under += int((d < 0).sum())
    assert under == 0


def test_budget_respected(rng):
    ce, n = _graph(rng, n=60, p=0.2)
    budget = max(8, len(ce) // 4)
    res = lower_bounding(n, ce, budget)
    # sequential partitioner keeps each NS within ~budget plus one vertex
    assert res.max_part_edges <= 2 * budget + int(
        glib.degrees(n, ce).max())
    # OocStats mirrors the legacy accounting fields
    assert res.stats is not None
    assert res.stats.max_part_edges == res.max_part_edges
    assert res.stats.rounds == res.rounds
    assert res.stats.scans == res.scans
    assert res.stats.parts >= 1
    assert 0.0 <= res.stats.padding_waste < 1.0


def test_sequential_partition_over_budget_warns(rng):
    """A hub vertex whose NS exceeds the budget must be reported, and the
    driver's max_part_edges accounting must record the actual overshoot."""
    from repro.core.partition import PartitionBudgetWarning

    n = 30
    hub = np.stack([np.zeros(n - 1, np.int64), np.arange(1, n)], axis=1)
    ce = glib.canonical_edges(hub, n)         # star: deg(0) = n - 1
    budget = 5
    g = glib.build_graph(n, ce)
    with pytest.warns(PartitionBudgetWarning) as rec:
        parts = plib.sequential_partition(g, budget)
    w = rec[0].message
    assert w.n_over == 1 and w.budget == budget
    assert w.max_cost == n - 1
    # every vertex still lands in exactly one part
    assert sum(len(P) for P in parts) == n
    with pytest.warns(PartitionBudgetWarning):
        res = lower_bounding(n, ce, budget)
    # the hub's NS is the whole star: accounting must reflect the overshoot
    assert res.max_part_edges == n - 1
    assert res.max_part_edges > budget
