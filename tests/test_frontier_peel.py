"""Frontier-compacted peel engine + skew-aware support (DESIGN.md §3-§4)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as glib
from repro.core.peel import (peel_classes, peel_classes_dense, peel_threshold,
                             peel_threshold_dense, truss_decompose)
from repro.core.serial import alg2_truss
from repro.core.support import (edge_support_jax, edge_support_np,
                                list_triangles_np, support_from_triangle_list,
                                triangle_incidence_np, wedge_bucket_plan)
from tests.conftest import random_graph


def _star_plus_clique(hub_deg=2000, q=30):
    """One hub vertex of degree ``hub_deg`` plus a disjoint q-clique — the
    skew shape that blows up a global-max-out-degree wedge tensor."""
    star = np.stack([np.zeros(hub_deg, np.int64),
                     np.arange(1, hub_deg + 1)], 1)
    iu = np.triu_indices(q, 1)
    clique = np.stack(iu, 1) + hub_deg + 1
    n = hub_deg + 1 + q
    return n, glib.canonical_edges(np.concatenate([star, clique]), n)


def _prep(n, ce):
    g = glib.build_graph(n, ce)
    tris = list_triangles_np(g)
    sup = support_from_triangle_list(tris, g.m).astype(np.int32)
    if len(tris) == 0:
        tris = np.full((1, 3), g.m, np.int32)
    return g, tris, sup


class TestSkewAwareSupport:
    def test_star_plus_clique_matches_np(self):
        n, ce = _star_plus_clique()
        g = glib.build_graph(n, ce)
        assert (edge_support_np(g) == np.asarray(edge_support_jax(g))).all()

    def test_bucketed_capacity_bounded(self):
        """The wedge-tensor capacity must not track the hub's degree."""
        n, ce = _star_plus_clique()
        g = glib.build_graph(n, ce)
        plan = wedge_bucket_plan(g)
        cap = sum(b.capacity for b in plan)
        # global-D capacity pays max_out_deg slots for every edge
        assert cap * 3 < g.m * g.max_out_deg
        # each bucket's D covers its own rows: no row longer than D, and D
        # never more than 2x the longest row it serves
        row_len = g.indptr[g.src + 1] - g.indptr[g.src]
        for b in plan:
            lens = row_len[b.eids[: b.n_real]]
            assert lens.max() <= b.D
            assert b.D <= max(2 * int(lens.max()), 1)

    def test_bucketed_equals_global_d(self, rng):
        e = random_graph(rng, 120, 0.1)
        g = glib.build_graph(120, glib.canonical_edges(e, 120))
        a = np.asarray(edge_support_jax(g, bucketed=True))
        b = np.asarray(edge_support_jax(g, bucketed=False))
        assert (a == b).all()

    def test_skew_trussness_exact(self):
        n, ce = _star_plus_clique(hub_deg=300, q=12)
        assert (truss_decompose(n, ce) == alg2_truss(n, ce)).all()


class TestFrontierPeel:
    @pytest.mark.parametrize("trial", range(8))
    def test_matches_serial_random(self, rng, trial):
        for _ in range(trial + 1):
            n = int(rng.integers(8, 70))
            p = rng.uniform(0.05, 0.5)
        e = random_graph(rng, n, p)
        ce = glib.canonical_edges(e, n)
        if len(ce) == 0:
            return
        oracle = alg2_truss(n, ce)
        g, tris, sup = _prep(n, ce)
        for engine in ("frontier", "auto"):
            phi, alive = peel_classes(
                jnp.asarray(sup), jnp.asarray(tris), jnp.ones(g.m, bool),
                engine=engine)
            assert (np.asarray(phi) == oracle).all()
            assert not np.asarray(alive).any()

    def test_matches_dense_engine(self, rng):
        e = random_graph(rng, 60, 0.3)
        ce = glib.canonical_edges(e, 60)
        g, tris, sup = _prep(60, ce)
        args = (jnp.asarray(sup), jnp.asarray(tris), jnp.ones(g.m, bool))
        phi_f, _ = peel_classes(*args, engine="frontier")
        phi_d, _ = peel_classes_dense(*args)
        assert (np.asarray(phi_f) == np.asarray(phi_d)).all()

    def test_max_k_stops_early(self, rng):
        e = random_graph(rng, 50, 0.4)
        ce = glib.canonical_edges(e, 50)
        g, tris, sup = _prep(50, ce)
        oracle = alg2_truss(50, ce)
        args = (jnp.asarray(sup), jnp.asarray(tris), jnp.ones(g.m, bool))
        kcut = int(oracle.max()) - 1
        if kcut < 2:
            return
        phi, alive = peel_classes(*args, max_k=kcut, engine="frontier")
        phi, alive = np.asarray(phi), np.asarray(alive)
        assert (phi[oracle <= kcut] == oracle[oracle <= kcut]).all()
        assert (phi[oracle > kcut] == 0).all()
        assert (alive == (oracle > kcut)).all()

    def test_threshold_matches_dense(self, rng):
        e = random_graph(rng, 50, 0.35)
        ce = glib.canonical_edges(e, 50)
        g, tris, sup = _prep(50, ce)
        removable = jnp.asarray(rng.random(g.m) < 0.7)
        args = (jnp.asarray(sup), jnp.asarray(tris), jnp.ones(g.m, bool),
                removable, jnp.int32(2))
        a_f, s_f, r_f = peel_threshold(*args, engine="frontier")
        a_d, s_d, r_d = peel_threshold_dense(*args)
        assert (np.asarray(a_f) == np.asarray(a_d)).all()
        assert (np.asarray(r_f) == np.asarray(r_d)).all()
        assert (np.asarray(s_f)[np.asarray(a_f)]
                == np.asarray(s_d)[np.asarray(a_d)]).all()

    def test_scatter_work_scales_with_frontier(self, rng):
        """Total gathered incidence slots == 3T for a full decomposition —
        each (edge, triangle) pair is touched exactly once, in the round its
        edge dies; the dense engine would touch rounds * 3T slots."""
        e = random_graph(rng, 90, 0.25)
        ce = glib.canonical_edges(e, 90)
        g, tris, sup = _prep(90, ce)
        T = int((tris < g.m).all(axis=1).sum())
        phi, _, stats = peel_classes(
            jnp.asarray(sup), jnp.asarray(tris), jnp.ones(g.m, bool),
            with_stats=True)
        assert stats.gathered == 3 * T
        assert stats.removed == g.m
        assert stats.rounds > 1
        # the dense engine's scatter work for the same decomposition
        assert stats.gathered < stats.rounds * 3 * T
        assert stats.max_frontier <= g.m

    def test_capacity_overflow_resume(self, rng):
        """Undersized explicit capacities must recover via host doubling."""
        e = random_graph(rng, 40, 0.5)
        ce = glib.canonical_edges(e, 40)
        oracle = alg2_truss(40, ce)
        g, tris, sup = _prep(40, ce)
        phi, _, stats = peel_classes(
            jnp.asarray(sup), jnp.asarray(tris), jnp.ones(g.m, bool),
            cap_f=4, cap_t=1, with_stats=True)
        assert (np.asarray(phi) == oracle).all()
        assert stats.resumes > 0

    def test_incidence_csr_shape(self, rng):
        e = random_graph(rng, 60, 0.3)
        ce = glib.canonical_edges(e, 60)
        g, tris, _ = _prep(60, ce)
        indptr, tids = triangle_incidence_np(tris, g.m)
        T = int((tris < g.m).all(axis=1).sum())
        assert indptr[-1] == 3 * T == len(tids)
        # row e lists exactly the triangles containing e
        for eid in rng.integers(0, g.m, 5):
            row = tids[indptr[eid]:indptr[eid + 1]]
            assert set(row) == {t for t in range(len(tris))
                                if eid in tris[t]}
