"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as glib
from repro.core.support import edge_support_np
from tests.conftest import random_graph


class TestTriangleCount:
    @pytest.mark.parametrize("n,p,block", [
        (64, 0.3, 32), (96, 0.2, 48), (128, 0.15, 64), (100, 0.25, 64),
    ])
    def test_vs_ref(self, rng, n, p, block):
        from repro.kernels.triangle_count import ref
        from repro.kernels.triangle_count.ops import (adjacency_from_edges,
                                                      dense_support)
        ce = glib.canonical_edges(random_graph(rng, n, p), n)
        A = jnp.asarray(adjacency_from_edges(n, ce))
        S = dense_support(A, block=block, interpret=True)
        np.testing.assert_allclose(np.asarray(S), np.asarray(ref.support_dense(A)))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, rng, dtype):
        from repro.kernels.triangle_count.ops import (adjacency_from_edges,
                                                      dense_support)
        ce = glib.canonical_edges(random_graph(rng, 64, 0.3), 64)
        A = jnp.asarray(adjacency_from_edges(64, ce)).astype(dtype)
        S = dense_support(A, block=32, interpret=True)
        g = glib.build_graph(64, ce)
        sup = edge_support_np(g)
        np.testing.assert_allclose(
            np.asarray(S)[ce[:, 0], ce[:, 1]], sup)

    def test_matches_sparse_path(self, rng):
        from repro.kernels.triangle_count.ops import dense_edge_support
        ce = glib.canonical_edges(random_graph(rng, 90, 0.25), 90)
        sup_dense = dense_edge_support(90, ce, block=64, interpret=True)
        sup_sparse = edge_support_np(glib.build_graph(90, ce))
        assert (sup_dense == sup_sparse).all()

    def test_rectangular_tiles(self, rng):
        from repro.kernels.triangle_count.ops import (adjacency_from_edges,
                                                      dense_support)
        ce = glib.canonical_edges(random_graph(rng, 128, 0.2), 128)
        A = jnp.asarray(adjacency_from_edges(128, ce))
        S_ref = dense_support(A, block=128, interpret=True, use_kernel=False)
        for block in [(64, 64, 128), (128, 64, 64), (64, 128, 32)]:
            S = dense_support(A, block=block, interpret=True)
            np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref))

    def test_bf16_tiles_f32_accum(self, rng):
        from repro.kernels.triangle_count.ops import dense_edge_support
        ce = glib.canonical_edges(random_graph(rng, 96, 0.3), 96)
        sup16 = dense_edge_support(96, ce, block=32, interpret=True,
                                   dtype=jnp.bfloat16)
        sup_sparse = edge_support_np(glib.build_graph(96, ce))
        assert (sup16 == sup_sparse).all()

    def test_vmem_budget_and_feasible_tiles(self):
        from repro.kernels.triangle_count.kernel import (VMEM_BUDGET_BYTES,
                                                         feasible_tiles,
                                                         kernel_vmem_bytes)
        # bf16 tiles are half the input footprint of f32
        assert kernel_vmem_bytes(256, 256, 256, jnp.bfloat16) < \
            kernel_vmem_bytes(256, 256, 256, jnp.float32)
        for tiles in feasible_tiles(512, jnp.float32):
            bm, bn, bk = tiles
            assert 512 % bm == 0 and 512 % bn == 0 and 512 % bk == 0
            assert kernel_vmem_bytes(bm, bn, bk) <= VMEM_BUDGET_BYTES

    def test_autotune_smoke(self, rng):
        from repro.kernels.triangle_count.kernel import autotune_tiles
        from repro.kernels.triangle_count.ops import (adjacency_from_edges,
                                                      dense_support)
        tiles = autotune_tiles(64, interpret=True, repeats=1)
        assert 64 % tiles[0] == 0
        # cached on second call
        assert autotune_tiles(64, interpret=True, repeats=1) == tiles
        ce = glib.canonical_edges(random_graph(rng, 64, 0.3), 64)
        A = jnp.asarray(adjacency_from_edges(64, ce))
        S = dense_support(A, block="auto", interpret=True)
        S_ref = dense_support(A, block=64, interpret=True, use_kernel=False)
        np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref))


class TestFlashAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,S,D,win", [
        (2, 4, 2, 256, 64, None),
        (1, 8, 8, 128, 128, None),
        (2, 4, 1, 256, 64, 96),
        (1, 2, 2, 512, 32, 128),
    ])
    def test_vs_ref(self, rng, B, Hq, Hkv, S, D, win):
        from repro.kernels.flash_attention import ref
        from repro.kernels.flash_attention.ops import flash_attention
        q = jnp.asarray(rng.standard_normal((B, Hq, S, D)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)).astype(np.float32))
        o = flash_attention(q, k, v, window=win, bq=64, bk=64, interpret=True)
        o_ref = ref.mha_reference(q, k, v, window=win)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16(self, rng):
        from repro.kernels.flash_attention import ref
        from repro.kernels.flash_attention.ops import flash_attention
        q = jnp.asarray(rng.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((1, 2, 128, 64))).astype(jnp.bfloat16)
        o = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
        o_ref = ref.mha_reference(q, k, v)
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(o_ref, np.float32),
            rtol=0.05, atol=0.05)

    def test_chunked_jnp_paths(self, rng):
        from repro.kernels.flash_attention import ref
        from repro.models.attention import banded_attention, chunked_attention
        q = jnp.asarray(rng.standard_normal((2, 128, 4, 32)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((2, 128, 2, 32)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((2, 128, 2, 32)).astype(np.float32))
        t = lambda x: x.transpose(0, 2, 1, 3)
        o_ref = t(ref.mha_reference(t(q), t(k), t(v), causal=True))
        o_c = chunked_attention(q, k, v, q_chunk=32, k_chunk=64)
        np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_ref),
                                   rtol=2e-5, atol=2e-5)
        o_refw = t(ref.mha_reference(t(q), t(k), t(v), causal=True, window=48))
        o_b = banded_attention(q, k, v, window=48, q_chunk=32)
        np.testing.assert_allclose(np.asarray(o_b), np.asarray(o_refw),
                                   rtol=2e-5, atol=2e-5)


class TestEmbeddingBag:
    @pytest.mark.parametrize("V,D,B,L,mode", [
        (64, 18, 8, 10, "mean"), (128, 128, 16, 4, "sum"),
        (32, 100, 4, 7, "mean"), (256, 64, 2, 100, "sum"),
    ])
    def test_vs_ref(self, rng, V, D, B, L, mode):
        from repro.kernels.embedding_bag import ref
        from repro.kernels.embedding_bag.ops import embedding_bag
        tbl = jnp.asarray(rng.standard_normal((V, D)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, V, (B, L)).astype(np.int32))
        o = embedding_bag(tbl, idx, mode=mode, interpret=True)
        o_ref = ref.embedding_bag(tbl, idx, mode=mode)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=1e-5, atol=1e-5)

    def test_bf16(self, rng):
        from repro.kernels.embedding_bag import ref
        from repro.kernels.embedding_bag.ops import embedding_bag
        tbl = jnp.asarray(rng.standard_normal((64, 32))).astype(jnp.bfloat16)
        idx = jnp.asarray(rng.integers(0, 64, (4, 8)).astype(np.int32))
        o = embedding_bag(tbl, idx, mode="sum", interpret=True)
        o_ref = ref.embedding_bag(tbl, idx, mode="sum")
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(o_ref, np.float32),
                                   rtol=0.05, atol=0.05)
