"""Regression tests for the partitioner-dispatch bugfixes (ISSUE 3):

* ``truss_decompose(memory_budget=0)`` silently fell back to the ``m // 8``
  default instead of being rejected;
* ``random_partition`` hashed vertices into bins ignoring per-vertex NS
  cost, so a bin's summed cost could exceed the budget by large factors
  with no warning;
* ``_resolve_partitioner`` wrapped user callables as 2-arg, silently
  discarding the round index, so custom partitioners could never vary per
  round the way the built-in "random" reseed does.
"""

import numpy as np
import pytest

from repro.core import graph as glib
from repro.core.bottom_up import (_resolve_partitioner, bottom_up_decompose,
                                  lower_bounding)
from repro.core.partition import (PartitionBudgetWarning, random_partition,
                                  sequential_partition)
from repro.core.peel import truss_decompose
from repro.core.serial import alg2_truss
from tests.conftest import er_graph, star_hub_graph


# ---------------------------------------------------------------------------
# truss_decompose: memory_budget=0 must be rejected, not defaulted
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["auto", "bottom-up", "top-down"])
@pytest.mark.parametrize("bad", [0, -1, -100])
def test_nonpositive_memory_budget_rejected(rng, engine, bad):
    n, ce = er_graph(rng)
    with pytest.raises(ValueError, match="memory_budget must be a positive"):
        truss_decompose(n, ce, engine=engine, memory_budget=bad)


def test_memory_budget_none_still_defaults(rng):
    """Only *explicit* non-positive budgets are errors; None keeps the
    m // 8 default for the forced out-of-core engines."""
    n, ce = er_graph(rng)
    oracle = alg2_truss(n, ce)
    for engine in ("bottom-up", "top-down"):
        phi = truss_decompose(n, ce, engine=engine, memory_budget=None)
        assert (phi == oracle).all()


def test_explicit_budget_honored(rng):
    """An explicit budget must steer the partitioning: a tiny working set
    forces strictly deeper partitioning than a roomy one."""
    from repro.core.peel import estimate_working_set

    n, ce = er_graph(rng, n=40, p=0.3)
    oracle = alg2_truss(n, ce)
    est = estimate_working_set(glib.build_graph(n, ce))
    phi_small, st_small = truss_decompose(
        n, ce, engine="bottom-up", memory_budget=64, with_stats=True)
    phi_large, st_large = truss_decompose(
        n, ce, engine="bottom-up", memory_budget=est // 2, with_stats=True)
    assert (phi_small == oracle).all() and (phi_large == oracle).all()
    assert st_small.parts > st_large.parts


# ---------------------------------------------------------------------------
# random_partition: cost-aware bins
# ---------------------------------------------------------------------------



def test_random_partition_respects_budget():
    """Pre-fix, hashing ~64 vertices into a handful of bins exceeded the
    budget by several x with no warning; post-fix every emitted part's
    summed NS cost fits (no single vertex is over budget here, so no
    over-budget singleton is allowed either)."""
    n = 64
    _, ce = star_hub_graph(n)
    g = glib.build_graph(n, ce)
    cost = g.deg.astype(np.int64)
    budget = int(cost.max()) + 4          # every vertex fits on its own
    for seed in range(5):
        parts = random_partition(g, budget, seed=seed)
        # a partition: every active vertex exactly once
        allv = np.concatenate(parts)
        assert len(allv) == len(np.unique(allv))
        assert set(allv.tolist()) == set(np.nonzero(g.deg > 0)[0].tolist())
        for P in parts:
            assert int(cost[P].sum()) <= budget, (seed, P)


def test_random_partition_warns_on_over_budget_vertex():
    """A single vertex above the budget must warn — consistently with
    sequential_partition — and still be emitted as a singleton part."""
    n, ce = star_hub_graph(30, 29)
    budget = 5
    g = glib.build_graph(n, ce)
    with pytest.warns(PartitionBudgetWarning) as rec:
        parts = random_partition(g, budget, seed=0)
    assert rec[0].message.max_cost == n - 1
    cost = g.deg.astype(np.int64)
    for P in parts:
        assert int(cost[P].sum()) <= budget or len(P) == 1
    # the decomposition built on top stays exact
    oracle = alg2_truss(n, ce)
    with pytest.warns(PartitionBudgetWarning):
        res = bottom_up_decompose(n, ce, budget, partitioner="random")
    assert (res.phi == oracle).all()


def test_random_partition_deterministic_per_seed():
    _, ce = star_hub_graph()
    g = glib.build_graph(64, ce)
    a = random_partition(g, budget=30, seed=3)
    b = random_partition(g, budget=30, seed=3)
    assert len(a) == len(b)
    for pa, pb in zip(a, b):
        assert (pa == pb).all()


# ---------------------------------------------------------------------------
# _resolve_partitioner: 3-arg user callables get the round index
# ---------------------------------------------------------------------------

def test_custom_partitioner_receives_round_index(rng):
    n, ce = er_graph(rng, n=30)
    seen: list = []

    def by_round(g, budget, round_idx):
        seen.append(round_idx)
        return sequential_partition(g, budget)

    res = lower_bounding(n, ce, budget=max(8, len(ce) // 4),
                         partitioner=by_round)
    assert seen == list(range(1, res.rounds + 1))


def test_custom_partitioner_two_arg_still_works(rng):
    n, ce = er_graph(rng, n=30)
    calls: list = []

    def plain(g, budget):
        calls.append(budget)
        return sequential_partition(g, budget)

    oracle = alg2_truss(n, ce)
    res = bottom_up_decompose(n, ce, max(8, len(ce) // 4),
                              partitioner=plain)
    assert (res.phi == oracle).all()
    assert len(calls) >= 1


def test_defaulted_third_param_keeps_two_arg_call(rng):
    """A defaulted third parameter is a config kwarg, not a round slot:
    the legacy 2-arg call must be kept so the round index never hijacks
    it."""
    n, ce = er_graph(rng, n=24)
    seen: list = []

    def with_config(g, budget, strict=True):
        seen.append(strict)
        return sequential_partition(g, budget)

    lower_bounding(n, ce, budget=max(8, len(ce) // 3),
                   partitioner=with_config)
    assert all(s is True for s in seen)


def test_resolve_partitioner_varargs_and_builtin():
    recorded: list = []

    def star(*args):
        recorded.append(args)
        return []

    fn = _resolve_partitioner(star)
    fn("g", 7, 3)
    assert recorded == [("g", 7, 3)]
    # the built-in "random" reseed path still threads the round through
    g = glib.build_graph(6, np.array([[0, 1], [1, 2], [0, 2]]))
    fn_r = _resolve_partitioner("random")
    p1 = fn_r(g, 100, 1)
    p2 = fn_r(g, 100, 1)
    assert all((a == b).all() for a, b in zip(p1, p2))


# ---------------------------------------------------------------------------
# partitioner_seed plumbing (ISSUE 4): random_partition's seed= was
# unreachable through the drivers
# ---------------------------------------------------------------------------

def test_resolve_partitioner_seed_reaches_random_partition():
    """_resolve_partitioner("random", seed=s) must call
    random_partition(g, b, seed=s + round); the default 0 preserves the
    historical seed=round schedule."""
    _, ce = star_hub_graph()
    g = glib.build_graph(64, ce)
    fn = _resolve_partitioner("random", seed=5)
    got = fn(g, 30, 2)
    ref = random_partition(g, 30, seed=7)
    assert len(got) == len(ref)
    assert all((a == b).all() for a, b in zip(got, ref))
    fn0 = _resolve_partitioner("random")
    legacy = random_partition(g, 30, seed=2)
    got0 = fn0(g, 30, 2)
    assert all((a == b).all() for a, b in zip(got0, legacy))


def test_partitioner_seed_threaded_through_drivers(rng, monkeypatch):
    """Both drivers and the unified dispatch must hand partitioner_seed=
    down to random_partition (pre-fix the kwarg did not exist and a caller
    could never steer the reseed)."""
    from repro.core import partition as plib
    from repro.core.bottom_up import partitioned_support
    from repro.core.top_down import top_down_decompose

    n, ce = er_graph(rng, n=28)
    seen: list = []

    def recording(g, budget, seed=0):
        seen.append(seed)
        return random_partition(g, budget, seed=seed)

    monkeypatch.setitem(plib.PARTITIONERS, "random", recording)
    oracle = alg2_truss(n, ce)
    budget = max(8, len(ce) // 4)

    seen.clear()
    res = bottom_up_decompose(n, ce, budget, partitioner="random",
                              partitioner_seed=100)
    assert (res.phi == oracle).all()
    assert seen and all(s > 100 for s in seen)     # seed + round, round >= 1

    seen.clear()
    td = top_down_decompose(n, ce, budget=budget, partitioner="random",
                            partitioner_seed=200)
    assert (td.phi == oracle).all()
    assert seen and all(s > 200 for s in seen)

    seen.clear()
    partitioned_support(n, ce, budget, partitioner="random",
                        partitioner_seed=300)
    assert seen and all(s > 300 for s in seen)

    seen.clear()
    phi = truss_decompose(n, ce, engine="bottom-up", memory_budget=64,
                          partitioner="random", partitioner_seed=400)
    assert (phi == oracle).all()
    assert seen and all(s > 400 for s in seen)


def test_partitioner_seed_changes_partition_identical_phi(rng, monkeypatch):
    """Different seeds must actually change the randomized partition (the
    kwarg is live, not silently ignored), while Lemma 1 keeps phi
    identical."""
    from repro.core import partition as plib

    n, ce = er_graph(rng, n=32, p=0.3)
    oracle = alg2_truss(n, ce)
    budget = max(8, len(ce) // 4)
    captured: list = []

    def recording(g, b, seed=0):
        parts = random_partition(g, b, seed=seed)
        captured.append([p.tolist() for p in parts])
        return parts

    monkeypatch.setitem(plib.PARTITIONERS, "random", recording)
    r_a = bottom_up_decompose(n, ce, budget, partitioner="random",
                              partitioner_seed=0)
    parts_a = list(captured)
    captured.clear()
    r_b = bottom_up_decompose(n, ce, budget, partitioner="random",
                              partitioner_seed=12345)
    assert captured != parts_a         # the seed steered the partitions
    assert (r_a.phi == oracle).all()
    assert (r_b.phi == oracle).all()
