"""Round journaling + resume (DESIGN.md §12): a decomposition interrupted
after an arbitrary completed round and resumed from its checkpoint
directory must produce phi bit-identical to an uninterrupted run.

In-process interruptions inject a non-retryable fault at a chosen site and
re-invoke with ``resume=True``; the subprocess smoke goes further and
SIGKILLs the worker mid-run (no atexit, no finally blocks) before resuming
in this process — the crash case the atomic tmp+rename snapshot contract
exists for.
"""

import contextlib
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.core import faults
from repro.core import graph as glib
from repro.core.bottom_up import bottom_up_decompose
from repro.core.partition import PartitionBudgetWarning
from repro.core.peel import truss_decompose
from repro.core.serial import alg2_truss
from repro.core.top_down import top_down_decompose
from tests.conftest import conformance_corpus

CORPUS = conformance_corpus()
_ORACLE = {name: alg2_truss(n, ce) for name, n, ce in CORPUS}
BUDGET = 64


@contextlib.contextmanager
def _quiet():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", PartitionBudgetWarning)
        yield


def _interrupt(fn, plan, **kwargs):
    """Run ``fn`` under ``plan``; return whether it was actually cut short
    (small corpus graphs may finish before the rule's nth match)."""
    with _quiet(), faults.active(plan):
        try:
            fn(**kwargs)
        except (faults.InjectedFault, OSError):
            return True
    return False


@pytest.mark.parametrize("name,n,ce", CORPUS, ids=[c[0] for c in CORPUS])
@pytest.mark.parametrize("site,where,nth", [
    (faults.PARTITIONER, {"stage": 1}, 3),      # between stage-1 rounds
    (faults.DISPATCH, {"stage": 2}, 1),         # first stage-2 level
    (faults.DISPATCH, {"stage": 2}, 3),         # mid stage-2
], ids=["s1-round3", "s2-first", "s2-mid"])
def test_bottom_up_interrupt_resume(tmp_path, name, n, ce, site, where, nth):
    from repro.checkpoint import manager as ckpt
    d = str(tmp_path / "ckpt")
    plan = faults.FaultPlan([faults.FaultRule(site=site, kind="error",
                                              where=dict(where), nth=nth)])
    _interrupt(bottom_up_decompose, plan, n=n, edges=ce, budget=BUDGET,
               checkpoint_dir=d, checkpoint_every=1)
    had_snap = ckpt.latest_step(d) is not None
    with _quiet():
        res = bottom_up_decompose(n, ce, budget=BUDGET, checkpoint_dir=d,
                                  resume=True)
    assert (res.phi == _ORACLE[name]).all(), name
    if plan.log and had_snap:         # interrupted after a journaled round
        assert res.stats.resumed_round >= 0, name


@pytest.mark.parametrize("name,n,ce", CORPUS, ids=[c[0] for c in CORPUS])
@pytest.mark.parametrize("site,where,nth", [
    (faults.PARTITIONER, {"stage": 1}, 2),      # between support rounds
    (faults.DISPATCH, {"stage": "td"}, 2),      # second class level
], ids=["sup-round2", "td-level2"])
def test_top_down_interrupt_resume(tmp_path, name, n, ce, site, where, nth):
    d = str(tmp_path / "ckpt")
    plan = faults.FaultPlan([faults.FaultRule(site=site, kind="error",
                                              where=dict(where), nth=nth)])
    _interrupt(top_down_decompose, plan, n=n, edges=ce, budget=BUDGET,
               checkpoint_dir=d, checkpoint_every=1)
    with _quiet():
        res = top_down_decompose(n, ce, budget=BUDGET, checkpoint_dir=d,
                                 resume=True)
    assert (res.phi == _ORACLE[name]).all(), name


def test_resume_empty_dir_is_fresh_run(tmp_path):
    name, n, ce = CORPUS[0]
    with _quiet():
        res = bottom_up_decompose(n, ce, budget=BUDGET,
                                  checkpoint_dir=str(tmp_path / "none"),
                                  resume=True)
    assert (res.phi == _ORACLE[name]).all()
    assert res.stats.resumed_round == -1


def test_resume_checkpoints_continue_sequence(tmp_path):
    """A resumed run keeps journaling: the step counter continues past the
    pre-crash snapshots instead of overwriting them."""
    from repro.checkpoint import manager as ckpt
    name, n, ce = CORPUS[0]
    d = str(tmp_path / "ckpt")
    plan = faults.FaultPlan([faults.FaultRule(
        site=faults.DISPATCH, kind="error", where={"stage": 2}, nth=1)])
    _interrupt(bottom_up_decompose, plan, n=n, edges=ce, budget=BUDGET,
               checkpoint_dir=d, checkpoint_every=1)
    before = ckpt.latest_step(d)
    with _quiet():
        bottom_up_decompose(n, ce, budget=BUDGET, checkpoint_dir=d,
                            resume=True)
    assert before is not None and ckpt.latest_step(d) > before


def test_run_key_mismatch_rejected(tmp_path):
    """Resuming a journal recorded for a different graph/config raises —
    silently continuing someone else's snapshot is never acceptable."""
    name, n, ce = CORPUS[0]
    d = str(tmp_path / "ckpt")
    with _quiet():
        bottom_up_decompose(n, ce, budget=BUDGET, checkpoint_dir=d,
                            checkpoint_every=1)
    other = glib.canonical_edges(ce[:-2], n)        # different edge list
    with _quiet(), pytest.raises(ValueError, match="run_key|different run"):
        bottom_up_decompose(n, other, budget=BUDGET, checkpoint_dir=d,
                            resume=True)
    with _quiet(), pytest.raises(ValueError, match="run_key|different run"):
        bottom_up_decompose(n, ce, budget=BUDGET * 2, checkpoint_dir=d,
                            resume=True)


def test_truss_decompose_threads_checkpointing(tmp_path):
    name, n, ce = CORPUS[0]
    d = str(tmp_path / "ckpt")
    with _quiet():
        phi0, _ = truss_decompose(n, ce, engine="bottom-up",
                                  memory_budget=BUDGET, with_stats=True)
        phi1, stats = truss_decompose(n, ce, engine="bottom-up",
                                      memory_budget=BUDGET, with_stats=True,
                                      checkpoint_dir=d, checkpoint_every=1)
        phi2, stats2 = truss_decompose(n, ce, engine="bottom-up",
                                       memory_budget=BUDGET, with_stats=True,
                                       checkpoint_dir=d, resume=True)
    assert (phi0 == phi1).all() and (phi0 == phi2).all()
    assert stats.checkpoints > 0
    assert stats2.resumed_round >= 0


def test_truss_decompose_in_memory_warns_and_ignores(tmp_path):
    name, n, ce = CORPUS[0]
    with pytest.warns(UserWarning, match="in-memory"):
        phi = truss_decompose(n, ce, engine="dense",
                              checkpoint_dir=str(tmp_path))
    assert (phi == _ORACLE[name]).all()


_KILL_DRIVER = r"""
import sys
import numpy as np
from repro.core import faults
from repro.core.bottom_up import bottom_up_decompose
from tests.conftest import conformance_corpus

ckpt_dir, kill_round = sys.argv[1], int(sys.argv[2])
name, n, ce = conformance_corpus()[0]
if kill_round >= 0:
    faults.install(faults.FaultPlan([faults.FaultRule(
        site=faults.PARTITIONER, kind="kill", where={"stage": 1},
        nth=kill_round)]))
import warnings
warnings.simplefilter("ignore")
res = bottom_up_decompose(n, ce, budget=64, checkpoint_dir=ckpt_dir,
                          checkpoint_every=1, resume=True)
np.save(ckpt_dir + "/phi.npy", res.phi)
"""


def _subprocess_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         os.path.join(os.path.dirname(__file__), ".."),
         env.get("PYTHONPATH", "")])
    return env


def test_sigkill_crash_and_resume(tmp_path):
    """The real thing: SIGKILL the worker between stage-1 rounds, resume in
    a second process, phi must match the oracle bit-for-bit."""
    d = str(tmp_path / "ckpt")
    os.makedirs(d)
    env = _subprocess_env()
    kill = subprocess.run([sys.executable, "-c", _KILL_DRIVER, d, "2"],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert kill.returncode == -9, (kill.returncode, kill.stderr[-2000:])
    assert not os.path.exists(d + "/phi.npy")   # it really died mid-run
    resume = subprocess.run([sys.executable, "-c", _KILL_DRIVER, d, "-1"],
                            env=env, capture_output=True, text=True,
                            timeout=600)
    assert resume.returncode == 0, resume.stderr[-2000:]
    phi = np.load(d + "/phi.npy")
    name, n, ce = CORPUS[0]
    assert (phi == _ORACLE[name]).all()


def test_zone_state_helpers_round_trip():
    """The locality partitioner's one float of cross-round feedback
    snapshots and restores; stateless partitioners snapshot as None and
    ignore restores (no attribute is ever attached to them)."""
    from repro.core.bottom_up import (_resolve_partitioner,
                                      _restore_zone_state, _zone_state)
    loc = _resolve_partitioner("locality")
    assert _zone_state(loc) is None          # cold start
    loc.prev_locality = 0.75
    assert _zone_state(loc) == 0.75
    loc2 = _resolve_partitioner("locality")
    _restore_zone_state(loc2, _zone_state(loc))
    assert loc2.prev_locality == 0.75
    seq = _resolve_partitioner("sequential")
    assert _zone_state(seq) is None
    _restore_zone_state(seq, 0.5)            # must not attach state
    assert _zone_state(seq) is None


def test_locality_zone_state_journaled_and_restored(tmp_path):
    """Satellite-1 regression: a stage-1 snapshot of a locality run must
    carry the adaptive partitioner's zone state so the resumed run
    re-plans its remaining rounds from the journaled feedback instead of
    the cold default."""
    from repro.core.bottom_up import (RoundJournal, _mesh_devices,
                                      _resolve_partitioner,
                                      _restore_zone_state, _run_key)
    name, n, ce = CORPUS[3]                  # clustered: locality's regime
    budget = 16
    d = str(tmp_path / "ckpt")
    plan = faults.FaultPlan([faults.FaultRule(
        site=faults.PARTITIONER, kind="error", where={"stage": 1}, nth=3)])
    cut = _interrupt(bottom_up_decompose, plan, n=n, edges=ce, budget=budget,
                     partitioner="locality", checkpoint_dir=d,
                     checkpoint_every=1)
    assert cut
    key = _run_key("bottom_up", n, ce, budget, "locality", 0,
                   devices=_mesh_devices(None, "data"))
    tree, meta = RoundJournal(d, key, every=1).load_latest()
    assert meta["stage"] == "lb"
    zs = meta.get("zone_state")
    assert zs is not None and 0.0 <= float(zs) <= 1.0
    part_fn = _resolve_partitioner("locality")
    _restore_zone_state(part_fn, zs)
    assert part_fn.prev_locality == float(zs)
    with _quiet():
        res = bottom_up_decompose(n, ce, budget=budget,
                                  partitioner="locality", checkpoint_dir=d,
                                  resume=True)
    assert (res.phi == _ORACLE[name]).all()
    assert res.stats.resumed_round >= 0


_SPILL_KILL_DRIVER = r"""
import sys
import numpy as np
from repro.core import faults
from repro.core.bottom_up import bottom_up_decompose
from repro.core.store import ChunkedDiskStore
from tests.conftest import conformance_corpus

ckpt_dir, store_dir, nth = sys.argv[1], sys.argv[2], int(sys.argv[3])
name, n, ce = conformance_corpus()[0]
if nth >= 0:
    faults.install(faults.FaultPlan([faults.FaultRule(
        site=faults.CHUNK_WRITE, kind="kill", nth=nth)]))
import warnings
warnings.simplefilter("ignore")
with ChunkedDiskStore(store_dir, chunk_bytes=1 << 10) as store:
    res = bottom_up_decompose(n, ce, budget=64, checkpoint_dir=ckpt_dir,
                              checkpoint_every=1, resume=True, store=store)
np.save(ckpt_dir + "/phi.npy", res.phi)
"""


def test_sigkill_mid_chunk_spill_and_resume(tmp_path):
    """SIGKILL delivered INSIDE a chunk spill (the chunk-write fault site,
    DESIGN.md §15): the journaled snapshot must survive the torn store
    state, the restarted store must sweep the dead process's spill files,
    and the resumed run must reproduce the oracle bit-for-bit."""
    d = str(tmp_path / "ckpt")
    sd = str(tmp_path / "store")
    os.makedirs(d)
    env = _subprocess_env()
    # write 25 of ~40 chunk spills, then die: mid-run, past several
    # journaled rounds, in the middle of one graph's spill
    kill = subprocess.run([sys.executable, "-c", _SPILL_KILL_DRIVER,
                           d, sd, "25"], env=env, capture_output=True,
                          text=True, timeout=600)
    assert kill.returncode == -9, (kill.returncode, kill.stderr[-2000:])
    assert not os.path.exists(d + "/phi.npy")
    leftovers = [f for f in os.listdir(sd) if f.endswith(".bin")]
    assert leftovers                      # the dead run's torn spill state
    resume = subprocess.run([sys.executable, "-c", _SPILL_KILL_DRIVER,
                             d, sd, "-1"], env=env, capture_output=True,
                            text=True, timeout=600)
    assert resume.returncode == 0, resume.stderr[-2000:]
    phi = np.load(d + "/phi.npy")
    name, n, ce = CORPUS[0]
    assert (phi == _ORACLE[name]).all()
