"""Cross-engine conformance matrix (DESIGN.md §11).

One parametrized sweep pins every engine × partitioner × mesh
configuration to the ``serial.alg2_truss`` oracle on the shared
``conformance_corpus`` graphs, and asserts the ``OocStats`` invariants
that every out-of-core run must satisfy.  The in-memory engines (dense /
frontier) ignore partitioner and mesh, so only their canonical
configuration runs; the out-of-core engines sweep the full cross product.

The mesh configurations build over whatever devices the ambient process
has — 1 locally, 8 in the CI step that sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax init —
the shard_map code path is identical either way (DESIGN.md §10).
"""

import warnings

import jax
import pytest

from repro.core.bottom_up import OocStats, bottom_up_decompose
from repro.core.partition import PartitionBudgetWarning
from repro.core.peel import truss_decompose
from repro.core.serial import alg2_truss, verify_truss
from repro.core.top_down import top_down_decompose
from tests.conftest import conformance_corpus

CORPUS = conformance_corpus()
_ORACLE = {name: alg2_truss(n, ce) for name, n, ce in CORPUS}

ENGINES = ("dense", "frontier", "bottom-up", "top-down")
PARTITIONERS = ("sequential", "random", "locality")
MESHES = ("none", "devices", "devices2d")


def _mesh(kind):
    """(mesh, mesh_axis) for a matrix row.  "devices2d" factors the same
    devices into a (lane, tri) grid (DESIGN.md §13) — (2, 4) under the CI
    step's 8 forced host devices, a degenerate (1, 1) locally."""
    if kind == "none":
        return None, "data"
    d = len(jax.devices())
    if kind == "devices":
        return jax.make_mesh((d,), ("data",)), "data"
    d0 = 1
    while (d0 * 2) ** 2 <= d and d % (d0 * 2) == 0:
        d0 *= 2
    return (jax.make_mesh((d0, d // d0), ("data", "tri")),
            ("data", "tri"))


def _check_ooc_stats(stats: OocStats, mesh, tag):
    """The invariants every out-of-core run's counters must satisfy."""
    assert stats is not None, tag
    assert stats.rounds >= 1, tag
    assert stats.parts >= 1, tag
    assert stats.scans >= stats.parts, tag
    assert 0 <= stats.tri_assigned <= stats.tri_total, tag
    assert 0.0 <= stats.tri_locality <= 1.0, tag
    assert stats.tri_est >= 0, tag
    assert stats.tri_est_error >= 0.0, tag
    assert stats.real_edges <= stats.padded_slots, tag
    assert 0.0 <= stats.padding_waste < 1.0, tag
    assert stats.ns_sweeps <= stats.rounds, tag
    assert stats.tri_routes == stats.ns_sweeps, tag
    assert 0 <= stats.stage2_overlapped <= stats.scans, tag
    assert stats.overlapped <= stats.rounds, tag
    expected_dev = 1 if mesh is None else len(jax.devices())
    assert stats.devices == expected_dev, tag
    if mesh is None:
        assert stats.sharded_rounds == 0, tag


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("partitioner", PARTITIONERS)
@pytest.mark.parametrize("mesh_kind", MESHES)
def test_conformance_matrix(engine, partitioner, mesh_kind):
    in_memory = engine in ("dense", "frontier")
    if in_memory and (partitioner != "sequential" or mesh_kind != "none"):
        pytest.skip("in-memory engines ignore partitioner and mesh")
    mesh, axes = _mesh(mesh_kind)
    for name, n, ce in CORPUS:
        oracle = _ORACLE[name]
        tag = (engine, partitioner, mesh_kind, name)
        kwargs = dict(engine=engine, with_stats=True)
        if not in_memory:
            kwargs.update(memory_budget=max(48, len(ce)),
                          partitioner=partitioner, mesh=mesh,
                          mesh_axes=axes if mesh_kind == "devices2d"
                          else None)
        with warnings.catch_warnings():
            # the star-hub graph legitimately warns at deep budgets
            warnings.simplefilter("ignore", PartitionBudgetWarning)
            phi, stats = truss_decompose(n, ce, **kwargs)
        assert (phi == oracle).all(), tag
        assert verify_truss(n, ce, phi), tag
        if not in_memory:
            _check_ooc_stats(stats, mesh, tag)
            if mesh is not None and stats.tri_total:
                # triangle-free work short-circuits on host (DESIGN.md
                # §10); anything else must have routed through shard_map
                assert stats.sharded_rounds > 0, tag


@pytest.mark.parametrize("partitioner", PARTITIONERS)
@pytest.mark.parametrize("mesh_kind", MESHES)
def test_conformance_drivers_direct(partitioner, mesh_kind):
    """The driver entry points (not just the unified dispatch) on a deep
    budget: phi equality plus the cross-driver stats contract."""
    mesh, axes = _mesh(mesh_kind)
    for name, n, ce in CORPUS:
        oracle = _ORACLE[name]
        tag = (partitioner, mesh_kind, name)
        budget = max(8, len(ce) // 4)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", PartitionBudgetWarning)
            res = bottom_up_decompose(n, ce, budget,
                                      partitioner=partitioner, mesh=mesh,
                                      mesh_axis=axes)
            td = top_down_decompose(n, ce, budget=budget,
                                    partitioner=partitioner, mesh=mesh,
                                    mesh_axis=axes)
        assert (res.phi == oracle).all(), tag
        _check_ooc_stats(res.stats, mesh, tag)
        assert (td.phi == oracle).all(), tag
        _check_ooc_stats(td.stats, mesh, tag)


@pytest.mark.parametrize("engine", ("bottom-up", "top-down"))
@pytest.mark.parametrize("store_kind", ("memory", "disk"))
@pytest.mark.parametrize("partitioner", ("sequential", "locality"))
def test_conformance_store_matrix(tmp_path, engine, store_kind,
                                  partitioner):
    """``store=`` rows of the matrix (DESIGN.md §15): the same drivers over
    an InMemoryStore (behavioral no-op) and a ChunkedDiskStore (graph
    arrays spilled chunk-wise) must stay phi bit-identical to the oracle,
    and the disk rows must show real chunk I/O in the OocStats counters."""
    from repro.core.store import ChunkedDiskStore, InMemoryStore

    for i, (name, n, ce) in enumerate(CORPUS):
        oracle = _ORACLE[name]
        tag = ("store", engine, store_kind, partitioner, name)
        if store_kind == "memory":
            store = InMemoryStore()
        else:
            store = ChunkedDiskStore(str(tmp_path / f"s{i}"),
                                     chunk_bytes=1 << 10)
        with store, warnings.catch_warnings():
            warnings.simplefilter("ignore", PartitionBudgetWarning)
            phi, stats = truss_decompose(
                n, ce, engine=engine, memory_budget=max(48, len(ce)),
                partitioner=partitioner, store=store, with_stats=True)
        assert (phi == oracle).all(), tag
        assert verify_truss(n, ce, phi), tag
        _check_ooc_stats(stats, None, tag)
        if store_kind == "disk":
            assert stats.chunk_writes > 0, tag
            assert stats.bytes_spilled > 0, tag
            assert stats.chunk_reads > 0, tag
            total = stats.prefetch_hits + stats.prefetch_misses
            assert total > 0, tag
        else:
            assert stats.chunk_writes == stats.chunk_reads == 0, tag
            assert stats.bytes_spilled == 0, tag


def test_conformance_host_memory_budget_knob():
    """The one-knob spelling: ``host_memory_budget=`` builds a scratch
    ChunkedDiskStore internally and must reproduce the oracle."""
    for name, n, ce in CORPUS:
        oracle = _ORACLE[name]
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", PartitionBudgetWarning)
            phi, stats = truss_decompose(
                n, ce, engine="bottom-up", memory_budget=max(48, len(ce)),
                host_memory_budget=1 << 16, with_stats=True)
        assert (phi == oracle).all(), name
        assert stats.chunk_writes > 0, name


@pytest.mark.parametrize("engine", ("bottom-up", "top-down"))
@pytest.mark.parametrize("kernel", ("pallas", "auto"))
def test_conformance_kernel_knob(engine, kernel):
    """``kernel=`` rows of the matrix (DESIGN.md §13): the fused Pallas
    peel (interpret mode off-TPU) and the auto route against the oracle.
    Single-device only — the mesh path always takes the XLA shard_map
    engine, so kernel × mesh is not a meaningful cell."""
    for name, n, ce in CORPUS:
        oracle = _ORACLE[name]
        tag = ("kernel", engine, kernel, name)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", PartitionBudgetWarning)
            phi, stats = truss_decompose(
                n, ce, engine=engine, memory_budget=max(48, len(ce)),
                kernel=kernel, with_stats=True)
        assert (phi == oracle).all(), tag
        assert verify_truss(n, ce, phi), tag
        _check_ooc_stats(stats, None, tag)
