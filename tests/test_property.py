"""Hypothesis property tests on the system's invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import graph as glib
from repro.core.bottom_up import bottom_up_decompose
from repro.core.peel import truss_decompose
from repro.core.serial import alg2_truss
from repro.core.support import edge_support_np
from repro.core.top_down import upper_bounds


@st.composite
def graphs(draw, max_n=28):
    n = draw(st.integers(3, max_n))
    m_max = n * (n - 1) // 2
    density = draw(st.floats(0.05, 0.7))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    iu = np.triu_indices(n, 1)
    keep = rng.random(m_max) < density
    return n, np.stack(iu, 1)[keep]


@settings(max_examples=25, deadline=None)
@given(graphs())
def test_bulk_equals_serial(g):
    n, edges = g
    ce = glib.canonical_edges(edges, n)
    if len(ce) == 0:
        return
    assert (truss_decompose(n, ce) == alg2_truss(n, ce)).all()


@settings(max_examples=20, deadline=None)
@given(graphs(), st.sampled_from(["sequential", "random"]))
def test_bottom_up_partition_invariance(g, partitioner):
    """Result independent of partitioning choice/budget (Theorem 2)."""
    n, edges = g
    ce = glib.canonical_edges(edges, n)
    if len(ce) < 4:
        return
    oracle = alg2_truss(n, ce)
    res = bottom_up_decompose(n, ce, budget=max(6, len(ce) // 3),
                              partitioner=partitioner)
    assert (res.phi == oracle).all()


@settings(max_examples=25, deadline=None)
@given(graphs())
def test_classes_partition_edges(g):
    """Phi_k for 2 <= k <= k_max partitions E (Definition 3)."""
    n, edges = g
    ce = glib.canonical_edges(edges, n)
    if len(ce) == 0:
        return
    phi = truss_decompose(n, ce)
    assert (phi >= 2).all()
    # trussness of an edge is at most its support + 2
    sup = edge_support_np(glib.build_graph(n, ce))
    assert (phi <= sup + 2).all()


@settings(max_examples=25, deadline=None)
@given(graphs())
def test_bound_sandwich(g):
    """phi(e) <= psi(e) (Lemma 2) for every edge."""
    n, edges = g
    ce = glib.canonical_edges(edges, n)
    if len(ce) == 0:
        return
    oracle = alg2_truss(n, ce)
    sup = edge_support_np(glib.build_graph(n, ce))
    psi = upper_bounds(n, ce, sup)
    assert (psi >= oracle).all()


@settings(max_examples=15, deadline=None)
@given(graphs(max_n=20), st.integers(0, 5))
def test_subgraph_monotone(g, drop):
    """Removing edges never increases trussness (Lemma 1 direction)."""
    n, edges = g
    ce = glib.canonical_edges(edges, n)
    if len(ce) < drop + 2:
        return
    phi_full = alg2_truss(n, ce)
    keep = np.ones(len(ce), bool)
    keep[:drop] = False
    sub = ce[keep]
    phi_sub = alg2_truss(n, sub)
    assert (phi_sub <= phi_full[keep]).all()
