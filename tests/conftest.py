import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_graph(rng, n, p):
    mask = rng.random((n, n)) < p
    iu = np.triu_indices(n, 1)
    return np.stack(iu, 1)[mask[iu]]
