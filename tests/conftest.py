"""Shared fixtures and graph factories for the test suite.

The factories were previously copy-pasted (with drift) across
``test_ooc_batch.py``, ``test_locality_ooc.py``, ``test_ooc_sharded.py``
and ``test_partitioner_fixes.py``; they are promoted here so every file —
and the cross-engine conformance matrix (``test_conformance.py``) — draws
from one corpus.  All factories are deterministic given their arguments
and return ``(n, canonical_edges)`` unless noted.
"""

import numpy as np
import pytest

from repro.core import graph as glib


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_graph(rng, n, p):
    """Erdős–Rényi edge list (NOT canonicalized; the historical helper)."""
    mask = rng.random((n, n)) < p
    iu = np.triu_indices(n, 1)
    return np.stack(iu, 1)[mask[iu]]


def er_graph(rng, n=24, p=0.35):
    """Canonical Erdős–Rényi graph: ``(n, edges)``."""
    return n, glib.canonical_edges(random_graph(rng, n, p), n)


def rmat_graph(scale=5, edge_factor=6, seed=2):
    """Seeded power-law (R-MAT) graph — the paper's web/social shape at
    test size; mirrors ``benchmarks/datasets.py``."""
    from repro.data import graphgen

    n, edges = graphgen.rmat(scale, edge_factor, seed)
    return n, glib.canonical_edges(edges, n)


def star_hub_graph(n=64, hub_deg=40):
    """A hub star plus a sparse path tail: per-vertex NS costs are wildly
    uneven — the regime where cost-blind partitioning overflows bins."""
    hub = np.stack([np.zeros(hub_deg, np.int64),
                    np.arange(1, hub_deg + 1)], axis=1)
    tail = np.stack([np.arange(hub_deg + 1, n - 1),
                     np.arange(hub_deg + 2, n)], axis=1)
    return n, glib.canonical_edges(np.concatenate([hub, tail]), n)


def clique_edges(lo, size):
    """Edge list of a clique on vertices [lo, lo + size)."""
    iu = np.triu_indices(size, 1)
    return np.stack(iu, 1) + lo


def clustered_cliques(n_cliques=6, size=8, seed=7):
    """Disjoint cliques bridged into one component, vertex ids shuffled —
    contiguous-id blocks split every clique, locality growth recovers
    them."""
    n = n_cliques * size
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    blocks = [clique_edges(c * size, size) for c in range(n_cliques)]
    bridges = np.stack([np.arange(0, n - size, size),
                        np.arange(size, n, size)], axis=1)
    edges = perm[np.concatenate(blocks + [bridges])]
    return n, glib.canonical_edges(edges, n)


def disconnected_graph():
    """Three components with distinct k-classes (K6 ⊔ K4 ⊔ path) — the
    stage-2 k-jump and per-component trussness regime."""
    edges = np.concatenate([
        clique_edges(0, 6), clique_edges(6, 4),
        np.stack([np.arange(10, 14), np.arange(11, 15)], axis=1),
    ])
    return 15, glib.canonical_edges(edges, 15)


def triangle_free_graph(n=24):
    """A cycle plus chords to odd distance-3 vertices stays bipartite-ish
    enough to hold no triangle; every support is 0, phi is all 2."""
    cyc = np.stack([np.arange(n), (np.arange(n) + 1) % n], axis=1)
    chords = np.stack([np.arange(0, n - 3, 2),
                       np.arange(3, n, 2)], axis=1)
    return n, glib.canonical_edges(np.concatenate([cyc, chords]), n)


def conformance_corpus():
    """The shared (name, n, edges) corpus the conformance matrix and the
    per-file tests sweep: ER, power-law, skewed hub, clustered,
    disconnected and triangle-free shapes."""
    rng = np.random.default_rng(12)
    return [
        ("er", *er_graph(rng, 26, 0.3)),
        ("rmat", *rmat_graph(scale=5, edge_factor=6, seed=3)),
        ("star-hub", *star_hub_graph(40, 24)),
        ("clustered", *clustered_cliques(4, 6, seed=9)),
        ("disconnected", *disconnected_graph()),
        ("triangle-free", *triangle_free_graph(20)),
    ]
