"""Deterministic fault injection: the FaultPlan machinery itself, and the
OOC drivers' retry / degradation ladder under injected device OOMs
(DESIGN.md §12).

The driver matrix injects a retryable OOM at every site × stage the engines
report and asserts the run *self-heals*: phi stays bit-identical to the
serial oracle while ``OocStats.retries`` records the recovery.  A
non-retryable :class:`InjectedFault` must instead propagate unchanged —
retrying a logic error would only mask it.
"""

import contextlib
import warnings

import numpy as np
import pytest

from repro.core import faults
from repro.core.bottom_up import bottom_up_decompose
from repro.core.partition import PartitionBudgetWarning
from repro.core.serial import alg2_truss
from repro.core.top_down import top_down_decompose
from tests.conftest import conformance_corpus

CORPUS = conformance_corpus()
_ORACLE = {name: alg2_truss(n, ce) for name, n, ce in CORPUS}
BUDGET = 64


@contextlib.contextmanager
def _quiet():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", PartitionBudgetWarning)
        yield


# ---------------------------------------------------------------- plan unit

def test_rule_subset_match_nth_times():
    plan = faults.FaultPlan([faults.FaultRule(
        site=faults.DISPATCH, kind="error", where={"stage": 1},
        nth=2, times=2)])
    fired = 0
    for i in range(6):
        try:
            plan.check(faults.DISPATCH, {"stage": 1, "round": i})
        except faults.InjectedFault:
            fired += 1
    assert fired == 2                      # nth=2 skips the first match
    assert plan.rules[0].seen == 6
    assert [e["ctx"]["round"] for e in plan.log] == [1, 2]


def test_rule_ignores_other_sites_and_ctx():
    plan = faults.FaultPlan([faults.FaultRule(
        site=faults.FINALIZE, kind="error", where={"stage": 2})])
    plan.check(faults.DISPATCH, {"stage": 2})          # wrong site
    plan.check(faults.FINALIZE, {"stage": 1})          # wrong ctx value
    plan.check(faults.FINALIZE, {})                    # key absent
    assert plan.log == []
    with pytest.raises(faults.InjectedFault):
        plan.check(faults.FINALIZE, {"stage": 2, "k": 5})


def test_oom_is_retryable_injected_is_not():
    oom = faults.make_oom("dispatch", {"stage": 1})
    assert faults.is_retryable(oom)
    assert "RESOURCE_EXHAUSTED" in str(oom)
    assert not faults.is_retryable(faults.InjectedFault("x"))
    assert not faults.is_retryable(ValueError("RESOURCE_EXHAUSTED"))
    assert faults.is_retryable(RuntimeError("... Out of memory ..."))
    assert not faults.is_retryable(RuntimeError("shape mismatch"))


def test_no_plan_is_noop_and_scoped():
    faults.check(faults.DISPATCH, stage=1)             # no plan: no-op
    plan = faults.FaultPlan([faults.FaultRule(site=faults.DISPATCH,
                                              kind="error")])
    with faults.active(plan):
        with pytest.raises(faults.InjectedFault):
            faults.check(faults.DISPATCH)
    faults.check(faults.DISPATCH)                      # uninstalled again


def test_unknown_kind_raises():
    plan = faults.FaultPlan([faults.FaultRule(site="x", kind="nonsense")])
    with pytest.raises(ValueError, match="unknown fault kind"):
        plan.check("x", {})


# ------------------------------------------------------- driver self-healing

@pytest.mark.parametrize("name,n,ce", CORPUS, ids=[c[0] for c in CORPUS])
@pytest.mark.parametrize("site,where", [
    (faults.DISPATCH, {"stage": 1}),
    (faults.DISPATCH, {"stage": 2}),
    (faults.FINALIZE, {"stage": 1}),
], ids=["dispatch-s1", "dispatch-s2", "finalize-s1"])
def test_bottom_up_recovers_from_oom(name, n, ce, site, where):
    plan = faults.FaultPlan([faults.FaultRule(site=site, kind="oom",
                                              where=dict(where), times=1)])
    with _quiet(), faults.active(plan):
        res = bottom_up_decompose(n, ce, budget=BUDGET)
    assert (res.phi == _ORACLE[name]).all(), name
    if plan.log:                 # graph actually exercised the site
        assert res.stats.retries >= 1, name


@pytest.mark.parametrize("name,n,ce", CORPUS, ids=[c[0] for c in CORPUS])
@pytest.mark.parametrize("site", [faults.DISPATCH, faults.FINALIZE],
                         ids=["dispatch", "finalize"])
def test_top_down_recovers_from_oom(name, n, ce, site):
    plan = faults.FaultPlan([faults.FaultRule(
        site=site, kind="oom", where={"stage": "td"}, times=1)])
    with _quiet(), faults.active(plan):
        res = top_down_decompose(n, ce, budget=BUDGET)
    assert (res.phi == _ORACLE[name]).all(), name
    if plan.log:
        assert res.stats.retries >= 1, name


def test_repeated_oom_walks_degradation_ladder():
    """Persistent stage-1 OOM: lane splits, then budget halving, then the
    failure propagates once the round budget floor is hit."""
    name, n, ce = CORPUS[0]
    plan = faults.FaultPlan([faults.FaultRule(
        site=faults.DISPATCH, kind="oom", where={"stage": 1},
        times=10**6)])
    with _quiet(), faults.active(plan):
        with pytest.raises(RuntimeError, match="RESOURCE_EXHAUSTED"):
            bottom_up_decompose(n, ce, budget=256)
    # the ladder kept retrying before giving up: lane splits re-dispatched
    # (retry > 0 in the context) and the budget-halving restarts re-entered
    # the round loop at least twice (256 -> 128 -> 64 floor)
    assert len(plan.log) >= 6
    assert any(e["ctx"].get("retry", 0) for e in plan.log)


def test_oom_then_recovery_mid_ladder():
    """OOM that clears after a few firings: the run degrades part-way down
    the ladder and still finishes exact."""
    name, n, ce = CORPUS[0]
    plan = faults.FaultPlan([faults.FaultRule(
        site=faults.DISPATCH, kind="oom", where={"stage": 1}, times=3)])
    with _quiet(), faults.active(plan):
        res = bottom_up_decompose(n, ce, budget=256)
    assert (res.phi == _ORACLE[name]).all()
    assert res.stats.retries >= 2
    assert res.stats.degraded >= 1       # a budget restart or mesh drop


@pytest.mark.parametrize("engine", ["bottom-up", "top-down"])
def test_injected_hard_error_propagates(engine):
    name, n, ce = CORPUS[0]
    fn = bottom_up_decompose if engine == "bottom-up" else top_down_decompose
    where = {"stage": 1} if engine == "bottom-up" else {"stage": "td"}
    plan = faults.FaultPlan([faults.FaultRule(
        site=faults.DISPATCH, kind="error", where=where)])
    with _quiet(), faults.active(plan):
        with pytest.raises(faults.InjectedFault):
            fn(n, ce, budget=BUDGET)
    # never reported as a retry: the drivers classified it non-retryable
    stats_retries = [e for e in plan.log if e["ctx"].get("retry", 0)]
    assert stats_retries == []


def test_partitioner_site_crash_propagates():
    name, n, ce = CORPUS[0]
    plan = faults.FaultPlan([faults.FaultRule(
        site=faults.PARTITIONER, kind="crash", nth=2)])
    with _quiet(), faults.active(plan):
        with pytest.raises(OSError, match="injected crash"):
            bottom_up_decompose(n, ce, budget=BUDGET)
    assert plan.log and plan.log[0]["ctx"]["round"] >= 1
