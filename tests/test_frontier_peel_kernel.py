"""Pallas-vs-reference parity for the fused frontier-peel kernel.

The fused kernel (``kernels/frontier_peel``, DESIGN.md §13) computes one
WHOLE removal round per ``pallas_call``; these tests pin it — in interpret
mode, the CPU CI path — to the jnp reference (``ref.fused_round_ref``),
to the host reference peel (``ref.peel_classes_ref``), and to the XLA
frontier engine it replaces (``peel.peel_classes`` /
``peel.peel_threshold``), over a seeded sweep of cap / tile shapes
(the environment has no ``hypothesis``; the sweep is deterministic).

Layout pins: ``ops.N_STATS`` mirrors ``peel.N_STATS`` so the fused path's
stats rows drop into the batched engine's accounting unchanged.
"""

import jax
import numpy as np
import pytest

from repro.core import peel
from repro.core.support import (list_triangles_np, support_from_triangle_list,
                                triangle_density)
from repro.core import graph as glib
from repro.kernels.frontier_peel import kernel as fk
from repro.kernels.frontier_peel import ops, ref
from tests.conftest import random_graph


def _lane(rng, n, p, cap_e):
    """One padded lane: (sup, alive, tris) on ``cap_e`` edge slots from a
    random graph, triangles in local edge ids."""
    edges = glib.canonical_edges(random_graph(rng, n, p), n)
    m = len(edges)
    assert m <= cap_e
    g = glib.build_graph(n, edges)
    tris = np.asarray(list_triangles_np(g), np.int64).reshape(-1, 3)
    sup = np.zeros(cap_e, np.int32)
    sup[:m] = support_from_triangle_list(tris, m)
    alive = np.zeros(cap_e, np.int32)
    alive[:m] = 1
    return sup, alive, np.asarray(tris, np.int32), m


def _pad_to(tris, t_cap, cap_e):
    out = np.full((t_cap, 3), cap_e, np.int32)
    out[: len(tris)] = tris
    return out


# ---------------------------------------------------------------------------
# single fused round: kernel (interpret) == jnp reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cap_e,bt", [(64, 8), (64, 16), (128, 32),
                                      (256, 64), (256, 128)])
def test_fused_round_matches_ref(cap_e, bt):
    rng = np.random.default_rng(cap_e + bt)
    n0 = max(10, int((cap_e / 0.35) ** 0.5))     # ~cap_e/2 expected edges
    for trial in range(3):
        n = n0 + trial
        sup, alive, tris, m = _lane(rng, n, 0.35, cap_e)
        t_cap = max(bt, -(-max(len(tris), 1) // bt) * bt)
        tris_p = _pad_to(tris, t_cap, cap_e)
        # a removal set mixing "support below threshold" and random picks
        rm = ((sup <= 1) & (alive > 0)).astype(np.int32)
        rm[rng.integers(0, m, size=max(1, m // 8))] = 1
        rm &= alive
        sup_k, alive_k = fk.fused_round(sup[None], alive[None], rm[None],
                                        tris_p[None], bt=bt, interpret=True)
        sup_r, alive_r = ref.fused_round_ref(sup[None], alive[None],
                                             rm[None], tris_p[None])
        np.testing.assert_array_equal(np.asarray(alive_k), np.asarray(alive_r))
        np.testing.assert_array_equal(np.asarray(sup_k), np.asarray(sup_r))


def test_fused_round_padding_rows_inert():
    """Rows pointing at the drop slot (id == cap_e) must not change any
    edge slot — the bucket builders' padding convention."""
    rng = np.random.default_rng(5)
    cap_e, bt = 64, 16
    sup, alive, tris, m = _lane(rng, 13, 0.4, cap_e)
    rm = ((sup <= 1) & (alive > 0)).astype(np.int32)
    lean = _pad_to(tris, max(bt, -(-len(tris) // bt) * bt), cap_e)
    fat = _pad_to(tris, lean.shape[0] + 4 * bt, cap_e)
    s1, a1 = fk.fused_round(sup[None], alive[None], rm[None], lean[None],
                            bt=bt, interpret=True)
    s2, a2 = fk.fused_round(sup[None], alive[None], rm[None], fat[None],
                            bt=bt, interpret=True)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


# ---------------------------------------------------------------------------
# full class peel: fused == host reference == XLA frontier engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cap_e,bt", [(64, "auto"), (128, 32), (256, 128)])
def test_peel_classes_fused_parity(cap_e, bt):
    rng = np.random.default_rng(17 + cap_e)
    n0 = max(9, int((cap_e / 0.45) ** 0.5) - 2)
    lanes = [_lane(rng, n0 + i, 0.45, cap_e) for i in range(3)]
    t_max = max(max(len(t) for _, _, t, _ in lanes), 1)
    sup_b = np.stack([s for s, _, _, _ in lanes])
    alive_b = np.stack([a for _, a, _, _ in lanes])
    tris_b = np.stack([_pad_to(t, t_max, cap_e) for _, _, t, _ in lanes])

    phi_f, st_f = ops.peel_classes_fused(sup_b, tris_b, alive_b,
                                         bt=bt, interpret=True)
    phi_r = ref.peel_classes_ref(sup_b, tris_b, alive_b)
    np.testing.assert_array_equal(np.asarray(phi_f), np.asarray(phi_r))
    # stats rows in peel.N_STATS layout: every alive edge was removed once
    st_f = np.asarray(st_f)
    np.testing.assert_array_equal(st_f[:, ops._S_REMOVED],
                                  alive_b.sum(axis=1))
    assert (st_f[:, ops._S_ROUNDS] >= 1).all()
    assert (st_f[:, ops._S_MAXF] <= st_f[:, ops._S_REMOVED]).all()

    for lane, (sup, alive, tris, m) in enumerate(lanes):
        phi_x, _ = peel.peel_classes(sup[:m].astype(np.int32),
                                     np.asarray(tris, np.int32),
                                     alive[:m] > 0)
        np.testing.assert_array_equal(np.asarray(phi_f)[lane, :m],
                                      np.asarray(phi_x), err_msg=str(lane))


@pytest.mark.parametrize("thresh", [0, 1, 2, 4])
def test_peel_threshold_fused_parity(thresh):
    rng = np.random.default_rng(23 + thresh)
    cap_e = 128
    sup, alive, tris, m = _lane(rng, 18, 0.4, cap_e)
    removable = np.zeros(cap_e, np.int32)
    removable[:m] = rng.integers(0, 2, m)
    tris_p = _pad_to(tris, max(len(tris), 1), cap_e)
    alive_f = ops.peel_threshold_fused(sup, tris_p, removable,
                                       thresh, alive, interpret=True)
    alive_x, _, _ = peel.peel_threshold(
        sup[:m].astype(np.int32), np.asarray(tris, np.int32),
        alive[:m] > 0, removable[:m] > 0, thresh)
    np.testing.assert_array_equal(np.asarray(alive_f)[:m] > 0,
                                  np.asarray(alive_x))


# ---------------------------------------------------------------------------
# layout / routing contracts
# ---------------------------------------------------------------------------

def test_stats_layout_pinned_to_peel():
    assert ops.N_STATS == peel.N_STATS
    assert (ops._S_ROUNDS, ops._S_REMOVED, ops._S_GATHERED, ops._S_MAXF) \
        == (peel._S_ROUNDS, peel._S_REMOVED, peel._S_GATHERED, peel._S_MAXF)


def test_resolve_kernel_routing():
    # explicit knobs pass through regardless of backend
    assert ops.resolve_kernel("xla", 64, 10_000) == "xla"
    assert ops.resolve_kernel("pallas", 1 << 30, 0) == "pallas"
    with pytest.raises(ValueError):
        ops.resolve_kernel("mxu", 64, 64)
    # auto: never Pallas off-TPU (jax 0.4.37 has no CPU lowering)
    assert ops.resolve_kernel("auto", 64, 10_000, backend="cpu") == "xla"
    # auto on TPU: dense lanes route to the kernel, sparse lanes and
    # VMEM-overflowing caps fall back
    assert ops.resolve_kernel("auto", 1024, 4096, backend="tpu") == "pallas"
    assert ops.resolve_kernel("auto", 1024, 16, backend="tpu") == "xla"
    huge = fk.VMEM_BUDGET_BYTES          # no tile fits this cap_e
    assert ops.resolve_kernel("auto", huge, 10 * huge, backend="tpu") == "xla"
    assert triangle_density(0, 5) == 0.0


def test_resolve_tile_and_feasibility():
    assert ops.resolve_tile(64, 1000, 32, True) == 32      # explicit wins
    bt = ops.resolve_tile(64, 1000, "auto", True)
    assert bt in fk.DEFAULT_TILE_CANDIDATES
    assert fk.kernel_vmem_bytes(64, bt) <= fk.VMEM_BUDGET_BYTES
    tiles = fk.feasible_tiles(256, 1024)
    assert tiles and all(1024 % t == 0 for t in tiles)
    assert tiles == sorted(tiles, reverse=True)
    # vmem model is monotone in both tile and cap
    assert fk.kernel_vmem_bytes(256, 256) > fk.kernel_vmem_bytes(256, 128)
    assert fk.kernel_vmem_bytes(512, 128) > fk.kernel_vmem_bytes(256, 128)


def test_autotune_tiles_returns_feasible():
    bt = fk.autotune_tiles(128, 512, interpret=True)
    assert 512 % bt == 0
    assert fk.kernel_vmem_bytes(128, bt) <= fk.VMEM_BUDGET_BYTES
    # cached: same key returns the same tile without re-timing
    assert fk.autotune_tiles(128, 512, interpret=True) == bt
