"""End-to-end behaviour tests for the paper's system."""

import numpy as np

from repro.core import graph as glib
from repro.core.bottom_up import bottom_up_decompose
from repro.core.peel import truss_decompose
from repro.core.serial import alg2_truss
from repro.core.top_down import top_down_decompose
from repro.data import graphgen


def test_end_to_end_decomposition_paths_agree():
    """The full production story on one power-law graph: in-memory bulk
    peel == bottom-up (restricted memory) == top-down == serial oracle."""
    n, edges = graphgen.rmat(scale=9, edge_factor=8, seed=11)
    oracle = alg2_truss(n, edges)
    assert (truss_decompose(n, edges) == oracle).all()
    bu = bottom_up_decompose(n, edges, budget=max(64, len(edges) // 6))
    assert (bu.phi == oracle).all()
    td = top_down_decompose(n, edges, t=3)
    for k in td.classes:
        assert ((td.phi == k) == (oracle == k)).all()


def test_end_to_end_training_converges():
    """Tiny LM through the full stack (data, model, optimizer, loop)."""
    import jax

    from repro.configs.reduced import make_reduced
    from repro.optim import adamw

    cfg, init_fn, loss_fn, batch_fn = make_reduced("granite-8b")
    params = init_fn()
    state = adamw.init_state(params)
    ocfg = adamw.AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=30)

    @jax.jit
    def step(params, state, batch):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        params, state, _ = adamw.update(ocfg, params, state, g)
        return params, state, loss

    losses = []
    for s in range(12):
        params, state, loss = step(params, state, batch_fn(s))
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
