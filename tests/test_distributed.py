"""Multi-device tests (8 virtual CPU devices via a subprocess, since device
count locks at first jax init)."""

import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=_ROOT)
    assert p.returncode == 0, p.stdout[-3000:] + p.stderr[-3000:]
    return p.stdout


def test_distributed_truss_core():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        mesh = jax.make_mesh((8,), ("data",))
        from repro.core import graph as glib
        from repro.core.support import edge_support_np, list_triangles_np
        from repro.core.serial import alg2_truss
        from repro.core.distributed import (peel_classes_sharded,
            pad_triangles, ring_support_dense, allgather_support_dense)
        rng = np.random.default_rng(3)
        n = 64
        mask = rng.random((n, n)) < 0.25
        iu = np.triu_indices(n, 1); e = np.stack(iu, 1)[mask[iu]]
        ce = glib.canonical_edges(e, n)
        g = glib.build_graph(n, ce)
        oracle = alg2_truss(n, ce)
        tris = list_triangles_np(g)
        sup = edge_support_np(g).astype(np.int32)
        tp = pad_triangles(tris, g.m, 8)
        phi = np.asarray(peel_classes_sharded(
            mesh, jnp.asarray(sup), jnp.asarray(tp), jnp.ones(g.m, bool)))
        assert (phi == oracle).all()
        A = np.zeros((n, n), np.float32)
        A[ce[:,0], ce[:,1]] = 1; A[ce[:,1], ce[:,0]] = 1
        S_ring = np.asarray(ring_support_dense(mesh, jnp.asarray(A)))
        S_ag = np.asarray(allgather_support_dense(mesh, jnp.asarray(A)))
        assert np.allclose(S_ring, S_ag)
        assert (S_ring[ce[:,0], ce[:,1]] == sup).all()
        print("DIST-CORE-OK")
    """)
    assert "DIST-CORE-OK" in out


def test_distributed_models():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        from repro.models.gnn import models as G
        from repro.models.gnn.distributed import (bucket_edges_by_owner,
            pad_nodes, eqv2_ring_loss)
        from repro.models.recsys import embedding as emb
        from repro.core import graph as glib
        rng = np.random.default_rng(0)
        n, n_pad = 60, 64
        mask = rng.random((n, n)) < 0.15
        iu = np.triu_indices(n, 1); e = np.stack(iu, 1)[mask[iu]]
        ce = glib.canonical_edges(e, n)
        ei = np.concatenate([ce, ce[:, ::-1]]).astype(np.int32)
        cfg = G.EquiformerV2Config(n_layers=2, d_hidden=16, l_max=2,
                                   m_max=2, n_heads=4, d_in=8)
        params = G.eqv2_init(jax.random.PRNGKey(0), cfg)
        nf = rng.standard_normal((n, 8)).astype(np.float32)
        pos = rng.standard_normal((n, 3)).astype(np.float32)
        tgt = rng.standard_normal(n).astype(np.float32)
        batch = {"node_feat": jnp.asarray(nf), "edge_index": jnp.asarray(ei),
                 "positions": jnp.asarray(pos), "targets": jnp.asarray(tgt),
                 "node_mask": jnp.ones(n, np.float32)}
        loss_plain = G.eqv2_loss(params, batch, cfg)
        g_plain = jax.grad(lambda p: G.eqv2_loss(p, batch, cfg))(params)
        bk = bucket_edges_by_owner(n_pad, ei, pos, 8, pad_factor=4.0)
        rb = {"node_feat": jnp.asarray(pad_nodes(nf, n_pad)),
              "positions": jnp.asarray(pad_nodes(pos, n_pad)),
              "targets": jnp.asarray(pad_nodes(tgt, n_pad)),
              "node_mask": jnp.asarray(pad_nodes(np.ones(n, np.float32), n_pad)),
              **{k: jnp.asarray(v) for k, v in bk.items() if k != "overflow"}}
        with mesh:
            loss_ring = eqv2_ring_loss(params, rb, cfg, mesh)
            g_ring = jax.jit(jax.grad(
                lambda p: eqv2_ring_loss(p, rb, cfg, mesh)))(params)
        np.testing.assert_allclose(float(loss_plain), float(loss_ring), rtol=2e-4)
        for a, b in zip(jax.tree.leaves(g_plain), jax.tree.leaves(g_ring)):
            a, b = np.asarray(a), np.asarray(b)
            assert np.max(np.abs(a - b)) <= 5e-3 * (np.max(np.abs(a)) + 1e-6)
        # sage ring == plain sage on the same graph
        from repro.models.gnn.distributed import sage_ring_loss
        scfg = G.GraphSAGEConfig(n_layers=2, d_hidden=16, d_in=8, n_classes=4)
        sparams = G.sage_init(jax.random.PRNGKey(1), scfg)
        labels = rng.integers(0, 4, n).astype(np.int32)
        lmask = (rng.random(n) < 0.6).astype(np.float32)
        sbatch = {"node_feat": jnp.asarray(nf), "edge_index": jnp.asarray(ei),
                  "labels": jnp.asarray(labels), "label_mask": jnp.asarray(lmask)}
        loss_flat = G.sage_loss(sparams, sbatch, scfg)
        srb = {"node_feat": jnp.asarray(pad_nodes(nf, n_pad)),
               "labels": jnp.asarray(pad_nodes(labels, n_pad)),
               "label_mask": jnp.asarray(pad_nodes(lmask, n_pad)),
               "src_loc": jnp.asarray(bk["src_loc"]),
               "dst_loc": jnp.asarray(bk["dst_loc"]),
               "edge_mask": jnp.asarray(bk["edge_mask"])}
        with mesh:
            loss_sring = sage_ring_loss(sparams, srb, scfg, mesh)
            gs = jax.jit(jax.grad(
                lambda p: sage_ring_loss(p, srb, scfg, mesh)))(sparams)
        np.testing.assert_allclose(float(loss_flat), float(loss_sring),
                                   rtol=2e-4)
        for leaf in jax.tree.leaves(gs):
            assert np.isfinite(np.asarray(leaf)).all()
        # sharded embedding lookup == take
        from jax.sharding import PartitionSpec as P, NamedSharding
        tbl = jnp.asarray(rng.standard_normal((64, 8)).astype(np.float32))
        ids = jnp.asarray(rng.integers(0, 64, (16,)).astype(np.int32))
        with mesh:
            tbl_s = jax.device_put(tbl, NamedSharding(mesh, P("model", None)))
            out = emb.sharded_lookup(tbl_s, ids, mesh=mesh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(tbl)[np.asarray(ids)])
        # compressed psum == mean of grads (within int8 quantization error)
        from repro.optim.compression import compressed_psum
        g8 = rng.standard_normal((8, 128)).astype(np.float32)
        def body(g, e):
            return compressed_psum(g, e, "data")
        fn = jax.shard_map(body, mesh=mesh,
            in_specs=(P("data", None), P("data", None)),
            out_specs=(P("data", None), P("data", None)), check_vma=False)
        gm, _ = fn(jnp.asarray(g8).reshape(8, 128),
                   jnp.zeros((8, 128)))
        # every data-row now holds the mean over its data group (4 shards x 2)
        got = np.asarray(gm)
        grp = g8.reshape(4, 2, 128).mean(0)
        for i in range(4):
            np.testing.assert_allclose(got[2*i:2*i+2], grp, atol=0.05)
        print("DIST-MODELS-OK")
    """)
    assert "DIST-MODELS-OK" in out


def test_dryrun_single_cell_small_mesh():
    """The dry-run machinery itself on an 8-device mesh (fast cell)."""
    out = _run("""
        import jax
        from repro.configs import registry
        from repro.launch.dryrun import run_cell
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cell = registry.get_cell("gat-cora", "full_graph_sm")
        rec = run_cell(cell, mesh, "4x2")
        assert rec["ok"], rec
        assert rec["t_memory"] > 0
        print("DRYRUN-OK")
    """)
    assert "DRYRUN-OK" in out
