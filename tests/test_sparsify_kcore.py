"""Coverage for the small exposed modules: ``sparsify`` (truss-based graph
utilities for the training pipelines) and ``kcore`` (the paper's Section
7.4 comparison structure)."""

import numpy as np
import pytest

from repro.core import graph as glib
from repro.core.kcore import cmax_core, core_decompose
from repro.core.serial import alg2_truss
from repro.core.sparsify import (clique_upper_bound, sampling_weights,
                                 truss_filter, trussness_features)
from tests.conftest import (clique_edges, clustered_cliques, random_graph,
                            star_hub_graph, triangle_free_graph)


def _max_clique_bruteforce(n, edges):
    """Exact maximum clique by recursion over adjacency bitmasks (small n)."""
    adj = [0] * n
    for u, v in np.asarray(edges, dtype=np.int64).tolist():
        adj[u] |= 1 << v
        adj[v] |= 1 << u

    best = 0

    def grow(cand, size):
        nonlocal best
        if size + bin(cand).count("1") <= best:
            return
        if cand == 0:
            best = max(best, size)
            return
        v = cand.bit_length() - 1
        grow(cand & adj[v], size + 1)       # take v
        grow(cand & ~(1 << v), size)        # skip v

    grow((1 << n) - 1, 0)
    return best


# ---------------------------------------------------------------------------
# sparsify properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alpha", [0.5, 1.0, 2.0])
def test_sampling_weights_normalized_and_positive(rng, alpha):
    for n, p in ((20, 0.3), (28, 0.15)):
        edges = random_graph(rng, n, p)
        if len(edges) < 3:
            continue
        w = sampling_weights(n, edges, alpha=alpha)
        ce = glib.canonical_edges(edges, n)
        assert w.shape == (len(ce),)
        assert (w > 0).all()
        assert w.sum() == pytest.approx(1.0, abs=1e-5)


def test_sampling_weights_monotone_in_trussness(rng):
    """Higher-trussness edges never get smaller weight (strong ties
    sampled first)."""
    n, ce = clustered_cliques(3, 6, seed=5)
    phi = alg2_truss(n, ce)
    w = sampling_weights(n, ce)
    order = np.argsort(phi)
    assert (np.diff(w[order]) >= -1e-9).all()


def test_truss_filter_is_k_truss(rng):
    n = 24
    edges = random_graph(rng, n, 0.35)
    ce = glib.canonical_edges(edges, n)
    phi = alg2_truss(n, ce)
    for k in (3, 4, 5):
        tk = truss_filter(n, edges, k)
        ref = ce[phi >= k]
        assert tk.shape == ref.shape
        assert (tk == ref).all()


def test_trussness_features_range(rng):
    n = 22
    edges = random_graph(rng, n, 0.3)
    ce, feat = trussness_features(n, edges)
    assert len(ce) == len(feat)
    assert (feat >= 0.0).all() and (feat <= 1.0).all()
    # a clique's internal edges are the strongest ties
    n2, ce2 = clustered_cliques(2, 7, seed=1)
    _, feat2 = trussness_features(n2, ce2)
    assert feat2.max() == pytest.approx(1.0)


def test_clique_upper_bound_vs_bruteforce(rng):
    """k_max bounds the maximum clique size from above (Section 7.4), and
    is tight on a clique."""
    for trial in range(4):
        n = 10 + 2 * trial
        edges = glib.canonical_edges(random_graph(rng, n, 0.4), n)
        if len(edges) < 3:
            continue
        ub = clique_upper_bound(n, edges)
        exact = _max_clique_bruteforce(n, edges)
        assert ub >= exact
    s = 7
    assert clique_upper_bound(s, clique_edges(0, s)) == s
    assert _max_clique_bruteforce(s, clique_edges(0, s)) == s


def test_clique_upper_bound_degenerate():
    # triangle-free: kmax == 2, max clique == 2 (any edge)
    n, ce = triangle_free_graph(16)
    assert clique_upper_bound(n, ce) == 2
    # empty graph
    assert clique_upper_bound(4, np.zeros((0, 2), np.int64)) == 2


# ---------------------------------------------------------------------------
# kcore edge cases
# ---------------------------------------------------------------------------

def test_core_decompose_empty_graph():
    core = core_decompose(5, np.zeros((0, 2), np.int64))
    assert core.shape == (5,)
    assert (core == 0).all()
    cmax, ce = cmax_core(5, np.zeros((0, 2), np.int64))
    assert cmax == 0 and len(ce) == 0


def test_core_decompose_multigraph_input():
    """Duplicate edges and self loops are canonicalized away — the core
    numbers match the simple-graph result."""
    simple = np.array([[0, 1], [1, 2], [0, 2], [2, 3]])
    noisy = np.concatenate([simple, simple[::-1], simple,
                            np.array([[1, 1], [3, 3]])])
    a = core_decompose(4, simple)
    b = core_decompose(4, noisy)
    assert (a == b).all()
    assert (a == np.array([2, 2, 2, 1])).all()


def test_cmax_core_on_clique():
    s = 8
    core = core_decompose(s, clique_edges(0, s))
    assert (core == s - 1).all()
    cmax, ce = cmax_core(s, clique_edges(0, s))
    assert cmax == s - 1
    assert len(ce) == s * (s - 1) // 2


def test_core_vs_truss_containment(rng):
    """A k-truss is a (k-1)-core (paper Section 7.4): every vertex of the
    k_max-truss has core number >= k_max - 1."""
    n, ce = clustered_cliques(3, 6, seed=2)
    phi = alg2_truss(n, ce)
    core = core_decompose(n, ce)
    kmax = int(phi.max())
    tk = ce[phi >= kmax]
    verts = np.unique(tk.reshape(-1))
    assert (core[verts] >= kmax - 1).all()


def test_core_star_and_path():
    n, ce = star_hub_graph(20, 12)
    core = core_decompose(n, ce)
    assert core.max() == 1          # star + path are 1-degenerate
    cmax, edges_c = cmax_core(n, ce)
    assert cmax == 1 and len(edges_c) == len(ce)
