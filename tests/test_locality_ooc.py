"""Locality-aware partitioner + double-buffered OOC rounds (DESIGN.md §9):
partition validity, triangle-locality scoring, round reduction on a
clustered graph, and the non-blocking peel dispatch path."""

import numpy as np
import pytest

from repro.core import graph as glib
from repro.core.bottom_up import (bottom_up_decompose, lower_bounding,
                                  partitioned_support)
from repro.core.partition import (PartitionBudgetWarning,
                                  build_partition_batch, locality_partition,
                                  sequential_partition)
from repro.core.peel import (PendingPeel, local_threshold_peel,
                             peel_classes_batched)
from repro.core.serial import alg2_truss
from repro.core.support import (edge_support_np, list_triangles_np,
                                support_from_triangle_list)
from tests.conftest import random_graph


def _clustered_graph(n_cliques=6, size=8, seed=7):
    """Disjoint cliques bridged into one component, vertex ids shuffled —
    contiguous-id blocks split every clique, BFS growth recovers them."""
    n = n_cliques * size
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    blocks = []
    for c in range(n_cliques):
        iu = np.triu_indices(size, 1)
        blocks.append(np.stack(iu, 1) + c * size)
    bridges = np.stack([np.arange(0, n - size, size),
                        np.arange(size, n, size)], axis=1)
    edges = perm[np.concatenate(blocks + [bridges])]
    return n, glib.canonical_edges(edges, n)


# ---------------------------------------------------------------------------
# partitioner properties
# ---------------------------------------------------------------------------

def test_locality_partition_is_valid_partition(rng):
    n = 50
    ce = glib.canonical_edges(random_graph(rng, n, 0.25), n)
    g = glib.build_graph(n, ce)
    budget = max(8, len(ce) // 5)
    parts = locality_partition(g, budget)
    allv = np.concatenate(parts)
    assert len(allv) == len(np.unique(allv))          # disjoint
    assert set(allv.tolist()) == set(np.nonzero(g.deg > 0)[0].tolist())
    cost = g.deg.astype(np.int64)
    for P in parts:
        # budget respected, except the warned over-budget singleton case
        assert int(cost[P].sum()) <= budget or len(P) == 1


def test_locality_partition_warns_on_hub():
    n = 30
    hub = np.stack([np.zeros(n - 1, np.int64), np.arange(1, n)], axis=1)
    ce = glib.canonical_edges(hub, n)
    g = glib.build_graph(n, ce)
    with pytest.warns(PartitionBudgetWarning) as rec:
        parts = locality_partition(g, budget=5)
    assert rec[0].message.max_cost == n - 1
    assert sum(len(P) for P in parts) == n


def test_locality_partition_is_compact(rng):
    """Bin-packed growth regions: the part count stays near the
    ceil(total_cost / budget) lower bound (first-fit-decreasing is within
    a constant factor), instead of one part per periphery fragment."""
    n = 60
    ce = glib.canonical_edges(random_graph(rng, n, 0.2), n)
    g = glib.build_graph(n, ce)
    cost = g.deg.astype(np.int64)
    for budget in (16, 40, 100):
        parts = locality_partition(g, budget)
        n_over = int((cost > budget).sum())
        lower = int(np.ceil(cost.sum() / budget))
        assert len(parts) <= 2 * lower + n_over + 1


def test_locality_beats_sequential_on_clustered_graph():
    """The tentpole claim in miniature: on a shuffled clique graph the
    locality-aware partitioner captures more triangles per part and
    settles the decomposition in no more rounds than contiguous-id
    blocks, with identical phi (Lemma 1 holds for any partition)."""
    n, ce = _clustered_graph()
    oracle = alg2_truss(n, ce)
    budget = 2 * 8 * 7 + 16        # ~ one clique's NS cost
    res = {}
    for p in ("sequential", "locality"):
        res[p] = bottom_up_decompose(n, ce, budget, partitioner=p)
        assert (res[p].phi == oracle).all()
    st_seq, st_loc = res["sequential"].stats, res["locality"].stats
    assert st_loc.tri_locality > st_seq.tri_locality
    assert res["locality"].rounds <= res["sequential"].rounds
    assert st_loc.ns_sweeps <= st_seq.ns_sweeps
    assert st_loc.tri_routes <= st_seq.tri_routes
    assert 0.0 <= st_loc.tri_locality <= 1.0


def test_partition_batch_tri_locality_counters(rng):
    n = 40
    ce = glib.canonical_edges(random_graph(rng, n, 0.3), n)
    g = glib.build_graph(n, ce)
    batch = build_partition_batch(
        g, sequential_partition(g, max(8, len(ce) // 4)))
    assert batch.tri_total == len(list_triangles_np(g))
    assert 0 <= batch.tri_assigned <= batch.tri_total
    assert batch.tri_locality == pytest.approx(
        batch.tri_assigned / batch.tri_total if batch.tri_total else 1.0)
    # one part captures everything
    whole = build_partition_batch(g, [np.nonzero(g.deg > 0)[0].astype(np.int32)])
    assert whole.tri_locality == 1.0


@pytest.mark.parametrize("budget_frac", [0.15, 0.4])
def test_locality_engines_match_oracle(rng, budget_frac):
    for trial in range(3):
        n = 22 + 7 * trial
        ce = glib.canonical_edges(random_graph(rng, n, 0.3), n)
        if len(ce) < 3:
            continue
        oracle = alg2_truss(n, ce)
        budget = max(4, int(len(ce) * budget_frac))
        res = bottom_up_decompose(n, ce, budget, partitioner="locality")
        assert (res.phi == oracle).all()
        sup = edge_support_np(glib.build_graph(n, ce))
        ps = partitioned_support(n, ce, budget, partitioner="locality")
        assert (ps == sup).all()
        from repro.core.top_down import top_down_decompose
        td = top_down_decompose(n, ce, budget=budget, partitioner="locality")
        assert (td.phi == oracle).all()


# ---------------------------------------------------------------------------
# double-buffered rounds: non-blocking dispatch path
# ---------------------------------------------------------------------------

def test_peel_classes_batched_nonblocking_matches_blocking(rng):
    n = 40
    ce = glib.canonical_edges(random_graph(rng, n, 0.3), n)
    g = glib.build_graph(n, ce)
    batch = build_partition_batch(
        g, sequential_partition(g, max(8, len(ce) // 4)))
    for bucket in batch.buckets:
        phi_b, st_b, _ = peel_classes_batched(
            bucket.sup, bucket.tris, bucket.indptr, bucket.tids, bucket.alive)
        handle = peel_classes_batched(
            bucket.sup, bucket.tris, bucket.indptr, bucket.tids, bucket.alive,
            blocking=False)
        assert isinstance(handle, PendingPeel)
        phi_nb, st_nb = handle.result()
        assert (phi_nb == phi_b).all()
        assert (st_nb == st_b).all()
        # result() is cached, not re-dispatched
        assert handle.result() is handle.result()


def test_local_threshold_peel_nonblocking_matches_blocking(rng):
    n = 24
    ce = glib.canonical_edges(random_graph(rng, n, 0.4), n)
    g = glib.build_graph(n, ce)
    tris = list_triangles_np(g)
    sup = support_from_triangle_list(tris, g.m).astype(np.int32)
    removable = rng.random(g.m) < 0.7
    for thresh in (0, 2, 5):
        alive_b, removed_b, _ = local_threshold_peel(
            sup, tris, removable, thresh)
        handle = local_threshold_peel(
            sup, tris, removable, thresh, blocking=False)
        alive_nb, removed_nb = handle.result()
        assert (alive_nb == alive_b).all()
        assert (removed_nb == removed_b).all()
    # triangle-free short-circuit honors the contract too
    h = local_threshold_peel(np.zeros(4, np.int32),
                             np.zeros((0, 3), np.int32),
                             np.ones(4, bool), 0, blocking=False)
    alive_nb, removed_nb = h.result()
    assert removed_nb.all() and not alive_nb.any()


def test_shape_cache_compile_counter_nonblocking(rng):
    n = 30
    ce = glib.canonical_edges(random_graph(rng, n, 0.35), n)
    g = glib.build_graph(n, ce)
    batch = build_partition_batch(
        g, sequential_partition(g, max(8, len(ce) // 3)))
    cache: set = set()
    bucket = batch.buckets[0]
    h1 = peel_classes_batched(bucket.sup, bucket.tris, bucket.indptr,
                              bucket.tids, bucket.alive,
                              shape_cache=cache, blocking=False)
    h2 = peel_classes_batched(bucket.sup, bucket.tris, bucket.indptr,
                              bucket.tids, bucket.alive,
                              shape_cache=cache, blocking=False)
    # new_compile is known at dispatch, before any result() blocks
    assert h2.new_compile is False
    assert (h1.result()[0] == h2.result()[0]).all()


def test_pipeline_overlap_counter(rng):
    """Multi-round runs consume each round one round late: all but the
    final consumed round overlapped the next round's host build."""
    n = 45
    ce = glib.canonical_edges(random_graph(rng, n, 0.25), n)
    res = lower_bounding(n, ce, budget=max(8, len(ce) // 6))
    st = res.stats
    assert st.overlapped >= 0
    if st.rounds > 1:
        # every yielded round except the last was consumed after the
        # following round had been built and dispatched
        assert st.overlapped >= 1
