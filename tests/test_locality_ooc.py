"""Triangle-aware locality partitioner + pipelined OOC rounds (DESIGN.md
§9, §11): zoned partition validity, the closed-wedge cost model, triangle-
locality scoring, the stage-2 candidate pipeline and the non-blocking peel
dispatch path."""

import numpy as np
import pytest

from repro.core import graph as glib
from repro.core import partition as plib
from repro.core.bottom_up import (bottom_up_decompose, lower_bounding,
                                  partitioned_support)
from repro.core.graph import closed_wedge_estimate
from repro.core.partition import (PartitionBudgetWarning,
                                  _first_fit_decreasing_2d,
                                  build_partition_batch, locality_partition,
                                  sequential_partition)
from repro.core.peel import (PendingPeel, local_threshold_peel,
                             peel_classes_batched)
from repro.core.serial import alg2_truss
from repro.core.support import (edge_support_np, list_triangles_np,
                                support_from_triangle_list)
from repro.core.top_down import top_down_decompose
from tests.conftest import (clique_edges, clustered_cliques, random_graph,
                            star_hub_graph, triangle_free_graph)


# ---------------------------------------------------------------------------
# the closed-wedge cost model (DESIGN.md §11)
# ---------------------------------------------------------------------------

def test_closed_wedge_estimate_exact_on_clique():
    """On K_s the estimate equals the incident triangle count C(s-1, 2)
    per vertex, so the graph total / 3 is the exact triangle count."""
    for s in (4, 6, 9):
        g = glib.build_graph(s, clique_edges(0, s))
        est = closed_wedge_estimate(g)
        assert (est == (s - 1) * (s - 2) // 2).all()
        assert int(est.sum()) // 3 == s * (s - 1) * (s - 2) // 6


def test_closed_wedge_estimate_zero_iff_triangle_free_vertex():
    n, ce = triangle_free_graph(20)
    g = glib.build_graph(n, ce)
    assert (closed_wedge_estimate(g) >= 0).all()
    # a star's leaves AND hub are triangle-free: estimate 0 everywhere
    n, ce = star_hub_graph(30, 20)
    g = glib.build_graph(n, ce)
    est = closed_wedge_estimate(g)
    assert (est[g.deg == 1] == 0).all()
    # empty graph
    g0 = glib.build_graph(5, np.zeros((0, 2), np.int64))
    assert (closed_wedge_estimate(g0) == 0).all()


# ---------------------------------------------------------------------------
# partitioner properties (zoned, marginal-cost)
# ---------------------------------------------------------------------------

def test_locality_partition_is_valid_zoned_partition(rng):
    """Parts are disjoint, only active vertices, and every part's TRUE
    working set |NS(P)| fits the budget (the marginal-cost accounting's
    guarantee) except the warned over-budget singleton case.  A zoned
    cover may defer periphery vertices to later rounds — that is the
    contract change of DESIGN.md §11."""
    n = 50
    ce = glib.canonical_edges(random_graph(rng, n, 0.25), n)
    g = glib.build_graph(n, ce)
    budget = max(8, len(ce) // 5)
    parts = locality_partition(g, budget)
    assert parts
    allv = np.concatenate(parts)
    assert len(allv) == len(np.unique(allv))          # disjoint
    active = set(np.nonzero(g.deg > 0)[0].tolist())
    assert set(allv.tolist()) <= active
    for P in parts:
        ns_ids, _, _ = glib.neighborhood_subgraph(g, P)
        assert len(ns_ids) <= budget or len(P) == 1


def test_locality_rounds_terminate_on_partial_covers(rng):
    """Repeatedly partitioning + removing internal edges must empty every
    graph even though single calls cover only a zone."""
    for n, p in ((40, 0.3), (30, 0.1)):
        ce = glib.canonical_edges(random_graph(rng, n, p), n)
        g = glib.build_graph(n, ce)
        budget = max(6, len(ce) // 6)
        for _ in range(500):
            if g.m == 0:
                break
            parts = locality_partition(g, budget)
            if not parts:
                break
            part_of = np.full(g.n, -1, np.int64)
            for i, P in enumerate(parts):
                part_of[P.astype(np.int64)] = i
            e = g.edges.astype(np.int64)
            internal = (part_of[e[:, 0]] == part_of[e[:, 1]]) \
                & (part_of[e[:, 0]] >= 0)
            if not internal.any():
                budget *= 2          # the driver's stall rule
                continue
            g = g.remove_edges(internal)
        assert g.m == 0


def test_locality_partition_warns_on_hub():
    n, ce = star_hub_graph(30, 29)
    g = glib.build_graph(n, ce)
    with pytest.warns(PartitionBudgetWarning) as rec:
        parts = locality_partition(g, budget=5)
    assert rec[0].message.max_cost == n - 1
    # the hub is emitted as an over-budget singleton part in SOME round's
    # zone; vertices are never duplicated
    allv = np.concatenate(parts)
    assert len(allv) == len(np.unique(allv))


def test_locality_partition_is_compact(rng):
    """Bin-packed growth fragments: the part count stays near the
    ceil(covered_cost / budget) lower bound (first-fit is within a factor
    2 on the cost dimension even with triangle-ordered insertion), instead
    of one part per periphery fragment."""
    n = 60
    ce = glib.canonical_edges(random_graph(rng, n, 0.2), n)
    g = glib.build_graph(n, ce)
    cost = g.deg.astype(np.int64)
    for budget in (16, 40, 100):
        parts = locality_partition(g, budget)
        covered = sum(
            len(glib.neighborhood_subgraph(g, P)[0]) for P in parts)
        n_over = int((cost > budget).sum())
        lower = int(np.ceil(covered / budget))
        assert len(parts) <= 2 * lower + n_over + 1


def test_first_fit_decreasing_2d_cost_guarantee():
    """The triangle dimension is soft: bins open only when COST fits
    nowhere, so the bin count matches the cost-only first-fit bound even
    under adversarial triangle sizes."""
    costs = [30, 30, 30, 30, 5, 5, 5, 5]
    tris = [1000, 1000, 1000, 1000, 0, 0, 0, 0]
    bins = _first_fit_decreasing_2d(costs, tris, cap_cost=70, cap_tri=10)
    assert sorted(i for b in bins for i in b) == list(range(len(costs)))
    total = sum(costs)
    assert len(bins) <= 2 * -(-total // 70) + 1
    # triangle-heavy items spread across the cost-opened bins instead of
    # piling into the first one
    costs2 = [60, 60, 5, 5]
    tris2 = [10, 10, 40, 40]
    bins2 = _first_fit_decreasing_2d(costs2, tris2, cap_cost=70, cap_tri=50)
    loads = [sum(tris2[i] for i in b) for b in bins2]
    assert len(bins2) == 2
    assert max(loads) <= 50


def test_marginal_cost_packs_cohesive_parts_denser():
    """A clique's NS is far below its Σ deg: with the marginal-cost
    accounting one part can hold several cliques a Σ-deg charge would
    split, while the true |NS| stays within budget."""
    n, ce = clustered_cliques(4, 6, seed=3)
    g = glib.build_graph(n, ce)
    # one K6's NS ≈ 15 internal + bridges; Σ deg = 6 * 5 = 30
    budget = 40
    parts = locality_partition(g, budget)
    sizes = sorted(len(P) for P in parts)
    assert sizes[-1] > 6            # at least one part spans > one clique
    for P in parts:
        assert len(glib.neighborhood_subgraph(g, P)[0]) <= budget


def test_locality_beats_sequential_on_clustered_graph():
    """The tentpole claim in miniature: on a shuffled clique graph the
    triangle-aware partitioner captures more triangles per scanned
    triangle and settles the decomposition in no more rounds than
    contiguous-id blocks, with identical phi (Lemma 1 holds for any
    partition)."""
    n, ce = clustered_cliques()
    oracle = alg2_truss(n, ce)
    budget = 2 * 8 * 7 + 16        # ~ two cliques' Σ-deg cost
    res = {}
    for p in ("sequential", "locality"):
        res[p] = bottom_up_decompose(n, ce, budget, partitioner=p)
        assert (res[p].phi == oracle).all()
    st_seq, st_loc = res["sequential"].stats, res["locality"].stats
    assert st_loc.tri_locality > st_seq.tri_locality
    assert res["locality"].rounds <= res["sequential"].rounds
    assert st_loc.ns_sweeps <= st_seq.ns_sweeps
    assert st_loc.tri_routes <= st_seq.tri_routes
    assert 0.0 <= st_loc.tri_locality <= 1.0


def test_partition_batch_tri_counters(rng):
    n = 40
    ce = glib.canonical_edges(random_graph(rng, n, 0.3), n)
    g = glib.build_graph(n, ce)
    batch = build_partition_batch(
        g, sequential_partition(g, max(8, len(ce) // 4)))
    # full cover: the scoped enumeration IS the whole working graph
    assert batch.tri_total == len(list_triangles_np(g))
    assert 0 <= batch.tri_assigned <= batch.tri_total
    assert batch.tri_est >= 0
    assert batch.tri_locality == pytest.approx(
        batch.tri_assigned / batch.tri_total if batch.tri_total else 1.0)
    # one part captures everything
    whole = build_partition_batch(g, [np.nonzero(g.deg > 0)[0].astype(np.int32)])
    assert whole.tri_locality == 1.0


def test_partition_batch_scoped_enumeration_on_partial_cover(rng):
    """With a partial cover, tri_total counts exactly the triangles of the
    NS-union subgraph (what the round reads), and the assigned triangles
    still route to the unique part holding >= 2 vertices."""
    n = 36
    ce = glib.canonical_edges(random_graph(rng, n, 0.3), n)
    g = glib.build_graph(n, ce)
    # cover only half the active vertices with sequential blocks
    half = np.nonzero(g.deg > 0)[0][: max(2, (g.deg > 0).sum() // 2)]
    sub_parts = plib._pack_cost_bounded(
        half, g.deg.astype(np.int64), max(8, len(ce) // 4))
    batch = build_partition_batch(g, sub_parts)
    in_part = np.zeros(n, bool)
    for P in sub_parts:
        in_part[P] = True
    e = ce.astype(np.int64)
    in_ns = in_part[e[:, 0]] | in_part[e[:, 1]]
    ref = glib.build_graph(n, ce[in_ns])
    assert batch.tri_total == len(list_triangles_np(ref))
    assert batch.tri_assigned <= batch.tri_total


@pytest.mark.parametrize("budget_frac", [0.15, 0.4])
def test_locality_engines_match_oracle(rng, budget_frac):
    for trial in range(3):
        n = 22 + 7 * trial
        ce = glib.canonical_edges(random_graph(rng, n, 0.3), n)
        if len(ce) < 3:
            continue
        oracle = alg2_truss(n, ce)
        budget = max(4, int(len(ce) * budget_frac))
        res = bottom_up_decompose(n, ce, budget, partitioner="locality")
        assert (res.phi == oracle).all()
        sup = edge_support_np(glib.build_graph(n, ce))
        ps = partitioned_support(n, ce, budget, partitioner="locality")
        assert (ps == sup).all()
        td = top_down_decompose(n, ce, budget=budget, partitioner="locality")
        assert (td.phi == oracle).all()


def test_wildly_wrong_triangle_estimate_only_costs_rounds(rng, monkeypatch):
    """Regression: the cost model steers locality, never correctness — a
    partitioner whose triangle estimate is garbage (reversed, huge,
    zero) must still yield phi identical to the oracle and respect the
    NS budget."""
    n = 32
    ce = glib.canonical_edges(random_graph(rng, n, 0.3), n)
    oracle = alg2_truss(n, ce)
    budget = max(8, len(ce) // 4)

    def wrong_estimate(graph):
        rng2 = np.random.default_rng(99)
        return rng2.integers(0, 10**9, size=graph.n)

    monkeypatch.setattr(plib, "closed_wedge_estimate", wrong_estimate)
    g = glib.build_graph(n, ce)
    parts = locality_partition(g, budget)
    for P in parts:
        assert len(glib.neighborhood_subgraph(g, P)[0]) <= budget \
            or len(P) == 1
    res = bottom_up_decompose(n, ce, budget, partitioner="locality")
    assert (res.phi == oracle).all()
    td = top_down_decompose(n, ce, budget=budget, partitioner="locality")
    assert (td.phi == oracle).all()


# ---------------------------------------------------------------------------
# stage-2 candidate pipeline (DESIGN.md §11)
# ---------------------------------------------------------------------------

def test_stage2_pipeline_overlaps_and_matches_oracle(rng):
    """Graphs with several consecutive k-classes drive the stage-2
    prebuild path: the overlapped counter must advance and phi stay
    exact on both drivers."""
    edges = np.concatenate([
        clique_edges(0, 9), clique_edges(6, 7),   # overlapping cliques
        random_graph(rng, 20, 0.25) + 12,
    ])
    n = 32
    ce = glib.canonical_edges(edges, n)
    oracle = alg2_truss(n, ce)
    budget = max(8, len(ce) // 4)
    res = bottom_up_decompose(n, ce, budget)
    assert (res.phi == oracle).all()
    assert res.stats.stage2_overlapped > 0
    td = top_down_decompose(n, ce, budget=budget)
    assert (td.phi == oracle).all()
    assert td.stats.stage2_overlapped > 0
    # the full-memory top-down path pipelines too
    td2 = top_down_decompose(n, ce)
    assert (td2.phi == oracle).all()
    assert td2.stats.stage2_overlapped > 0


def test_local_threshold_peel_alive0_equals_prefiltered(rng):
    """Passing a dead-edge mask must equal physically removing those edges
    and re-indexing — the fixup contract the stage-2 pipeline relies on."""
    n = 24
    ce = glib.canonical_edges(random_graph(rng, n, 0.4), n)
    g = glib.build_graph(n, ce)
    tris = list_triangles_np(g)
    removable = rng.random(g.m) < 0.7
    dead = rng.random(g.m) < 0.25
    alive0 = ~dead
    t_alive = alive0[tris[:, 0]] & alive0[tris[:, 1]] & alive0[tris[:, 2]]
    sup = support_from_triangle_list(tris[t_alive], g.m).astype(np.int32)
    for thresh in (0, 1, 3):
        alive_m, removed_m, _ = local_threshold_peel(
            sup, tris, removable, thresh, alive0=alive0)
        # reference: rebuild on the surviving edge set
        keep_ids = np.nonzero(alive0)[0]
        tris_ref = glib.compact_index(keep_ids, tris[t_alive])
        sup_ref = support_from_triangle_list(
            tris_ref, len(keep_ids)).astype(np.int32)
        alive_r, removed_r, _ = local_threshold_peel(
            sup_ref, tris_ref, removable[keep_ids], thresh)
        assert (alive_m[keep_ids] == alive_r).all()
        assert (removed_m[keep_ids] == removed_r).all()
        # dead edges never resurface in either mask
        assert not alive_m[dead].any()
        assert not removed_m[dead].any()


def test_stage2_superset_candidate_is_sound(rng):
    """The pipeline peels NS(U') for a SUPERSET U' of the true U_k (built
    before the previous level's removals landed).  Emulate the extreme
    case — U' = all vertices — and check the removed set still equals the
    exact class."""
    from repro.core.peel import peel_threshold_dense
    import jax.numpy as jnp

    n = 26
    ce = glib.canonical_edges(random_graph(rng, n, 0.35), n)
    oracle = alg2_truss(n, ce)
    g = glib.build_graph(n, ce)
    tris = list_triangles_np(g)
    sup = support_from_triangle_list(tris, g.m).astype(np.int32)
    if len(tris) == 0:
        tris = np.full((1, 3), g.m, np.int32)
    kmin = int(oracle.min())
    # peel the whole graph (maximal superset candidate) at the first
    # class's threshold: removals must be exactly that class
    _, _, removed = peel_threshold_dense(
        jnp.asarray(sup), jnp.asarray(tris), jnp.ones(g.m, bool),
        jnp.ones(g.m, bool), jnp.int32(kmin - 2))
    assert (np.asarray(removed) == (oracle == kmin)).all()


# ---------------------------------------------------------------------------
# double-buffered rounds: non-blocking dispatch path
# ---------------------------------------------------------------------------

def test_peel_classes_batched_nonblocking_matches_blocking(rng):
    n = 40
    ce = glib.canonical_edges(random_graph(rng, n, 0.3), n)
    g = glib.build_graph(n, ce)
    batch = build_partition_batch(
        g, sequential_partition(g, max(8, len(ce) // 4)))
    for bucket in batch.buckets:
        phi_b, st_b, _ = peel_classes_batched(
            bucket.sup, bucket.tris, bucket.indptr, bucket.tids, bucket.alive)
        handle = peel_classes_batched(
            bucket.sup, bucket.tris, bucket.indptr, bucket.tids, bucket.alive,
            blocking=False)
        assert isinstance(handle, PendingPeel)
        phi_nb, st_nb = handle.result()
        assert (phi_nb == phi_b).all()
        assert (st_nb == st_b).all()
        # result() is cached, not re-dispatched
        assert handle.result() is handle.result()


def test_local_threshold_peel_nonblocking_matches_blocking(rng):
    n = 24
    ce = glib.canonical_edges(random_graph(rng, n, 0.4), n)
    g = glib.build_graph(n, ce)
    tris = list_triangles_np(g)
    sup = support_from_triangle_list(tris, g.m).astype(np.int32)
    removable = rng.random(g.m) < 0.7
    for thresh in (0, 2, 5):
        alive_b, removed_b, _ = local_threshold_peel(
            sup, tris, removable, thresh)
        handle = local_threshold_peel(
            sup, tris, removable, thresh, blocking=False)
        alive_nb, removed_nb = handle.result()
        assert (alive_nb == alive_b).all()
        assert (removed_nb == removed_b).all()
    # triangle-free short-circuit honors the contract too, incl. alive0
    h = local_threshold_peel(np.zeros(4, np.int32),
                             np.zeros((0, 3), np.int32),
                             np.ones(4, bool), 0, blocking=False)
    alive_nb, removed_nb = h.result()
    assert removed_nb.all() and not alive_nb.any()
    alive_nb, removed_nb, _ = local_threshold_peel(
        np.zeros(4, np.int32), np.zeros((0, 3), np.int32),
        np.ones(4, bool), 0,
        alive0=np.array([True, False, True, False]))
    assert (removed_nb == np.array([True, False, True, False])).all()
    assert not alive_nb.any()


def test_shape_cache_compile_counter_nonblocking(rng):
    n = 30
    ce = glib.canonical_edges(random_graph(rng, n, 0.35), n)
    g = glib.build_graph(n, ce)
    batch = build_partition_batch(
        g, sequential_partition(g, max(8, len(ce) // 3)))
    cache: set = set()
    bucket = batch.buckets[0]
    h1 = peel_classes_batched(bucket.sup, bucket.tris, bucket.indptr,
                              bucket.tids, bucket.alive,
                              shape_cache=cache, blocking=False)
    h2 = peel_classes_batched(bucket.sup, bucket.tris, bucket.indptr,
                              bucket.tids, bucket.alive,
                              shape_cache=cache, blocking=False)
    # new_compile is known at dispatch, before any result() blocks
    assert h2.new_compile is False
    assert (h1.result()[0] == h2.result()[0]).all()


def test_pipeline_overlap_counter(rng):
    """Multi-round runs consume each round one round late: all but the
    final consumed round overlapped the next round's host build."""
    n = 45
    ce = glib.canonical_edges(random_graph(rng, n, 0.25), n)
    res = lower_bounding(n, ce, budget=max(8, len(ce) // 6))
    st = res.stats
    assert st.overlapped >= 0
    if st.rounds > 1:
        # every yielded round except the last was consumed after the
        # following round had been built and dispatched
        assert st.overlapped >= 1
