"""Pod-spanning OOC rounds (DESIGN.md §10): the batched engines with bucket
lanes routed through shard_map must produce phi identical to the
single-device batched engine (and the serial oracle).

The in-process tests run on a mesh over whatever devices the ambient
process has (1 locally; 8 in the CI sharded job, which sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax init) —
the shard_map code path is identical either way.  The 8-device corpus
equality, the uneven-lane bucket split and the non-blocking double-buffered
round are additionally forced in a subprocess (device count locks at first
jax init), mirroring ``test_distributed.py``.
"""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import graph as glib
from repro.core.bottom_up import bottom_up_decompose
from repro.core.partition import build_partition_batch, sequential_partition
from repro.core.peel import (local_threshold_peel, peel_classes_batched,
                             truss_decompose)
from repro.core.serial import alg2_truss
from repro.core.support import list_triangles_np, support_from_triangle_list
from repro.core.top_down import top_down_decompose
from tests.conftest import er_graph, random_graph

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((len(jax.devices()),), ("data",))


def _graph(rng, n=26, p=0.3):
    n, ce = er_graph(rng, n, p)
    assert len(ce) >= 3
    return ce, n


def test_bottom_up_sharded_matches_oracle_and_single(rng, mesh):
    ce, n = _graph(rng)
    oracle = alg2_truss(n, ce)
    budget = max(8, len(ce) // 4)
    res_s = bottom_up_decompose(n, ce, budget, mesh=mesh)
    res_1 = bottom_up_decompose(n, ce, budget)
    assert (res_s.phi == oracle).all()
    assert (res_s.phi == res_1.phi).all()
    # the double-buffered (blocking=False) path IS the driver's only path,
    # so overlapped rounds prove the PendingPeel pipeline ran sharded
    assert res_s.stats.sharded_rounds > 0
    assert res_s.stats.devices == len(jax.devices())
    assert res_1.stats.sharded_rounds == 0 and res_1.stats.devices == 1
    # the stage-2 candidate pipeline (DESIGN.md §11) is control-flow
    # identical across the mesh: same levels prebuilt either way
    assert res_s.stats.stage2_overlapped == res_1.stats.stage2_overlapped


def test_top_down_sharded_matches_oracle(rng, mesh):
    ce, n = _graph(rng)
    oracle = alg2_truss(n, ce)
    budget = max(8, len(ce) // 4)
    td = top_down_decompose(n, ce, budget=budget, mesh=mesh)
    assert (td.phi == oracle).all()
    assert td.stats.sharded_rounds > 0
    assert td.stats.devices == len(jax.devices())
    # without a budget the candidate peels still span the mesh
    td2 = top_down_decompose(n, ce, mesh=mesh)
    assert (td2.phi == oracle).all()
    assert td2.stats.sharded_rounds > 0


def test_truss_decompose_mesh_dispatch(rng, mesh):
    ce, n = _graph(rng)
    oracle = alg2_truss(n, ce)
    for engine in ("bottom-up", "top-down"):
        phi, st = truss_decompose(n, ce, engine=engine, memory_budget=48,
                                  mesh=mesh, with_stats=True)
        assert (phi == oracle).all(), engine
        assert st.sharded_rounds > 0, engine


def test_mesh_rejected_on_perpart_engine(rng, mesh):
    ce, n = _graph(rng)
    with pytest.raises(ValueError, match="batched engine"):
        bottom_up_decompose(n, ce, 32, engine="perpart", mesh=mesh)


def test_bucket_sharded_matches_single_device(rng, mesh):
    """Direct bucket-level equality, including uneven lane counts: with
    ``pad_lanes_pow2=False`` the lane count is whatever the packer produced,
    so the sharded dispatcher must pad to a device multiple and slice the
    result back to the caller's B."""
    ce, n = _graph(rng, n=40)
    g = glib.build_graph(n, ce)
    parts = sequential_partition(g, budget=max(8, len(ce) // 6))
    batch = build_partition_batch(g, parts, pad_lanes_pow2=False)
    assert batch.buckets
    for bucket in batch.buckets:
        phi_s, st_s, _ = peel_classes_batched(
            bucket.sup, bucket.tris, bucket.indptr, bucket.tids,
            bucket.alive, mesh=mesh)
        phi_1, st_1, _ = peel_classes_batched(
            bucket.sup, bucket.tris, bucket.indptr, bucket.tids,
            bucket.alive)
        assert phi_s.shape == phi_1.shape == bucket.sup.shape
        assert (phi_s == phi_1).all()
        assert st_s.shape == st_1.shape


def test_sharded_nonblocking_pending(rng, mesh):
    ce, n = _graph(rng)
    g = glib.build_graph(n, ce)
    parts = sequential_partition(g, budget=max(8, len(ce) // 3))
    batch = build_partition_batch(g, parts)
    bucket = max(batch.buckets, key=lambda b: b.real_edges)
    handle = peel_classes_batched(
        bucket.sup, bucket.tris, bucket.indptr, bucket.tids, bucket.alive,
        mesh=mesh, blocking=False)
    phi_ref, _, _ = peel_classes_batched(
        bucket.sup, bucket.tris, bucket.indptr, bucket.tids, bucket.alive)
    phi, st = handle.result()
    assert handle.sharded
    assert (phi == phi_ref).all()
    # result is cached, not re-finalized
    assert handle.result()[0] is phi


def test_local_threshold_peel_sharded_matches(rng, mesh):
    ce, n = _graph(rng, n=24, p=0.4)
    g = glib.build_graph(n, ce)
    tris = list_triangles_np(g)
    sup = support_from_triangle_list(tris, g.m).astype(np.int32)
    removable = rng.random(g.m) < 0.7
    for thresh in (0, 1, 2, 4):
        alive_s, rem_s, _ = local_threshold_peel(
            sup, tris, removable, thresh, mesh=mesh)
        alive_1, rem_1, _ = local_threshold_peel(
            sup, tris, removable, thresh)
        assert (alive_s == alive_1).all(), thresh
        assert (rem_s == rem_1).all(), thresh


# ---------------------------------------------------------------------------
# forced 8-device corpus equality (subprocess: device count locks at init)
# ---------------------------------------------------------------------------

def _run(code: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=_ROOT)
    assert p.returncode == 0, p.stdout[-3000:] + p.stderr[-3000:]
    return p.stdout


def test_sharded_rounds_8_devices():
    """phi-equality vs the single-device batched engine on a corpus shaped
    like the test_ooc_property graphs, with 8 real shards: both drivers,
    two partitioners, a non-blocking round and an uneven-lane bucket."""
    out = _run("""
        import jax, numpy as np
        mesh = jax.make_mesh((8,), ("data",))
        from repro.core import graph as glib
        from repro.core.serial import alg2_truss
        from repro.core.bottom_up import bottom_up_decompose
        from repro.core.top_down import top_down_decompose
        from repro.core.partition import (build_partition_batch,
                                          sequential_partition)
        from repro.core.peel import peel_classes_batched
        rng = np.random.default_rng(7)
        for trial, (n, dens) in enumerate([(20, 0.35), (26, 0.25)]):
            iu = np.triu_indices(n, 1)
            keep = rng.random(len(iu[0])) < dens
            ce = glib.canonical_edges(np.stack(iu, 1)[keep], n)
            oracle = alg2_truss(n, ce)
            budget = max(8, len(ce) // 4)
            part = ("sequential", "locality")[trial % 2]
            res_s = bottom_up_decompose(n, ce, budget, partitioner=part,
                                        mesh=mesh)
            res_1 = bottom_up_decompose(n, ce, budget, partitioner=part)
            assert (res_s.phi == oracle).all()
            assert (res_s.phi == res_1.phi).all()
            assert res_s.stats.sharded_rounds > 0
            assert res_s.stats.devices == 8
            td = top_down_decompose(n, ce, budget=budget, mesh=mesh)
            assert (td.phi == oracle).all()
            assert td.stats.sharded_rounds > 0
        # uneven lane count: the dispatcher pads to a multiple of 8 and
        # slices back; a non-blocking handle drives the same path
        g = glib.build_graph(n, ce)
        parts = sequential_partition(g, budget=max(8, len(ce) // 6))
        batch = build_partition_batch(g, parts, pad_lanes_pow2=False)
        uneven = [b for b in batch.buckets if b.n_lanes % 8]
        assert uneven, [b.n_lanes for b in batch.buckets]
        for bucket in uneven:
            h = peel_classes_batched(
                bucket.sup, bucket.tris, bucket.indptr, bucket.tids,
                bucket.alive, mesh=mesh, blocking=False)
            phi_1, _, _ = peel_classes_batched(
                bucket.sup, bucket.tris, bucket.indptr, bucket.tids,
                bucket.alive)
            phi_s, _ = h.result()
            assert h.sharded
            assert phi_s.shape == phi_1.shape
            assert (phi_s == phi_1).all()
        print("SHARDED-OOC-OK")
    """)
    assert "SHARDED-OOC-OK" in out


# ---------------------------------------------------------------------------
# hypothesis sweep (CI): the test_ooc_property corpus, sharded vs single
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:

    @st.composite
    def graphs(draw, max_n=26):
        # same corpus shape as tests/test_ooc_property.py
        n = draw(st.integers(4, max_n))
        density = draw(st.floats(0.1, 0.6))
        seed = draw(st.integers(0, 2**31 - 1))
        g_rng = np.random.default_rng(seed)
        iu = np.triu_indices(n, 1)
        keep = g_rng.random(len(iu[0])) < density
        return n, np.stack(iu, 1)[keep]

    @settings(max_examples=8, deadline=None)
    @given(graphs(), st.sampled_from(["sequential", "locality"]),
           st.sampled_from([0.2, 0.5]))
    def test_sharded_property_corpus(g, partitioner, budget_frac):
        n, edges = g
        ce = glib.canonical_edges(edges, n)
        if len(ce) < 3:
            return
        mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        budget = max(4, int(len(ce) * budget_frac))
        res_s = bottom_up_decompose(n, ce, budget, partitioner=partitioner,
                                    mesh=mesh)
        res_1 = bottom_up_decompose(n, ce, budget, partitioner=partitioner)
        assert (res_s.phi == res_1.phi).all()
        assert (res_s.phi == alg2_truss(n, ce)).all()
        td = top_down_decompose(n, ce, budget=budget,
                                partitioner=partitioner, mesh=mesh)
        assert (td.phi == res_1.phi).all()
