"""Incremental truss maintenance (DESIGN.md §16): ``truss_maintain`` must
produce φ bit-identical to a full recompute on the post-edit edge set, for
every conformance-corpus graph, under insert-only / delete-only / mixed
edit batches — including edits that raise or lower trussness, edits routed
through a spilled :class:`ChunkedDiskStore` graph, and batches interrupted
mid-maintenance (injected error and SIGKILL) then resumed.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import faults
from repro.core import graph as glib
from repro.core.graph import build_graph, edge_id_lookup, undirected_csr
from repro.core.maintain import EditBatch, truss_maintain
from repro.core.peel import truss_decompose
from repro.core.serial import alg2_truss
from tests.conftest import clique_edges, conformance_corpus

CORPUS = conformance_corpus()
_PHI0 = {name: alg2_truss(n, ce) for name, n, ce in CORPUS}


def _existing(rng, n, ce, k):
    """k distinct (u, v) pairs drawn from the current edge list."""
    k = min(k, len(ce))
    ids = rng.choice(len(ce), size=k, replace=False)
    return [tuple(int(x) for x in ce[i]) for i in ids]


def _absent(rng, n, ce, k):
    """k distinct canonical (u, v) pairs NOT in the current edge list."""
    present = {tuple(e) for e in np.asarray(ce).tolist()}
    out = []
    while len(out) < k:
        u, v = (int(x) for x in rng.integers(0, n, 2))
        if u == v:
            continue
        a, b = min(u, v), max(u, v)
        if (a, b) in present:
            continue
        present.add((a, b))
        out.append((a, b))
    return out


def _check(n, ce, phi0, steps, **kwargs):
    """Maintain, then pin φ AND the maintained edge list to the oracle."""
    res = truss_maintain((n, ce), phi0, steps, **kwargs)
    s = {tuple(e) for e in np.asarray(ce).tolist()}
    for op, u, v in steps:
        a, b = min(int(u), int(v)), max(int(u), int(v))
        if op == "delete":
            s.discard((a, b))
        elif a != b:
            s.add((a, b))
    exp_edges = glib.canonical_edges(
        np.asarray(sorted(s), np.int64).reshape(-1, 2), n)
    assert (res.graph.edges == exp_edges).all()
    assert (res.phi == alg2_truss(n, exp_edges)).all()
    return res


@pytest.mark.parametrize("name,n,ce", CORPUS, ids=[c[0] for c in CORPUS])
def test_differential_insert_only(name, n, ce):
    rng = np.random.default_rng(11)
    steps = [("insert", u, v) for u, v in _absent(rng, n, ce, 4)]
    res = _check(n, ce, _PHI0[name], steps)
    assert res.stats.edits_applied == 4


@pytest.mark.parametrize("name,n,ce", CORPUS, ids=[c[0] for c in CORPUS])
def test_differential_delete_only(name, n, ce):
    if not len(ce):
        pytest.skip("no edges to delete")
    rng = np.random.default_rng(13)
    steps = [("delete", u, v) for u, v in _existing(rng, n, ce, 4)]
    res = _check(n, ce, _PHI0[name], steps)
    assert res.stats.edits_applied == len(steps)


@pytest.mark.parametrize("name,n,ce", CORPUS, ids=[c[0] for c in CORPUS])
def test_differential_mixed(name, n, ce):
    if not len(ce):
        pytest.skip("no edges to delete")
    rng = np.random.default_rng(17)
    dels = [("delete", u, v) for u, v in _existing(rng, n, ce, 3)]
    ins = [("insert", u, v) for u, v in _absent(rng, n, ce, 3)]
    steps = [s for pair in zip(dels, ins) for s in pair]   # interleaved
    _check(n, ce, _PHI0[name], steps)


def test_insert_raises_trussness():
    """Completing an almost-clique promotes the surviving edges — the
    k-raising direction must propagate past the inserted edge itself."""
    n, size = 6, 6
    full = glib.canonical_edges(clique_edges(0, size), n)
    hole = full[1:]                          # K6 minus one edge
    phi0 = alg2_truss(n, hole)
    u, v = (int(x) for x in full[0])
    res = _check(n, hole, phi0, [("insert", u, v)])
    assert int(res.phi.max()) > int(phi0.max())
    assert (res.phi == size).all()           # K6: every edge has φ = 6


def test_delete_lowers_trussness():
    """Breaking a clique demotes its edges — the k-lowering direction must
    reach edges far from the deleted one."""
    n, size = 6, 6
    full = glib.canonical_edges(clique_edges(0, size), n)
    phi0 = alg2_truss(n, full)
    u, v = (int(x) for x in full[0])
    res = _check(n, full, phi0, [("delete", u, v)])
    assert int(res.phi.max()) < int(phi0.max())


def test_edit_batch_deletes_first():
    name, n, ce = CORPUS[0]
    rng = np.random.default_rng(19)
    dels = np.asarray(_existing(rng, n, ce, 2), np.int64)
    ins = np.asarray(_absent(rng, n, ce, 2), np.int64)
    batch = EditBatch(inserts=ins, deletes=dels)
    res = truss_maintain((n, ce), _PHI0[name], batch)
    steps = ([("delete", int(u), int(v)) for u, v in dels]
             + [("insert", int(u), int(v)) for u, v in ins])
    ref = _check(n, ce, _PHI0[name], steps)
    assert (res.phi == ref.phi).all()
    assert (res.graph.edges == ref.graph.edges).all()
    assert res.stats.edits_applied == 4


def test_noop_edits_skipped():
    """Deleting an absent edge / inserting a present one is a no-op: φ and
    the graph are untouched and ``edits_applied`` stays 0."""
    name, n, ce = CORPUS[0]
    rng = np.random.default_rng(23)
    (au, av), = _absent(rng, n, ce, 1)
    pu, pv = (int(x) for x in ce[0])
    res = truss_maintain((n, ce), _PHI0[name],
                         [("delete", au, av), ("insert", pu, pv),
                          ("insert", 4, 4)])
    assert res.stats.edits_applied == 0
    assert res.graph.m == len(ce)
    assert (res.phi == _PHI0[name]).all()


def test_bad_edit_op_rejected():
    name, n, ce = CORPUS[0]
    with pytest.raises(ValueError, match="insert.*delete|op"):
        truss_maintain((n, ce), _PHI0[name], [("upsert", 0, 1)])


def test_phi_length_mismatch_rejected():
    name, n, ce = CORPUS[0]
    with pytest.raises(ValueError, match="entries"):
        truss_maintain((n, ce), _PHI0[name][:-1], [("insert", 0, 1)])


def test_spilled_chunk_edits(tmp_path):
    """Edits against a disk-spilled graph: the splice/filter plans must
    rewrite only the touched chunks while the maintained φ stays exact."""
    from repro.core.store import ChunkedDiskStore

    name, n, ce = CORPUS[1]                  # rmat: enough edges to chunk
    rng = np.random.default_rng(29)
    dels = [("delete", u, v) for u, v in _existing(rng, n, ce, 2)]
    ins = [("insert", u, v) for u, v in _absent(rng, n, ce, 2)]
    with ChunkedDiskStore(str(tmp_path / "store"),
                          chunk_bytes=1 << 10) as store:
        res = _check(n, ce, _PHI0[name], dels + ins, store=store)
        assert res.stats.chunk_writes > 0
        assert res.stats.edits_applied == 4


def test_truss_decompose_edits_dispatch():
    """``truss_decompose(..., edits=)`` routes through maintenance; with a
    caller-supplied ``phi0`` the pre-edit decomposition is not recomputed,
    and ``phi0`` without ``edits`` is rejected."""
    name, n, ce = CORPUS[0]
    rng = np.random.default_rng(31)
    steps = ([("delete", u, v) for u, v in _existing(rng, n, ce, 2)]
             + [("insert", u, v) for u, v in _absent(rng, n, ce, 2)])
    ref = _check(n, ce, _PHI0[name], steps)
    phi1 = truss_decompose(n, ce, edits=steps)
    assert (phi1 == ref.phi).all()
    phi2, stats = truss_decompose(n, ce, edits=steps, phi0=_PHI0[name],
                                  with_stats=True)
    assert (phi2 == ref.phi).all()
    assert stats.edits_applied == 4
    with pytest.raises(ValueError, match="phi0"):
        truss_decompose(n, ce, phi0=_PHI0[name])


def test_maintain_interrupt_resume(tmp_path):
    """An injected error between edits leaves a journal the resumed call
    replays from — only the edits after the newest snapshot re-run, and
    the final φ still matches the oracle."""
    name, n, ce = CORPUS[3]
    rng = np.random.default_rng(37)
    steps = ([("delete", u, v) for u, v in _existing(rng, n, ce, 3)]
             + [("insert", u, v) for u, v in _absent(rng, n, ce, 3)])
    d = str(tmp_path / "ckpt")
    plan = faults.FaultPlan([faults.FaultRule(
        site=faults.MAINTAIN, kind="error", nth=4)])
    with faults.active(plan):
        with pytest.raises((faults.InjectedFault, OSError)):
            truss_maintain((n, ce), _PHI0[name], steps, checkpoint_dir=d,
                           checkpoint_every=1)
    res = _check(n, ce, _PHI0[name], steps, checkpoint_dir=d, resume=True)
    assert res.stats.resumed_round >= 0


def test_maintain_rejects_foreign_journal(tmp_path):
    """A maintenance resume must refuse a journal recorded by a
    decomposition run (different stage), not silently continue it."""
    name, n, ce = CORPUS[0]
    d = str(tmp_path / "ckpt")
    import warnings

    from repro.core.bottom_up import bottom_up_decompose
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        bottom_up_decompose(n, ce, budget=64, checkpoint_dir=d,
                            checkpoint_every=1)
    with pytest.raises(ValueError):
        truss_maintain((n, ce), _PHI0[name], [("insert", 0, 1)],
                       checkpoint_dir=d, resume=True)


def test_add_edges_invariants():
    """``Graph.add_edges`` splices in canonical id order with rank reuse.
    CSR orientation legitimately differs from a fresh ``build_graph`` (the
    reused ranks order old vertices by their OLD degrees), so the
    invariants here are orientation-independent: canonical edge list,
    id lookup, undirected adjacency."""
    name, n, ce = CORPUS[0]
    g = build_graph(n, ce)
    rng = np.random.default_rng(41)
    new = np.asarray(_absent(rng, n, ce, 3), np.int64)
    g1 = g.add_edges(new)
    exp = glib.canonical_edges(np.concatenate([ce, new]), n)
    assert g1.m == g.m + 3
    assert (g1.edges == exp).all()
    assert (edge_id_lookup(g1, new[:, 0], new[:, 1]) >= 0).all()
    ip1, nb1 = undirected_csr(g1)
    gf = build_graph(n, exp)
    ipf, nbf = undirected_csr(gf)
    assert (ip1 == ipf).all()
    for r in range(n):
        assert (np.sort(nb1[ip1[r]:ip1[r + 1]])
                == np.sort(nbf[ipf[r]:ipf[r + 1]])).all(), r
    # duplicates and self-loops are no-ops that return the same object
    assert g1.add_edges(new[:1]) is g1
    assert g1.add_edges(np.asarray([[5, 5]], np.int64)) is g1


_MAINT_KILL_DRIVER = r"""
import sys
import numpy as np
from repro.core import faults
from repro.core.maintain import truss_maintain
from repro.core.serial import alg2_truss
from tests.conftest import conformance_corpus

ckpt_dir, nth = sys.argv[1], int(sys.argv[2])
name, n, ce = conformance_corpus()[1]            # rmat
phi0 = alg2_truss(n, ce)
rng = np.random.default_rng(7)
present = {tuple(e) for e in np.asarray(ce).tolist()}
steps = [("delete", int(u), int(v))
         for u, v in (ce[i] for i in rng.choice(len(ce), 4, replace=False))]
while len(steps) < 8:
    u, v = (int(x) for x in rng.integers(0, n, 2))
    a, b = min(u, v), max(u, v)
    if a == b or (a, b) in present:
        continue
    present.add((a, b))
    steps.append(("insert", a, b))
if nth >= 0:
    faults.install(faults.FaultPlan([faults.FaultRule(
        site=faults.MAINTAIN, kind="kill", nth=nth)]))
res = truss_maintain((n, ce), phi0, steps, checkpoint_dir=ckpt_dir,
                     checkpoint_every=1, resume=True)
np.save(ckpt_dir + "/phi.npy", res.phi)
np.save(ckpt_dir + "/edges.npy", res.graph.edges)
"""


def _subprocess_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         os.path.join(os.path.dirname(__file__), ".."),
         env.get("PYTHONPATH", "")])
    return env


def test_sigkill_mid_maintenance_and_resume(tmp_path):
    """SIGKILL the worker between committed edits (no atexit, no finally),
    then resume in a fresh process: the replayed tail must land on the
    same φ a full recompute of the final edge set produces."""
    d = str(tmp_path / "ckpt")
    os.makedirs(d)
    env = _subprocess_env()
    kill = subprocess.run([sys.executable, "-c", _MAINT_KILL_DRIVER, d, "5"],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert kill.returncode == -9, (kill.returncode, kill.stderr[-2000:])
    assert not os.path.exists(d + "/phi.npy")    # it really died mid-batch
    resume = subprocess.run([sys.executable, "-c", _MAINT_KILL_DRIVER,
                             d, "-1"], env=env, capture_output=True,
                            text=True, timeout=600)
    assert resume.returncode == 0, resume.stderr[-2000:]
    phi = np.load(d + "/phi.npy")
    edges = np.load(d + "/edges.npy")
    name, n, ce = CORPUS[1]
    assert (phi == alg2_truss(n, edges)).all()


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                              # container has no dev deps
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    _HN = 14

    @settings(max_examples=15, deadline=None)
    @given(st.lists(
        st.tuples(st.booleans(),
                  st.integers(0, _HN - 1), st.integers(0, _HN - 1)),
        min_size=1, max_size=10))
    def test_hypothesis_edit_stream(ops):
        """Arbitrary edit streams (duplicates, self-loops, re-inserting a
        just-deleted edge, deleting a never-present one) always land on
        the full-recompute φ of the final edge set."""
        rng = np.random.default_rng(43)
        ce = glib.canonical_edges(random_edges(rng, _HN), _HN)
        steps = [("insert" if ins else "delete", u, v)
                 for ins, u, v in ops]
        _check(_HN, ce, alg2_truss(_HN, ce), steps)

    def random_edges(rng, n):
        mask = rng.random((n, n)) < 0.3
        iu = np.triu_indices(n, 1)
        return np.stack(iu, 1)[mask[iu]]
