"""Core truss decomposition: paper Figure 2, oracle agreement, invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as glib
from repro.core.kcore import cmax_core, core_decompose
from repro.core.peel import (kmax_truss, peel_classes, peel_recompute,
                             truss_decompose)
from repro.core.serial import alg1_truss, alg2_truss, verify_truss
from repro.core.support import (edge_support_jax, edge_support_np,
                                list_triangles_np)
from tests.conftest import random_graph

NAMES = {c: i for i, c in enumerate("abcdefghijkl")}
FIG2 = """a b;a c;a d;a e;b c;b d;b e;c d;c e;d e;d g;d k;d l;e f;e g;f g;
g h;g k;g l;f h;f i;f j;h i;h j;i j;i k"""
FIG2_EDGES = np.array([[NAMES[x] for x in p.split()]
                       for p in FIG2.replace("\n", "").split(";") if p.strip()])
FIG2_CLASSES = {
    2: {"ik"},
    3: set("dg dk dl ef eg fg gh gk gl".split()),
    4: set("fh fi fj hi hj ij".split()),
    5: set("ab ac ad ae bc bd be cd ce de".split()),
}


def test_canonical_edges_rejects_out_of_range_ids():
    """Regression: an explicit n smaller than the max vertex id used to
    wrap ids through the u*n+v dedup key and silently corrupt the edge
    list; it must raise instead."""
    bad = np.array([[0, 1], [2, 5]])
    with pytest.raises(ValueError, match=r"vertex id 5 but n=3"):
        glib.canonical_edges(bad, 3)
    # boundary: ids in [0, n) are fine
    ok = glib.canonical_edges(bad, 6)
    assert ok.max() == 5


def test_canonical_edges_rejects_negative_ids():
    with pytest.raises(ValueError, match="negative vertex id"):
        glib.canonical_edges(np.array([[0, 1], [-2, 3]]), 10)
    # negatives are rejected even when n is inferred
    with pytest.raises(ValueError, match="negative vertex id"):
        glib.canonical_edges(np.array([[-1, 2]]))


def test_canonical_edges_valid_inputs_unchanged():
    e = np.array([[3, 1], [1, 3], [2, 2], [0, 3]])
    ce = glib.canonical_edges(e, 4)
    # dedup, self-loop drop, u < v orientation, lexicographic order
    assert ce.tolist() == [[0, 3], [1, 3]]
    assert glib.canonical_edges(np.zeros((0, 2), np.int64), 4).shape == (0, 2)


def test_figure2_exact():
    """Reproduces the paper's running example (Figure 2) exactly."""
    n = 12
    ce = glib.canonical_edges(FIG2_EDGES, n)
    phi = truss_decompose(n, ce)
    inv = {v: k for k, v in NAMES.items()}
    got = {}
    for eid, (u, v) in enumerate(ce):
        got.setdefault(int(phi[eid]), set()).add(inv[u] + inv[v])
    assert got == FIG2_CLASSES
    assert phi.max() == 5  # k_max


def test_figure2_no_6truss():
    n = 12
    ce = glib.canonical_edges(FIG2_EDGES, n)
    kmax, t = kmax_truss(n, ce)
    assert kmax == 5 and len(t) == 10  # the 5-clique


@pytest.mark.parametrize("trial", range(10))
def test_all_algorithms_agree(rng, trial):
    for _ in range(trial + 1):
        n = int(rng.integers(5, 60))
        p = rng.uniform(0.05, 0.6)
    e = random_graph(rng, n, p)
    if len(e) == 0:
        return
    ce = glib.canonical_edges(e, n)
    a1 = alg1_truss(n, ce)
    a2 = alg2_truss(n, ce)
    bulk = truss_decompose(n, ce)
    assert (a1 == a2).all()
    assert (a2 == bulk).all()
    g = glib.build_graph(n, ce)
    tris = list_triangles_np(g)
    if len(tris) == 0:
        tris = np.full((1, 3), g.m, np.int32)
    rec = np.asarray(peel_recompute(jnp.asarray(tris), jnp.ones(g.m, bool)))
    assert (rec == a2).all()


def test_support_np_equals_jax(rng):
    e = random_graph(rng, 80, 0.15)
    g = glib.build_graph(80, glib.canonical_edges(e, 80))
    assert (edge_support_np(g) == np.asarray(edge_support_jax(g))).all()


def test_truss_definition_holds(rng):
    e = random_graph(rng, 40, 0.3)
    ce = glib.canonical_edges(e, 40)
    phi = truss_decompose(40, ce)
    assert verify_truss(40, ce, phi)


def test_truss_in_core(rng):
    """A k-truss is a (k-1)-core (paper Section 1)."""
    e = random_graph(rng, 50, 0.25)
    ce = glib.canonical_edges(e, 50)
    phi = truss_decompose(50, ce)
    core = core_decompose(50, ce)
    for eid, (u, v) in enumerate(ce):
        assert core[u] >= phi[eid] - 1
        assert core[v] >= phi[eid] - 1


def test_clique_gives_truss():
    """A planted q-clique is exactly a q-truss (paper Section 7.4)."""
    q = 7
    iu = np.triu_indices(q, 1)
    e = np.stack(iu, 1)
    phi = truss_decompose(q, e)
    assert (phi == q).all()


def test_kmax_bounds_clique(rng):
    """max-clique size <= k_max <= c_max + 1 relationships (Section 7.4)."""
    e = random_graph(rng, 40, 0.4)
    ce = glib.canonical_edges(e, 40)
    kmax, _ = kmax_truss(40, ce)
    cmax, _ = cmax_core(40, ce)
    assert kmax <= cmax + 1
