"""Substrates: optimizer, compression, checkpointing, fault-tolerant loop."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.optim import adamw, compression
from repro.runtime import train_loop as TL


def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=5, total_steps=200,
                            weight_decay=0.0)
    target = jnp.asarray(np.random.default_rng(0).standard_normal(8), jnp.float32)
    params = {"w": jnp.zeros(8)}
    state = adamw.init_state(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - target))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.update(cfg, params, state, g)
    assert float(loss(params)) < 1e-2


def test_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0 and abs(lrs[2] - 1.0) < 1e-6
    assert lrs[2] > lrs[3] > lrs[4] >= 0.1 - 1e-6


def test_zero_specs_shard_free_dim():
    from jax.sharding import PartitionSpec as P
    specs = {"w": P(None, "model")}
    shapes = {"w": jax.ShapeDtypeStruct((64, 128), jnp.float32)}
    z = adamw.zero_specs(specs, shapes, data_axes=("data",), data_size=16)
    assert z["master"]["w"] == P("data", "model")


def test_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    err = jnp.zeros(1000)
    acc = jnp.zeros(1000)
    for i in range(50):
        q, scale, err = compression.ef_compress(g_true, err)
        acc = acc + compression.dequantize(q, scale)
    # error feedback: the running mean converges to the true gradient
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g_true),
                               atol=2e-3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, tree, {"note": "x"})
    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), tree)
    out, meta = ckpt.restore(d, like)
    assert meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_k(tmp_path):
    d = str(tmp_path / "ck")
    for s in range(5):
        ckpt.save(d, s, {"x": jnp.zeros(1)}, keep=2)
    assert ckpt.all_steps(d) == [3, 4]


def test_checkpoint_atomic_tmp_ignored(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"x": jnp.zeros(1)})
    os.makedirs(os.path.join(d, "step_0000000002.tmp"))  # simulated crash
    assert ckpt.latest_step(d) == 1


def test_fault_tolerant_loop(tmp_path):
    d = str(tmp_path / "loop")
    target = jnp.asarray([3.0, -2.0])
    ocfg = adamw.AdamWConfig(lr=0.2, warmup_steps=1, total_steps=100,
                             weight_decay=0.0)

    def init_fn():
        p = {"w": jnp.zeros(2)}
        return {"params": p, "opt": adamw.init_state(p)}

    @jax.jit
    def step(state, batch):
        loss, g = jax.value_and_grad(
            lambda p: jnp.sum(jnp.square(p["w"] - batch["t"])))(state["params"])
        p, o, _ = adamw.update(ocfg, state["params"], state["opt"], g)
        return {"params": p, "opt": o}, {"loss": loss}

    armed = {"on": True}

    def fault(s):
        if s == 13 and armed["on"]:
            armed["on"] = False
            raise RuntimeError("injected")

    cfg = TL.LoopConfig(steps=30, ckpt_dir=d, ckpt_every=5, log_every=5)
    state, rows = TL.run(cfg, init_fn, step,
                         lambda s: {"t": target}, fault_hook=fault)
    assert any("restart" in r for r in rows)
    final = [r["loss"] for r in rows if "loss" in r][-1]
    assert final < 0.5
    assert ckpt.latest_step(d) == 30


def test_deterministic_data_streams():
    from repro.data.recsys_stream import RecsysStream
    from repro.data.tokens import TokenStream
    ts = TokenStream(101, 16, 8, seed=3)
    a = ts.batch(5, shard=1, n_shards=2)
    b = ts.batch(5, shard=1, n_shards=2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    rs = RecsysStream(1000, 10, 20, 8, seed=3)
    x = rs.batch(2)
    y = rs.batch(2)
    np.testing.assert_array_equal(x["hist_items"], y["hist_items"])


def test_sampler_shapes_and_mask():
    from repro.data import graphgen
    from repro.models.gnn.sampler import CSR, minibatch
    n = 50
    edges = graphgen.erdos_renyi(n, 150, seed=1)
    csr = CSR.from_edges(n, edges)
    rng = np.random.default_rng(0)
    feats = rng.standard_normal((n, 6)).astype(np.float32)
    labels = rng.integers(0, 4, n).astype(np.int32)
    mb = minibatch(csr, feats, labels, batch_nodes=8, fanouts=(4, 3), rng=rng)
    n_sub = 8 * (1 + 4 + 12)
    assert mb["node_feat"].shape == (n_sub, 6)
    assert mb["edge_index"].shape == (8 * (4 + 12), 2)
    assert mb["edge_index"].max() < n_sub
    assert mb["label_mask"].sum() == 8


def test_truss_sparsify_features():
    from repro.core.sparsify import (clique_upper_bound, sampling_weights,
                                     truss_filter, trussness_features)
    from repro.data import graphgen
    edges = graphgen.planted_cliques(60, 2, 6, 60, seed=0)
    t6 = truss_filter(60, edges, 6)
    assert len(t6) >= 2 * 15 - 15  # at least one clique survives
    _, feats = trussness_features(60, edges)
    assert feats.min() >= 0 and feats.max() <= 1
    w = sampling_weights(60, edges)
    assert abs(w.sum() - 1.0) < 1e-5
    assert clique_upper_bound(60, edges) >= 6
