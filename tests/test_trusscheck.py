"""trusscheck: golden positive/negative fixtures per rule, the historical
bug reproductions the rules codify (PR 3 / PR 4 / PR 6), --fix round
trips, and the self-run gate (the repo must check clean, DESIGN.md §14).

The fixture tests drive :func:`repro.analysis.check_paths` on snippets
written under a tmp tree shaped like the repo (``src/repro/...``) so the
path-scoped rules (library roots, hot modules, required fault hooks) see
the layout they key on.
"""

import ast
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro import analysis
from repro.analysis.fixes import apply_fixes

REPO_ROOT = Path(__file__).resolve().parents[1]


def _write(tmp_path, source, rel="src/repro/mod.py"):
    f = tmp_path / rel
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(source), encoding="utf-8")
    return f


def _check(tmp_path, source, *, rel="src/repro/mod.py", only=None):
    f = _write(tmp_path, source, rel)
    return f, analysis.check_paths([str(f)], only=only)


def _ids(report):
    return sorted(f.rule_id for f in report.active)


# ---------------------------------------------------------------------------
# TRK102 falsy-zero guards (the PR-3 class)
# ---------------------------------------------------------------------------

PR3_BUG = """
    def truss_decompose(g, memory_budget=None):
        if memory_budget:   # BUG: 0 silently routed to the default engine
            return "out-of-core"
        return "in-memory"
"""

PR3_FIXED = """
    def truss_decompose(g, memory_budget=None):
        if memory_budget is not None and memory_budget <= 0:
            raise ValueError(f"memory_budget must be positive, got "
                             f"{memory_budget!r}")
        if memory_budget is not None:
            return "out-of-core"
        return "in-memory"
"""


def test_trk102_flags_the_pr3_budget_fallback(tmp_path):
    _, report = _check(tmp_path, PR3_BUG, only=["TRK102"])
    assert _ids(report) == ["TRK102"]
    assert "memory_budget" in report.active[0].message


def test_trk102_clean_on_the_pr3_fix(tmp_path):
    _, report = _check(tmp_path, PR3_FIXED, only=["TRK102"])
    assert _ids(report) == []


def test_trk102_flags_or_default_and_annotation_suspects(tmp_path):
    _, report = _check(tmp_path, """
        def pack(lane_capacity=None, depth: int | None = None):
            cap = lane_capacity or 1
            d = depth or 4
            return cap + d
    """, only=["TRK102"])
    # `lane_capacity` matches the name patterns; `depth` only via its
    # `int | None` annotation — both or-defaults swallow a legitimate 0
    assert _ids(report) == ["TRK102", "TRK102"]


def test_trk102_ignores_non_numeric_names(tmp_path):
    _, report = _check(tmp_path, """
        def load(path=None, verbose=False):
            if path:
                return path
            if verbose:
                print("default")
            return "default"
    """, only=["TRK102"])
    assert _ids(report) == []


# ---------------------------------------------------------------------------
# TRK103 bare asserts (the PR-6 class)
# ---------------------------------------------------------------------------

PR6_BUG = """
    def restore(blob):
        assert blob["magic"] == 7, "corrupt snapshot"   # erased under -O
        return blob["state"]
"""


def test_trk103_flags_the_pr6_bare_assert(tmp_path):
    _, report = _check(tmp_path, PR6_BUG, only=["TRK103"])
    assert _ids(report) == ["TRK103"]


def test_trk103_clean_on_typed_raise(tmp_path):
    _, report = _check(tmp_path, """
        def restore(blob):
            if blob["magic"] != 7:
                raise ValueError("corrupt snapshot")
            return blob["state"]
    """, only=["TRK103"])
    assert _ids(report) == []


def test_trk103_scoped_to_library_roots(tmp_path):
    # same assert outside src/repro (tests, scripts) is fine
    _, report = _check(tmp_path, PR6_BUG, rel="scratch/helper.py",
                       only=["TRK103"])
    assert _ids(report) == []


# ---------------------------------------------------------------------------
# TRK101 donation safety (the PR-4 class)
# ---------------------------------------------------------------------------

PR4_BUG = """
    import jax

    peel_step = jax.jit(lambda s, t: s, donate_argnums=(0,))

    def finalize_with_retry(sup, tris):
        for attempt in range(2):
            try:
                return peel_step(sup, tris)   # retry re-donates dead memory
            except RuntimeError:
                continue
        raise RuntimeError("gave up")
"""

PR4_FIXED = """
    import jax

    peel_step = jax.jit(lambda s, t: s, donate_argnums=(0,))

    def finalize_with_retry(sup_host, tris):
        for attempt in range(2):
            try:
                sup = jax.numpy.asarray(sup_host)   # rebuilt every attempt
                return peel_step(sup, tris)
            except RuntimeError:
                continue
        raise RuntimeError("gave up")
"""


def test_trk101_flags_the_pr4_donated_retry(tmp_path):
    _, report = _check(tmp_path, PR4_BUG, only=["TRK101"])
    assert "TRK101" in _ids(report)
    assert "sup" in report.active[0].message


def test_trk101_clean_when_buffer_rebuilt_per_iteration(tmp_path):
    _, report = _check(tmp_path, PR4_FIXED, only=["TRK101"])
    assert _ids(report) == []


def test_trk101_flags_read_after_donation(tmp_path):
    _, report = _check(tmp_path, """
        import jax

        step = jax.jit(lambda x: x, donate_argnums=(0,))

        def drive(buf):
            out = step(buf)
            return out + buf.sum()   # buf was consumed by the donation
    """, only=["TRK101"])
    assert _ids(report) == ["TRK101"]


def test_trk101_fresh_expression_arguments_are_safe(tmp_path):
    _, report = _check(tmp_path, """
        import jax
        import jax.numpy as jnp

        step = jax.jit(lambda x: x, donate_argnums=(0,))

        def drive(host_buf):
            for _ in range(3):
                out = step(jnp.asarray(host_buf))   # new buffer every call
            return out
    """, only=["TRK101"])
    assert _ids(report) == []


def test_trk101_donate_argnames_resolves_to_position(tmp_path):
    # donate_argnames names a position-1 parameter; the read-after-donation
    # must be caught at that position, not at the position-0 convention
    _, report = _check(tmp_path, """
        import jax
        from functools import partial

        @partial(jax.jit, donate_argnames=("state",))
        def advance(cfg, state):
            return state

        def drive(cfg, state):
            out = advance(cfg, state)
            return out + state.sum()   # state was donated at position 1
    """, only=["TRK101"])
    assert _ids(report) == ["TRK101"]
    assert "state" in report.active[0].message


def test_trk101_donate_argnames_no_false_positive_at_position_0(tmp_path):
    # only `state` (position 1) donates; reading the position-0 arg after
    # the call is safe — the old (0,) fallback would flag `cfg` here
    _, report = _check(tmp_path, """
        import jax

        def advance(cfg, state):
            return state

        advance_j = jax.jit(advance, donate_argnames="state")

        def drive(cfg, state):
            out = advance_j(cfg, state)
            return out + cfg.sum()   # cfg (position 0) is NOT donated
    """, only=["TRK101"])
    assert _ids(report) == []


def test_trk101_donate_argnames_resolves_lambda_params(tmp_path):
    _, report = _check(tmp_path, """
        import jax

        step = jax.jit(lambda cfg, buf: buf, donate_argnames=("buf",))

        def drive(cfg, buf):
            out = step(cfg, buf)
            return out + buf.sum()   # buf donated at position 1
    """, only=["TRK101"])
    assert _ids(report) == ["TRK101"]


# ---------------------------------------------------------------------------
# TRK104 recompile hazards (the PR-7 shape discipline)
# ---------------------------------------------------------------------------

def test_trk104_flags_undisciplined_loop_dispatch(tmp_path):
    _, report = _check(tmp_path, """
        def rounds(batches):
            for batch in batches:
                out = peel_classes_batched(batch)
            return out
    """, only=["TRK104"])
    assert _ids(report) == ["TRK104"]
    assert "shape_cache" in report.active[0].message


def test_trk104_clean_with_shape_cache_or_outside_loops(tmp_path):
    _, report = _check(tmp_path, """
        def rounds(batches, cache):
            for batch in batches:
                out = peel_classes_batched(batch, shape_cache=cache)
            once = peel_classes_batched(batches[0])   # no loop, no hazard
            return out, once
    """, only=["TRK104"])
    assert _ids(report) == []


def test_trk104_flags_local_jit_binding_with_loop_varying_args(tmp_path):
    # the class the first rule missed: the jitted callable is defined in
    # the same file (no config entry), and its in-loop argument shrinks
    # every iteration — each iteration is a fresh trace + compile
    _, report = _check(tmp_path, """
        import jax

        step = jax.jit(lambda x: x.sum())

        def drive(frontiers):
            total = 0
            for f in frontiers:
                total += step(f[f >= 0])   # compacted: new shape per round
            return total
    """, only=["TRK104"])
    assert _ids(report) == ["TRK104"]
    assert "`step`" in report.active[0].message
    assert "`f`" in report.active[0].message


def test_trk104_flags_jit_decorated_def_called_in_loop(tmp_path):
    _, report = _check(tmp_path, """
        import jax
        from functools import partial

        @partial(jax.jit, static_argnums=())
        def fold(acc, x):
            return acc + x

        def drive(chunks):
            acc = 0
            for c in chunks:
                acc = fold(acc, c)
            return acc
    """, only=["TRK104"])
    assert _ids(report) == ["TRK104"]
    assert "`fold`" in report.active[0].message


def test_trk104_local_jit_clean_with_loop_invariant_args(tmp_path):
    # every argument is bound outside the loop: one trace, N cache hits —
    # hoisting isn't required when the shapes cannot vary
    _, report = _check(tmp_path, """
        import jax

        step = jax.jit(lambda x: x * 2)

        def drive(x0, n):
            for _ in range(n):
                y = step(x0)
            return y
    """, only=["TRK104"])
    assert _ids(report) == []


def test_trk104_local_jit_allowlisted_with_shape_invariant(tmp_path):
    _, report = _check(tmp_path, """
        import jax

        step = jax.jit(lambda x: x * 2)

        def drive(x):
            for _ in range(3):
                # trusscheck: allow[TRK104] -- x is loop-carried with a fixed shape
                x = step(x)
            return x
    """, only=["TRK104"])
    assert report.errors == []
    assert [f.rule_id for f in report.findings if f.allowlisted] == ["TRK104"]


# ---------------------------------------------------------------------------
# TRK105 host syncs in the hot round loops
# ---------------------------------------------------------------------------

HOT = "src/repro/core/peel.py"

def test_trk105_flags_loop_sync_in_hot_module(tmp_path):
    _, report = _check(tmp_path, """
        import jax

        step = jax.jit(lambda x: x)

        def drive(xs):
            out = None
            for x in xs:
                out = step(x)
                n = int(out)   # blocks the double-buffered pipeline
            return out
    """, rel=HOT, only=["TRK105"])
    assert _ids(report) == ["TRK105"]


def test_trk105_sync_after_the_loop_is_fine(tmp_path):
    _, report = _check(tmp_path, """
        import jax

        step = jax.jit(lambda x: x)

        def drive(xs):
            out = None
            for x in xs:
                out = step(x)
            return int(out)   # one sync, outside the loop
    """, rel=HOT, only=["TRK105"])
    assert _ids(report) == []


def test_trk105_scoped_to_hot_modules(tmp_path):
    _, report = _check(tmp_path, """
        import jax

        step = jax.jit(lambda x: x)

        def drive(xs):
            for x in xs:
                n = int(step(x).sum())
                print(n)
    """, rel="src/repro/launch/bench.py", only=["TRK105"])
    assert _ids(report) == []


# ---------------------------------------------------------------------------
# TRK106 fault-site coverage
# ---------------------------------------------------------------------------

def test_trk106_flags_unregistered_site(tmp_path):
    _, report = _check(tmp_path, """
        def risky(faults):
            faults.check("bogus-site", round=1)
    """, only=["TRK106"])
    assert _ids(report) == ["TRK106"]
    assert "bogus-site" in report.active[0].message


def test_trk106_accepts_sites_from_the_registry(tmp_path):
    # a faults.py up the tree defines the registry the rule parses
    _write(tmp_path, 'DISPATCH = "dispatch"\nCUSTOM = "custom-site"\n',
           rel="src/repro/core/faults.py")
    _, report = _check(tmp_path, """
        def risky(faults):
            faults.check("custom-site", round=1)
            faults.check(faults.DISPATCH, round=2)
    """, rel="src/repro/core/top_down.py", only=["TRK106"])
    assert _ids(report) == []


def test_trk106_requires_the_configured_hooks(tmp_path):
    _, report = _check(tmp_path, """
        def peel_classes_batched(batch):
            return batch
    """, rel=HOT, only=["TRK106"])
    assert _ids(report) == ["TRK106"]
    assert "faults.check" in report.active[0].message


def test_trk106_plain_hook_names_do_not_bind_to_methods(tmp_path):
    # the configured ("checkpoint/manager.py", "save") hook is satisfied by
    # the module-level save; AsyncWriter.save delegating to it must not be
    # required to hook twice
    _, report = _check(tmp_path, """
        from repro.core import faults

        def save(state):
            faults.check(faults.CHECKPOINT_WRITE, step=0)
            return state

        class AsyncWriter:
            def save(self, state):
                return save(state)
    """, rel="src/repro/checkpoint/manager.py", only=["TRK106"])
    assert _ids(report) == []


def test_trk106_driver_dispatch_requires_fault_ctx(tmp_path):
    f, report = _check(tmp_path, """
        def rounds(batches):
            for b in batches:
                out = peel_classes_batched(b, shape_cache=None)
            return out
    """, rel="src/repro/core/bottom_up.py", only=["TRK106"])
    assert _ids(report) == ["TRK106"]
    assert "fault_ctx" in report.active[0].message
    f.write_text(textwrap.dedent("""
        def rounds(batches):
            for b in batches:
                out = peel_classes_batched(
                    b, shape_cache=None,
                    fault_ctx={"stage": "stage2", "round": 0})
            return out
    """), encoding="utf-8")
    assert _ids(analysis.check_paths([str(f)], only=["TRK106"])) == []


# ---------------------------------------------------------------------------
# TRK107 Pallas invariants
# ---------------------------------------------------------------------------

PALLAS_BUG = """
    from jax.experimental import pallas as pl

    def launch(x, bm: int = 128):
        return pl.pallas_call(_kern, grid=(x.shape[0] // bm,))(x)
"""

PALLAS_FIXED = """
    from jax.experimental import pallas as pl

    VMEM_BUDGET_BYTES = 12 * 1024 * 1024

    def kernel_vmem_bytes(bm):
        return bm * 4 * 2

    def launch(x, bm: int = 128):
        if x.shape[0] % bm:
            raise ValueError("bm must divide the row count")
        need = kernel_vmem_bytes(bm)
        if need > VMEM_BUDGET_BYTES:
            raise ValueError("tile working set exceeds the VMEM budget")
        return pl.pallas_call(_kern, grid=(x.shape[0] // bm,))(x)
"""


def test_trk107_flags_unguarded_tile_and_missing_vmem_estimate(tmp_path):
    _, report = _check(tmp_path, PALLAS_BUG, only=["TRK107"])
    msgs = " ".join(f.message for f in report.active)
    assert _ids(report) == ["TRK107", "TRK107"]
    assert "tile knob `bm`" in msgs and "VMEM" in msgs


def test_trk107_clean_with_live_guard_and_budget_compare(tmp_path):
    _, report = _check(tmp_path, PALLAS_FIXED, only=["TRK107"])
    assert _ids(report) == []


def test_trk107_assert_is_not_a_live_guard(tmp_path):
    # the -O lane erases asserts, so an asserted divisibility check does
    # not satisfy the rule (it still separately trips TRK103)
    _, report = _check(tmp_path, """
        from jax.experimental import pallas as pl

        VMEM_BUDGET_BYTES = 1 << 20

        def kernel_vmem_bytes(bm):
            return bm * 4

        def launch(x, bm: int = 128):
            assert x.shape[0] % bm == 0
            if kernel_vmem_bytes(bm) > VMEM_BUDGET_BYTES:
                raise ValueError("over budget")
            return pl.pallas_call(_kern, grid=(x.shape[0] // bm,))(x)
    """, only=["TRK107"])
    assert _ids(report) == ["TRK107"]
    assert "tile knob `bm`" in report.active[0].message


# ---------------------------------------------------------------------------
# TRK100 pragma hygiene + allowlisting
# ---------------------------------------------------------------------------

def test_pragma_with_rationale_allowlists_the_finding(tmp_path):
    _, report = _check(tmp_path, """
        def restore(blob):
            assert blob  # trusscheck: allow[TRK103] -- test-only scaffold
            return blob
    """, only=["TRK103"])
    assert report.errors == []
    assert [f.rule_id for f in report.findings if f.allowlisted] == ["TRK103"]


def test_pragma_on_the_line_above_counts(tmp_path):
    _, report = _check(tmp_path, """
        def restore(blob):
            # trusscheck: allow[TRK103] -- test-only scaffold
            assert blob
            return blob
    """, only=["TRK103"])
    assert report.errors == []


def test_pragma_without_rationale_is_its_own_finding(tmp_path):
    _, report = _check(tmp_path, """
        def restore(blob):
            assert blob  # trusscheck: allow[TRK103]
            return blob
    """, only=["TRK103"])
    assert _ids(report) == ["TRK100", "TRK103"]


def test_stale_pragma_is_flagged(tmp_path):
    _, report = _check(tmp_path, """
        def restore(blob):
            # trusscheck: allow[TRK103] -- nothing here anymore
            return blob
    """, only=["TRK103"])
    assert _ids(report) == ["TRK100"]
    assert "stale" in report.active[0].message


# ---------------------------------------------------------------------------
# --fix round trips
# ---------------------------------------------------------------------------

def test_fix_rewrites_assert_to_typed_raise(tmp_path):
    f, report = _check(tmp_path, """
        def restore(blob):
            assert blob["magic"] == 7, "corrupt snapshot"
            return blob["state"]
    """, only=["TRK103"])
    assert apply_fixes(str(f), report.findings) == 1
    fixed = f.read_text(encoding="utf-8")
    ast.parse(fixed)                      # still valid syntax
    assert "raise ValueError" in fixed and "assert" not in fixed
    assert _ids(analysis.check_paths([str(f)], only=["TRK103"])) == []
    ns = {}
    exec(compile(fixed, str(f), "exec"), ns)
    with pytest.raises(ValueError, match="corrupt snapshot"):
        ns["restore"]({"magic": 0})


def test_fix_rewrites_falsy_guard_and_or_default(tmp_path):
    f, report = _check(tmp_path, """
        def pack(lane_capacity=None):
            if lane_capacity:
                cap = lane_capacity
            cap = lane_capacity or 64
            return cap
    """, only=["TRK102"])
    assert apply_fixes(str(f), report.findings) == 2
    fixed = f.read_text(encoding="utf-8")
    ast.parse(fixed)
    assert _ids(analysis.check_paths([str(f)], only=["TRK102"])) == []
    ns = {}
    exec(compile(fixed, str(f), "exec"), ns)
    # the behaviour change IS the fix: 0 no longer falls back to 64
    assert ns["pack"](0) == 0
    assert ns["pack"](None) == 64
    assert ns["pack"](8) == 8


def test_fix_leaves_allowlisted_and_multiline_findings_alone(tmp_path):
    f, report = _check(tmp_path, """
        def restore(blob):
            assert blob  # trusscheck: allow[TRK103] -- scaffold
            assert (blob["magic"]
                    == 7)
            return blob
    """, only=["TRK103"])
    before = f.read_text(encoding="utf-8")
    assert apply_fixes(str(f), report.findings) == 0
    assert f.read_text(encoding="utf-8") == before


# ---------------------------------------------------------------------------
# runner plumbing + the self-run gate
# ---------------------------------------------------------------------------

def test_unknown_rule_ids_are_rejected():
    with pytest.raises(ValueError, match="TRK999"):
        analysis.build_rules(["TRK999"])


def test_cli_exit_codes_and_json(tmp_path):
    f = _write(tmp_path, PR6_BUG)
    env_src = str(REPO_ROOT / "src")
    dirty = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(f), "--json", "-"],
        capture_output=True, text=True, env={"PYTHONPATH": env_src,
                                             "PATH": "/usr/bin:/bin"})
    assert dirty.returncode == 1
    assert '"TRK103"' in dirty.stdout
    clean = _write(tmp_path, "X = 1\n", rel="src/repro/clean.py")
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(clean)],
        capture_output=True, text=True, env={"PYTHONPATH": env_src,
                                             "PATH": "/usr/bin:/bin"})
    assert ok.returncode == 0
    assert "clean" in ok.stdout


def test_self_run_repo_is_clean():
    """The CI gate: src/repro checks clean modulo explicit allowlists."""
    report = analysis.check_paths([str(REPO_ROOT / "src" / "repro")])
    assert report.files_checked > 50
    assert [f.render() for f in report.errors] == []
    # every allowlist that exists carries a rationale (TRK100 enforces it,
    # but pin the invariant directly too)
    for f in report.findings:
        if f.allowlisted:
            assert f.rule_id in ("TRK104", "TRK105")


# ---------------------------------------------------------------------------
# regression tests for the sites the sweep fixed (satellite b)
# ---------------------------------------------------------------------------

def test_build_partition_batch_rejects_zero_lane_capacity():
    from repro.core import graph as glib
    from repro.core.partition import build_partition_batch
    edges = glib.canonical_edges(
        np.array([[0, 1], [1, 2], [0, 2], [2, 3]]), 4)
    g = glib.build_graph(4, edges)
    parts = [np.array([0, 1, 2, 3], dtype=np.int32)]
    with pytest.raises(ValueError, match="lane_capacity"):
        build_partition_batch(g, parts, lane_capacity=0)
    # None still means "natural pow4 classes"
    batch = build_partition_batch(g, parts, lane_capacity=None)
    assert batch.n_parts == 1


def test_make_host_mesh_rejects_zero_devices():
    from repro.launch.mesh import make_host_mesh
    with pytest.raises(ValueError, match="positive"):
        make_host_mesh(0)


def test_prefill_rejects_max_seq_shorter_than_prompt():
    import jax.numpy as jnp
    from repro.models.transformer import prefill
    tokens = jnp.zeros((1, 4), jnp.int32)
    with pytest.raises(ValueError, match="max_seq"):
        prefill({}, tokens, None, max_seq=2)


def test_flash_attention_kernel_rejects_bad_tiles_loudly():
    import jax.numpy as jnp
    from repro.kernels.flash_attention.kernel import (VMEM_BUDGET_BYTES,
                                                      flash_attention_kernel,
                                                      kernel_vmem_bytes)
    q = jnp.zeros((1, 4, 6, 8), jnp.float32)   # s=6 not divisible by bq=4
    k = v = jnp.zeros((1, 2, 6, 8), jnp.float32)
    with pytest.raises(ValueError, match="divide"):
        flash_attention_kernel(q, k, v, bq=4, bk=2, interpret=True)
    bad_heads = jnp.zeros((1, 3, 6, 8), jnp.float32)
    with pytest.raises(ValueError, match="kv heads"):
        flash_attention_kernel(q, bad_heads, bad_heads, interpret=True)
    assert kernel_vmem_bytes(512, 512, 128) < VMEM_BUDGET_BYTES


def test_triangle_count_kernel_rejects_bad_tiles_loudly():
    import jax.numpy as jnp
    from repro.kernels.triangle_count.kernel import triangle_count_kernel
    A = jnp.zeros((6, 6), jnp.float32)
    with pytest.raises(ValueError, match="divide"):
        triangle_count_kernel(A, bm=4, bn=4, bk=4, interpret=True)
    with pytest.raises(TypeError, match="dtype"):
        triangle_count_kernel(A.astype(jnp.int32), bm=2, bn=2, bk=2,
                              interpret=True)
