"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.reduced import make_reduced
from repro.optim import adamw

OCFG = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)


@pytest.mark.parametrize("arch", list(registry.ARCHS))
def test_smoke_train_step(arch):
    cfg, init_fn, loss_fn, batch_fn = make_reduced(arch)
    params = init_fn()
    state = adamw.init_state(params)
    batch = batch_fn(0)

    @jax.jit
    def step(params, state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, state, m = adamw.update(OCFG, params, state, grads)
        return params, state, loss

    params, state, loss = step(params, state, batch)
    assert np.isfinite(float(loss)), (arch, loss)
    for leaf in jax.tree.leaves(params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch
    # second step with fresh data must also be finite and change the loss
    params, state, loss2 = step(params, state, batch_fn(1))
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", registry.LM_ARCHS)
def test_lm_forward_shapes(arch):
    from repro.models import transformer as T
    cfg, init_fn, _, batch_fn = make_reduced(arch)
    params = init_fn()
    batch = batch_fn(0)
    logits, aux = T.forward(params, batch["tokens"], cfg)
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_lm_decode_matches_forward():
    from repro.models import transformer as T
    cfg, init_fn, _, batch_fn = make_reduced("gemma3-4b")  # local:global mix
    params = init_fn()
    toks = batch_fn(0)["tokens"][:2, :16]
    full, _ = T.forward(params, toks, cfg)
    cache, last = T.prefill(params, toks[:, :8], cfg, max_seq=20)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, 7]),
                               rtol=5e-4, atol=5e-4)
    for i in range(8, 12):
        cache, lg = T.decode_step(params, cache, toks[:, i], cfg)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, i]),
                                   rtol=5e-3, atol=5e-3)


def test_equiformer_rotation_invariance():
    from scipy.spatial.transform import Rotation
    from repro.models.gnn import models as G
    cfg, init_fn, loss_fn, batch_fn = make_reduced("equiformer-v2")
    params = init_fn()
    batch = dict(batch_fn(0))
    out1 = G.eqv2_forward(params, batch, cfg)
    R = Rotation.random(1, np.random.default_rng(1)).as_matrix()[0]
    batch2 = dict(batch)
    batch2["positions"] = batch["positions"] @ jnp.asarray(R.T, jnp.float32)
    out2 = G.eqv2_forward(params, batch2, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-3, atol=1e-4)


def test_din_retrieval_consistent():
    from repro.models.recsys import din as DIN
    cfg, init_fn, _, batch_fn = make_reduced("din")
    import dataclasses
    cfg = dataclasses.replace(cfg, cand_chunks=8)
    params = init_fn()
    b = batch_fn(0)
    rng = np.random.default_rng(0)
    cands = jnp.asarray(rng.integers(0, cfg.n_items, 64).astype(np.int32))
    ccats = jnp.asarray(rng.integers(0, cfg.n_cats, 64).astype(np.int32))
    rb = {"hist_items": b["hist_items"][:1], "hist_cats": b["hist_cats"][:1],
          "hist_mask": b["hist_mask"][:1],
          "cand_items": cands, "cand_cats": ccats}
    scores = DIN.din_retrieval(params, rb, cfg)
    sb = {"hist_items": jnp.broadcast_to(b["hist_items"][:1], (64, cfg.seq_len)),
          "hist_cats": jnp.broadcast_to(b["hist_cats"][:1], (64, cfg.seq_len)),
          "hist_mask": jnp.broadcast_to(b["hist_mask"][:1], (64, cfg.seq_len)),
          "cand_item": cands, "cand_cat": ccats}
    np.testing.assert_allclose(np.asarray(scores),
                               np.asarray(DIN.din_scores(params, sb, cfg)),
                               rtol=1e-5, atol=1e-5)


def test_registry_covers_assignment():
    """40 cells: 5 LM x 4 + 4 GNN x 4 + 1 recsys x 4."""
    cells = list(registry.all_cells())
    assert len(cells) == 40
    # exact assigned config spot checks
    q = registry.get_config("qwen2.5-14b")
    assert (q.n_layers, q.d_model, q.n_q, q.n_kv, q.d_ff, q.vocab,
            q.qkv_bias) == (48, 5120, 40, 8, 13824, 152064, True)
    g = registry.get_config("gemma3-4b")
    assert (g.n_layers, g.d_model, g.n_q, g.n_kv, g.d_ff, g.vocab) == \
        (34, 2560, 8, 4, 10240, 262144)
    assert g.pattern == ("local",) * 5 + ("global",)
    gr = registry.get_config("granite-8b")
    assert (gr.n_layers, gr.d_model, gr.n_q, gr.n_kv, gr.d_ff, gr.vocab) == \
        (36, 4096, 32, 8, 14336, 49152)
    p = registry.get_config("phi3.5-moe-42b-a6.6b")
    assert (p.n_layers, p.d_model, p.n_experts, p.top_k, p.d_ff_expert,
            p.vocab) == (32, 4096, 16, 2, 6400, 32064)
    m = registry.get_config("moonshot-v1-16b-a3b")
    assert (m.n_layers, m.d_model, m.n_experts, m.top_k, m.d_ff_expert,
            m.vocab) == (48, 2048, 64, 6, 1408, 163840)
    e = registry.get_config("equiformer-v2")
    assert (e.n_layers, e.d_hidden, e.l_max, e.m_max, e.n_heads) == \
        (12, 128, 6, 2, 8)
    d = registry.get_config("din")
    assert (d.embed_dim, d.seq_len, d.attn_mlp, d.mlp) == \
        (18, 100, (80, 40), (200, 80))
    for c in cells:
        assert c.model_flops > 0, c.key
