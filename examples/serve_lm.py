"""Batched serving example: prefill + greedy decode with a KV cache.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch import serve

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "gemma3-4b", "--requests", "8",
                "--prompt-len", "32", "--new-tokens", "16"]
    serve.main()
