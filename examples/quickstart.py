"""Quickstart: truss-decompose the paper's running example (Figure 2).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import graph as glib
from repro.core.peel import truss_decompose
from repro.core.bottom_up import bottom_up_decompose
from repro.core.top_down import top_down_decompose

NAMES = {c: i for i, c in enumerate("abcdefghijkl")}
EDGES = """a b;a c;a d;a e;b c;b d;b e;c d;c e;d e;d g;d k;d l;e f;e g;f g;
g h;g k;g l;f h;f i;f j;h i;h j;i j;i k"""


def main():
    edges = np.array([[NAMES[x] for x in p.split()]
                      for p in EDGES.replace("\n", "").split(";") if p.strip()])
    n = 12
    ce = glib.canonical_edges(edges, n)
    inv = {v: k for k, v in NAMES.items()}

    phi = truss_decompose(n, ce)
    print("k-classes of the Figure-2 graph:")
    for k in sorted(set(phi.tolist())):
        cls = [f"({inv[u]},{inv[v]})" for (u, v), p in zip(ce, phi) if p == k]
        print(f"  Phi_{k}: {' '.join(cls)}")
    print(f"  k_max = {phi.max()}  (the 5-truss is the clique a-e)")

    # same answer from the I/O-efficient paths with a tiny memory budget
    bu = bottom_up_decompose(n, ce, budget=10)
    td = top_down_decompose(n, ce)
    assert (bu.phi == phi).all() and (td.phi == phi).all()
    print("bottom-up (budget=10 edges) and top-down agree. "
          f"bottom-up used {bu.rounds} partition rounds, {bu.scans} scans.")


if __name__ == "__main__":
    main()
