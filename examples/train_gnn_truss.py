"""The paper's technique as a first-class pipeline feature: train GraphSAGE
with truss-based neighbor sampling (strong-tie-weighted fanouts) and
compare against uniform sampling.

Run:  PYTHONPATH=src python examples/train_gnn_truss.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparsify import sampling_weights, trussness_features
from repro.data import graphgen
from repro.models.gnn import models as G
from repro.models.gnn.sampler import CSR, minibatch
from repro.optim import adamw


def run(weighted: bool, steps: int = 60):
    n = 400
    edges = graphgen.planted_cliques(n, 8, 8, 900, seed=1)
    rng = np.random.default_rng(0)
    # labels correlate with membership in cohesive cores -> trussness-aware
    # sampling should help
    _, tf = trussness_features(n, edges)
    node_core = np.zeros(n)
    for (u, v), t in zip(edges, tf):
        node_core[u] = max(node_core[u], t)
        node_core[v] = max(node_core[v], t)
    labels = (node_core > 0.5).astype(np.int32)
    feats = rng.standard_normal((n, 8)).astype(np.float32)
    feats[:, 0] += labels * 0.5

    w = sampling_weights(n, edges) if weighted else None
    csr = CSR.from_edges(n, edges, edge_w=w)
    cfg = G.GraphSAGEConfig(n_layers=2, d_hidden=32, d_in=8, n_classes=2)
    params = G.sage_init(jax.random.PRNGKey(0), cfg)
    ocfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=steps)
    state = adamw.init_state(params)

    @jax.jit
    def step(params, state, batch):
        loss, g = jax.value_and_grad(
            lambda p, b: G.sage_loss(p, b, cfg))(params, batch)
        params, state, _ = adamw.update(ocfg, params, state, g)
        return params, state, loss

    loss = None
    for s in range(steps):
        mb = minibatch(csr, feats, labels, 16, (5, 3), rng)
        params, state, loss = step(params, state,
                                   {k: jnp.asarray(v) for k, v in mb.items()})
    return float(loss)


if __name__ == "__main__":
    lu = run(weighted=False)
    lw = run(weighted=True)
    print(f"GraphSAGE final loss — uniform sampling: {lu:.4f}, "
          f"truss-weighted sampling: {lw:.4f}")
