"""Network-analysis example (paper Section 7.4): find the cohesive core of
a power-law network, compare k_max-truss vs c_max-core, bound the maximum
clique, and extract the top-2 classes with the top-down algorithm.

Run:  PYTHONPATH=src python examples/truss_analysis.py
"""
import numpy as np

from repro.core.graph import clustering_coefficient, incident_vertices
from repro.core.kcore import cmax_core
from repro.core.peel import kmax_truss
from repro.core.sparsify import clique_upper_bound
from repro.core.top_down import top_down_decompose
from repro.data import graphgen


def main():
    n, edges = graphgen.rmat(scale=13, edge_factor=10, seed=7)
    print(f"R-MAT graph: n={n}, m={len(edges)}")

    kmax, truss = kmax_truss(n, edges)
    cmax, core = cmax_core(n, edges)
    vt, vc = len(incident_vertices(truss)), len(incident_vertices(core))
    print(f"k_max-truss: k={kmax}, |V|={vt}, |E|={len(truss)}, "
          f"CC={clustering_coefficient(n, truss):.2f}")
    print(f"c_max-core : c={cmax}, |V|={vc}, |E|={len(core)}, "
          f"CC={clustering_coefficient(n, core):.2f}")
    print(f"max clique is <= k_max = {clique_upper_bound(n, edges)} "
          f"(vs the weaker c_max+1 = {cmax + 1})")

    td = top_down_decompose(n, edges, t=2)
    for k in td.classes:
        print(f"top-down Phi_{k}: {(td.phi == k).sum()} edges "
              f"(candidate subgraphs: {td.candidate_sizes[:4]}...)")


if __name__ == "__main__":
    main()
