"""End-to-end LM training driver: a few hundred steps of the (reduced)
qwen2.5 architecture with the full stack — deterministic data pipeline,
AdamW, checkpointing, fault-tolerant loop.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import sys

from repro.launch import train

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "qwen2.5-14b",
                "--steps", sys.argv[sys.argv.index("--steps") + 1]
                if "--steps" in sys.argv else "200",
                "--ckpt-dir", "/tmp/repro_example_lm"]
    train.main()
