"""k-core decomposition (bulk vertex peeling) — the paper's comparison
structure (Section 7.4, Table 6): a k-truss is a (k-1)-core but not vice
versa; the experiments contrast the k_max-truss with the c_max-core.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import graph as glib

_BIG = jnp.int32(np.iinfo(np.int32).max // 2)


@jax.jit
def _core_peel(eu, ev, deg0, n_alive0):
    """Bulk-synchronous core peeling over a static edge list."""
    n = deg0.shape[0]

    def cond(state):
        alive, deg, core, k = state
        return jnp.any(alive)

    def body(state):
        alive, deg, core, k = state
        rm = alive & (deg <= k)
        has_rm = jnp.any(rm)

        def remove(_):
            alive2 = alive & ~rm
            e_was = alive[eu] & alive[ev]
            e_now = alive2[eu] & alive2[ev]
            died = e_was & ~e_now
            dec = jnp.zeros(n + 1, jnp.int32)
            dec = dec.at[eu].add((died & alive2[eu]).astype(jnp.int32), mode="drop")
            dec = dec.at[ev].add((died & alive2[ev]).astype(jnp.int32), mode="drop")
            core2 = jnp.where(rm, k, core)
            return alive2, deg - dec[:n], core2, k

        def jump(_):
            mind = jnp.min(jnp.where(alive, deg, _BIG))
            return alive, deg, core, jnp.maximum(k + 1, mind)

        return jax.lax.cond(has_rm, remove, jump, operand=None)

    alive, deg, core, k = jax.lax.while_loop(
        cond, body, (n_alive0, deg0, jnp.zeros(n, jnp.int32), jnp.int32(0))
    )
    return core


def core_decompose(n: int, edges: np.ndarray) -> np.ndarray:
    """Core number of every vertex."""
    edges = glib.canonical_edges(edges, n)
    deg = glib.degrees(n, edges).astype(np.int32)
    if len(edges) == 0:
        return np.zeros(n, np.int64)
    core = _core_peel(
        jnp.asarray(edges[:, 0]), jnp.asarray(edges[:, 1]),
        jnp.asarray(deg), jnp.asarray(deg > 0),
    )
    return np.asarray(core).astype(np.int64)


def cmax_core(n: int, edges: np.ndarray) -> tuple[int, np.ndarray]:
    """The c_max-core: (c_max, edge list of the maximum core)."""
    edges = glib.canonical_edges(edges, n)
    core = core_decompose(n, edges)
    cmax = int(core.max()) if n else 0
    keep = (core[edges[:, 0]] >= cmax) & (core[edges[:, 1]] >= cmax)
    return cmax, edges[keep]
