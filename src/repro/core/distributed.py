"""Distributed truss decomposition (shard_map over the production mesh).

Four device-parallel pieces (DESIGN.md §2, §10):

1. ``distributed_local_truss`` — the LowerBounding stage (Algorithm 3) at pod
   scale: every device owns one (padded) neighborhood subgraph NS(P_i) and
   peels it locally with NO communication — the partition-locality that makes
   the paper's design beat iterate-globally MapReduce.  vmap over the parts
   stacked on each device.

2. ``peel_classes_sharded`` — bulk peeling of ONE big graph whose triangle
   list is sharded across devices: each round every device gathers the
   triangles its shard holds for the (replicated) removal frontier through a
   per-shard edge→triangle incidence CSR and a single psum all-reduce merges
   the decrements (frontier engine, DESIGN.md §3).  Edge-state
   (alive/sup/phi/k) is replicated, so the per-round communication is
   exactly one all-reduce of m int32 plus a scalar pmin agreeing on the
   frontier chunk — the ICI analogue of the paper's "one sequential scan per
   iteration".

3. ``ring_support_dense`` — SUMMA-style dense support counting: adjacency
   row-blocks rotate around the ring (``ppermute``) while each device
   accumulates A_i @ A into its block of (A @ A) ∘ A.  Sequential-neighbor
   traffic instead of all-to-all: the scan(N) discipline applied to ICI.

4. ``peel_classes_batched_sharded`` / ``local_threshold_peel_sharded`` —
   the pod-spanning form of the batched out-of-core engine (DESIGN.md §10):
   one partition round's ``partition.PartBucket`` lanes are split over a
   mesh axis (lanes are independent subproblems, so the per-lane peels need
   no communication), and the per-k candidate peel of both drivers runs
   with its triangle list sharded (pmin on the frontier prefix, psum on the
   decrements — the discipline of piece 2 at a single threshold level).
   ``peel.peel_classes_batched`` / ``peel.local_threshold_peel`` dispatch
   here when a ``mesh=`` is supplied, keeping the drivers' double-buffered
   non-blocking rounds — and the stage-2 candidate pipeline's pre-built
   supersets with their ``alive0`` dead-edge masks (DESIGN.md §11) —
   intact across the mesh: the replicated edge state simply starts with
   the masked edges dead, so they never enter any shard's frontier.
"""

from __future__ import annotations

from functools import lru_cache, partial
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.partition import round_up_to_multiple
from repro.core.peel import (N_STATS, _frontier_round,
                             _peel_classes_vmapped_impl,
                             peel_classes_fixedcap)
from repro.core.support import _pow2_ceil, triangle_incidence_np

_BIG = jnp.int32(np.iinfo(np.int32).max // 2)


def _shard_map(f, mesh, in_specs, out_specs):
    """shard_map across jax versions (jax.shard_map moved; check_vma was
    check_rep).  Trip counts are data-dependent per shard, so both checks
    are disabled."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


# ---------------------------------------------------------------------------
# 1. LowerBounding at pod scale
# ---------------------------------------------------------------------------

def pad_parts(
    parts: Sequence[tuple[np.ndarray, np.ndarray]], n_devices: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Stack per-part (sup, tris) into device-shardable padded arrays.

    Returns (sup_p, tris_p, alive_p, indptr_p, tids_p): shapes (P, Em),
    (P, Tm, 3), (P, Em), (P, Em+1), (P, Lm) with P a multiple of n_devices.
    Padding edges are dead; padding triangles point at the per-part drop
    slot Em.  (indptr_p, tids_p) is each part's edge→triangle incidence CSR
    consumed by the frontier peel engine.
    """
    n_parts = len(parts)
    P_total = max(1, -(-n_parts // n_devices) * n_devices)
    Em = max([len(s) for s, _ in parts] + [1])
    Tm = max([len(t) for _, t in parts] + [1])
    Lm = max(1, 3 * Tm)
    sup_p = np.zeros((P_total, Em), np.int32)
    tris_p = np.full((P_total, Tm, 3), Em, np.int32)
    alive_p = np.zeros((P_total, Em), bool)
    indptr_p = np.zeros((P_total, Em + 1), np.int32)
    tids_p = np.zeros((P_total, Lm), np.int32)
    for i, (sup, tris) in enumerate(parts):
        sup_p[i, : len(sup)] = sup
        alive_p[i, : len(sup)] = True
        if len(tris):
            tris_p[i, : len(tris)] = tris
        indptr, tids = triangle_incidence_np(tris_p[i], Em)
        indptr_p[i] = indptr
        tids_p[i, : len(tids)] = tids
    return sup_p, tris_p, alive_p, indptr_p, tids_p


def distributed_local_truss(mesh, sup_p, tris_p, alive_p, indptr_p, tids_p,
                            axis: str = "data"):
    """Peel every part locally, parts sharded over ``axis``; returns phi_p.

    Runs the frontier-compacted engine per part with capacities pinned to
    the padded part sizes (static under vmap, so the overflow path can never
    trigger)."""
    Em = sup_p.shape[1]
    cap_f = Em
    cap_t = max(1, tids_p.shape[1])

    def one(s, t, ip, ti, a):
        phi0 = jnp.zeros(Em, jnp.int32)
        st0 = jnp.zeros(N_STATS, jnp.int32)
        _, _, phi, _, _, _ = peel_classes_fixedcap(
            s, t, ip, ti, a, phi0, jnp.int32(2), st0,
            cap_f=cap_f, cap_t=cap_t)
        return phi

    def local(sup, tris, indptr, tids, alive):
        return jax.vmap(one)(sup, tris, indptr, tids, alive)

    fn = _shard_map(
        local, mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=P(axis),
    )
    return fn(sup_p, tris_p, indptr_p, tids_p, alive_p)


# ---------------------------------------------------------------------------
# 2. Sharded-triangle bulk peel (one big graph)
# ---------------------------------------------------------------------------

def _peel_sharded_body(sup0, tris_loc, indptr_loc, tids_loc, alive0,
                       axis: str, cap_f: int, cap_t: int):
    """Runs on each device: triangle shard + its incidence local, edge state
    replicated.  Every round removes an agreed (pmin) frontier chunk, gathers
    only the local triangles incident to it, and merges decrements with one
    psum."""
    m = sup0.shape[0]
    indptr_loc = indptr_loc.reshape(-1)
    tids_loc = tids_loc.reshape(-1)

    def cond(state):
        alive, sup, phi, k = state
        return jnp.any(alive)

    def body(state):
        alive, sup, phi, k = state
        rm = alive & (sup <= k - 2)
        has_rm = jnp.any(rm)

        def remove(_):
            alive2, sup2, rm_sub, _, _, _, _ = _frontier_round(
                alive, sup, rm, tris_loc, indptr_loc, tids_loc,
                cap_f=cap_f, cap_t=cap_t, axis=axis)
            phi2 = jnp.where(rm_sub, k, phi)
            return alive2, sup2, phi2, k

        def jump(_):
            min_sup = jnp.min(jnp.where(alive, sup, _BIG))
            return alive, sup, phi, jnp.maximum(k + 1, min_sup + 2)

        return jax.lax.cond(has_rm, remove, jump, operand=None)

    state0 = (alive0, sup0, jnp.zeros(m, jnp.int32), jnp.int32(2))
    alive, sup, phi, k = jax.lax.while_loop(cond, body, state0)
    return phi


def _sharded_caps(m: int, indptr_s: np.ndarray, tids_s: np.ndarray,
                  cap_f=None, cap_t=None) -> tuple[int, int]:
    """Frontier capacities for a triangle-sharded peel: ``cap_t`` is clamped
    to cover the largest per-shard incidence row (even when caller-provided),
    so every shard fits at least one edge's row and the pmin-agreed prefix
    is never empty — progress is guaranteed without an overflow/resume
    path.  Shared by ``peel_classes_sharded`` and
    ``local_threshold_peel_sharded``."""
    max_row = int((indptr_s[:, 1:] - indptr_s[:, :-1]).max()) if m else 1
    n_inc = tids_s.shape[1]
    if cap_f is None:
        cap_f = _pow2_ceil(min(max(m, 1), max(256, m // 16)))
    if cap_t is None:
        cap_t = _pow2_ceil(min(max(n_inc, 1), max(max_row, 512, n_inc // 16)))
    return cap_f, max(cap_t, _pow2_ceil(max_row))


def shard_incidence(tris: np.ndarray, m: int, n_shards: int):
    """Per-shard edge→triangle incidence over contiguous triangle shards.

    ``tris`` (T_pad, 3) with T_pad divisible by ``n_shards``; triangle ids in
    each shard's CSR are LOCAL to the shard (matching the tris rows that
    shard_map hands each device).  Returns (indptr_s (S, m+1), tids_s (S, L))
    padded to a common L.
    """
    t_loc = len(tris) // n_shards
    per = [triangle_incidence_np(tris[i * t_loc:(i + 1) * t_loc], m)
           for i in range(n_shards)]
    L = max([len(t) for _, t in per] + [1])
    indptr_s = np.zeros((n_shards, m + 1), np.int32)
    tids_s = np.zeros((n_shards, L), np.int32)
    for i, (indptr, tids) in enumerate(per):
        indptr_s[i] = indptr
        tids_s[i, : len(tids)] = tids
    return indptr_s, tids_s


def peel_classes_sharded(mesh, sup0, tris, alive0, axis: str = "data",
                         cap_f=None, cap_t=None):
    """Trussness of one big graph with the triangle list sharded on ``axis``.

    ``tris`` (T, 3) must be padded to a multiple of the axis size (padding
    rows point at edge id m = drop slot).  The per-shard incidence CSR is
    built host-side; capacities default to frontier-sized buffers with
    ``cap_t`` covering the largest incidence row of any shard (progress is
    then guaranteed, so no overflow/resume path is needed here).
    """
    n_shards = mesh.shape[axis]
    m = int(sup0.shape[0])
    tris_np = np.asarray(tris)
    indptr_s, tids_s = shard_incidence(tris_np, m, n_shards)
    cap_f, cap_t = _sharded_caps(m, indptr_s, tids_s, cap_f, cap_t)
    fn = _shard_map(
        partial(_peel_sharded_body, axis=axis, cap_f=cap_f, cap_t=cap_t),
        mesh,
        in_specs=(P(), P(axis), P(axis), P(axis), P()),
        out_specs=P(),
    )
    return fn(sup0, jnp.asarray(tris), jnp.asarray(indptr_s),
              jnp.asarray(tids_s), alive0)


def pad_triangles(tris: np.ndarray, m: int, multiple: int) -> np.ndarray:
    t = len(tris)
    t_pad = max(1, -(-t // multiple)) * multiple
    out = np.full((t_pad, 3), m, np.int32)
    if t:
        out[:t] = tris
    return out


# ---------------------------------------------------------------------------
# 3. Ring (SUMMA) dense support counting
# ---------------------------------------------------------------------------

def ring_support_dense(mesh, A: jnp.ndarray, axis: str = "data"):
    """S = (A @ A) ∘ A with A row-sharded; neighbor-ring collective schedule.

    A: (n, n) 0/1 matrix (float dtype), n divisible by the axis size.
    Returns S with S[u, v] = common-neighbor count for the edge (u, v)
    (zero off-edges) — per-edge support for the dense-core regime.
    """
    p = mesh.shape[axis]
    perm = [(j, (j + 1) % p) for j in range(p)]

    def body(a_loc):                      # (nb, n) block of rows
        nb = a_loc.shape[0]
        idx0 = jax.lax.axis_index(axis)

        def step(i, carry):
            blk, acc = carry              # blk holds rows of device (idx0 - i) % p
            src = (idx0 - i) % p
            cols = jax.lax.dynamic_slice(a_loc, (0, src * nb), (nb, nb))
            acc = acc + cols @ blk        # (nb, nb) @ (nb, n)
            blk = jax.lax.ppermute(blk, axis, perm)
            return blk, acc

        _, acc = jax.lax.fori_loop(0, p, step, (a_loc, jnp.zeros_like(a_loc)))
        return acc * a_loc

    fn = _shard_map(body, mesh, in_specs=P(axis, None), out_specs=P(axis, None))
    return fn(A)


def allgather_support_dense(mesh, A: jnp.ndarray, axis: str = "data"):
    """Baseline: same computation via one big all-gather (no ring overlap).

    Used by EXPERIMENTS.md §Perf to contrast collective schedules.
    """

    def body(a_loc):
        a_full = jax.lax.all_gather(a_loc, axis, tiled=True)   # (n, n)
        return (a_loc @ a_full) * a_loc

    fn = _shard_map(body, mesh, in_specs=P(axis, None), out_specs=P(axis, None))
    return fn(A)


# ---------------------------------------------------------------------------
# 4. Pod-spanning batched OOC rounds (DESIGN.md §10)
# ---------------------------------------------------------------------------

def pad_bucket_lanes(sup_b, tris_b, indptr_b, tids_b, alive_b, n_lanes: int):
    """``pad_parts``-style padding of a bucket's lane dimension to
    ``n_lanes``: appended lanes are dead (alive False, sup 0, every triangle
    row on the per-lane drop slot cap_e, empty incidence), so they exit the
    peel's while loop immediately and can never contribute support."""
    B, cap_e = sup_b.shape
    if n_lanes == B:
        return sup_b, tris_b, indptr_b, tids_b, alive_b
    pad = n_lanes - B
    return (
        np.concatenate([sup_b, np.zeros((pad, cap_e), np.int32)]),
        np.concatenate(
            [tris_b, np.full((pad,) + tris_b.shape[1:], cap_e, np.int32)]),
        np.concatenate([indptr_b, np.zeros((pad, cap_e + 1), np.int32)]),
        np.concatenate([tids_b, np.zeros((pad, tids_b.shape[1]), np.int32)]),
        np.concatenate([alive_b, np.zeros((pad, cap_e), bool)]),
    )


def _axes_tuple(axis) -> tuple:
    """Normalize an axis knob (one name or a sequence) to a tuple."""
    return (axis,) if isinstance(axis, str) else tuple(axis)


def shard_incidence_lanes(tris_b: np.ndarray, cap_e: int, n_shards: int):
    """Lane-wise :func:`shard_incidence`: per-lane per-shard edge→triangle
    incidence over contiguous triangle shards.

    ``tris_b`` (B, T, 3) with T divisible by ``n_shards``; triangle ids in
    each shard's CSR are LOCAL to the (lane, shard) rows shard_map hands
    each device.  Returns (indptr_ls (B, S, cap_e+1), tids_ls (B, S, L))
    padded to a common L across lanes AND shards (one static shape per
    bucket).
    """
    B, T = tris_b.shape[0], tris_b.shape[1]
    t_loc = T // n_shards
    per = [[triangle_incidence_np(tris_b[b, i * t_loc:(i + 1) * t_loc],
                                  cap_e)
            for i in range(n_shards)] for b in range(B)]
    L = max([len(t) for row in per for _, t in row] + [1])
    indptr_ls = np.zeros((B, n_shards, cap_e + 1), np.int32)
    tids_ls = np.zeros((B, n_shards, L), np.int32)
    for b in range(B):
        for i, (indptr, tids) in enumerate(per[b]):
            indptr_ls[b, i] = indptr
            tids_ls[b, i, : len(tids)] = tids
    return indptr_ls, tids_ls


@lru_cache(maxsize=None)
def _batched_sharded2_fn(mesh, lane_axis: str, tri_axis: str, cap_f: int,
                         cap_t: int):
    """jit(shard_map) of the TWO-AXIS batched peel (DESIGN.md §13): lanes
    split over ``lane_axis`` while each lane's triangle list + incidence
    shard over ``tri_axis``.  Edge state is sharded by lane and replicated
    across the triangle axis, so inside each lane's vmapped
    ``peel_classes_fixedcap`` the frontier prefix is agreed by pmin and
    decrements merged by psum over ``tri_axis`` — a bucket with fewer lanes
    than devices still spreads every lane's round across the second axis."""

    def local(sup, tris, indptr, tids, alive):
        def one(s, t, ip, ti, a):
            Em = s.shape[0]
            phi0 = jnp.zeros(Em, jnp.int32)
            st0 = jnp.zeros(N_STATS, jnp.int32)
            _, _, phi, _, st, _ = peel_classes_fixedcap(
                s, t, ip.reshape(-1), ti.reshape(-1), a, phi0,
                jnp.int32(2), st0, cap_f=cap_f, cap_t=cap_t, axis=tri_axis)
            return phi, st

        return jax.vmap(one)(sup, tris, indptr, tids, alive)

    fn = _shard_map(
        local, mesh,
        in_specs=(P(lane_axis), P(lane_axis, tri_axis),
                  P(lane_axis, tri_axis), P(lane_axis, tri_axis),
                  P(lane_axis)),
        out_specs=(P(lane_axis), P(lane_axis)),
    )
    return jax.jit(fn, donate_argnums=(0,))


@lru_cache(maxsize=None)
def _batched_sharded_fn(mesh, axis: str, cap_f: int, cap_t: int):
    """jit(shard_map(·)) of ``peel._peel_classes_vmapped_impl`` — each
    device runs the SAME per-lane vmapped kernel as the single-device path
    on its lane slice; cached per (mesh, caps) so the compile cache stays
    keyed on the pow2/pow4 bucket-shape lattice."""
    fn = _shard_map(
        partial(_peel_classes_vmapped_impl, cap_f=cap_f, cap_t=cap_t),
        mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
    )
    # sup is donated exactly like the single-device path: rebuilt from
    # scratch by the host every round, layout matching the phi output
    return jax.jit(fn, donate_argnums=(0,))


def peel_classes_batched_sharded(mesh, sup_b, tris_b, indptr_b, tids_b,
                                 alive_b, *, cap_f: int, cap_t: int,
                                 axis: str = "data"):
    """One bucket's NS lanes peeled across the mesh (DESIGN.md §10).

    The lane dimension of the (B, ...) ``partition.PartBucket`` stacks is
    split over ``axis``; lanes are disjoint subproblems, so each device
    peels its slice to its own fixed point with NO communication — the
    pod-wide form of ``peel.peel_classes_batched``'s vmapped kernel.  The
    lane count is first padded to a multiple of the axis size with dead
    lanes (:func:`pad_bucket_lanes`); ``partition.build_partition_batch``'s
    ``lane_multiple`` pre-pads batches so this is normally a no-op, with
    the waste visible in ``OocStats.padding_waste``.

    With ``axis`` a TUPLE (lane_axis, tri_axis) the bucket spans a
    multi-axis mesh (DESIGN.md §13): lanes pad to a multiple of the lane
    axis only, and each lane's triangle rows (padded to a multiple of the
    triangle axis) shard over the second axis with a per-(lane, shard)
    incidence CSR (:func:`shard_incidence_lanes`) — pmin/psum over
    ``tri_axis`` keep the replicated per-lane edge state in lockstep.  The
    caller's ``cap_t`` covers the largest whole-lane incidence row, which
    bounds every shard-local row, so progress stays guaranteed.

    Returns DEVICE arrays ``(phi, stats)`` over the PADDED lane count —
    still futures at return time, so the caller's host work overlaps the
    pod-wide peel; slice back to the original B when materializing.
    """
    axes = _axes_tuple(axis)
    n_lane = int(mesh.shape[axes[0]])
    arrs = pad_bucket_lanes(
        sup_b, tris_b, indptr_b, tids_b, alive_b,
        round_up_to_multiple(sup_b.shape[0], n_lane))
    if len(axes) == 1:
        fn = _batched_sharded_fn(mesh, axes[0], int(cap_f), int(cap_t))
        return fn(*(jnp.asarray(a) for a in arrs))
    lane_axis, tri_axis = axes
    n_tri = int(mesh.shape[tri_axis])
    sup_p, tris_p, _, _, alive_p = arrs
    cap_e = int(sup_p.shape[1])
    T = int(tris_p.shape[1])
    T_pad = round_up_to_multiple(T, n_tri)
    if T_pad != T:  # contiguous triangle shards need equal rows per device
        pad = np.full((sup_p.shape[0], T_pad - T, 3), cap_e, np.int32)
        tris_p = np.concatenate([np.asarray(tris_p), pad], axis=1)
    indptr_ls, tids_ls = shard_incidence_lanes(
        np.asarray(tris_p), cap_e, n_tri)
    fn = _batched_sharded2_fn(mesh, lane_axis, tri_axis,
                              int(cap_f), int(cap_t))
    return fn(jnp.asarray(sup_p), jnp.asarray(tris_p),
              jnp.asarray(indptr_ls), jnp.asarray(tids_ls),
              jnp.asarray(alive_p))


@lru_cache(maxsize=None)
def _threshold_sharded_fn(mesh, axis, cap_f: int, cap_t: int):
    """jit(shard_map) of the single-level peel: edge state replicated,
    triangles + incidence sharded, pmin/psum per round (see
    ``_peel_sharded_body`` for the multi-level analogue).  ``axis`` may be
    one axis name or a tuple of names — ``P(axis)`` then shards the
    triangle rows over the flattened product and pmin/psum reduce over all
    named axes at once (DESIGN.md §13)."""

    def local(sup0, tris_loc, indptr_loc, tids_loc, alive0, removable,
              thresh):
        indptr_loc = indptr_loc.reshape(-1)
        tids_loc = tids_loc.reshape(-1)

        def cond(state):
            alive, sup = state
            return jnp.any(alive & removable & (sup <= thresh))

        def body(state):
            alive, sup = state
            rm = alive & removable & (sup <= thresh)
            alive2, sup2, _, _, _, _, _ = _frontier_round(
                alive, sup, rm, tris_loc, indptr_loc, tids_loc,
                cap_f=cap_f, cap_t=cap_t, axis=axis)
            return alive2, sup2

        alive, _ = jax.lax.while_loop(cond, body, (alive0, sup0))
        return alive

    fn = _shard_map(
        local, mesh,
        in_specs=(P(), P(axis), P(axis), P(axis), P(), P(), P()),
        out_specs=P(),
    )
    return jax.jit(fn)


def local_threshold_peel_sharded(mesh, sup0, tris, alive0, removable, thresh,
                                 *, axis="data"):
    """Single-level candidate peel with the triangle list sharded on ``axis``.

    The mesh form of ``peel.local_threshold_peel``'s kernel (the per-k
    candidate peel of BOTH out-of-core drivers): sup/alive/removable are
    replicated, ``tris`` (T, 3; T a multiple of the axis size, padding rows
    on the drop slot m) is sharded along with its per-shard incidence CSR.
    Every round the devices agree on the removal prefix via ``pmin`` and
    merge support decrements with one ``psum``, so replicated edge state
    stays in lockstep.  ``cap_t`` covers the largest per-shard incidence
    row, so each shard always fits at least one edge's row and the agreed
    prefix is non-empty — no overflow/resume path.

    With ``axis`` a tuple of names the shards span the flattened product of
    those mesh axes (DESIGN.md §13) — one huge candidate peel spreads its
    psum volume across the whole multi-axis mesh.

    Returns ``(alive_device_array, cap_f, cap_t)``; the caps feed the
    caller's compile-shape cache key.
    """
    axes = _axes_tuple(axis)
    n_shards = 1
    for a in axes:
        n_shards *= int(mesh.shape[a])
    spec_axis = axes[0] if len(axes) == 1 else axes
    m = int(sup0.shape[0])
    tris_np = np.asarray(tris)
    indptr_s, tids_s = shard_incidence(tris_np, m, n_shards)
    cap_f, cap_t = _sharded_caps(m, indptr_s, tids_s)
    fn = _threshold_sharded_fn(mesh, spec_axis, int(cap_f), int(cap_t))
    alive = fn(jnp.asarray(sup0), jnp.asarray(tris_np),
               jnp.asarray(indptr_s), jnp.asarray(tids_s),
               jnp.asarray(alive0), jnp.asarray(removable),
               jnp.int32(thresh))
    return alive, cap_f, cap_t
