"""Distributed truss decomposition (shard_map over the production mesh).

Three device-parallel pieces (DESIGN.md §2):

1. ``distributed_local_truss`` — the LowerBounding stage (Algorithm 3) at pod
   scale: every device owns one (padded) neighborhood subgraph NS(P_i) and
   peels it locally with NO communication — the partition-locality that makes
   the paper's design beat iterate-globally MapReduce.  vmap over the parts
   stacked on each device.

2. ``peel_classes_sharded`` — bulk peeling of ONE big graph whose triangle
   list is sharded across devices: each round every device computes the
   support decrement induced by its triangle shard and a single psum
   all-reduce merges them.  Edge-state (alive/sup/phi/k) is replicated, so
   the per-round communication is exactly one all-reduce of m int32 — the
   ICI analogue of the paper's "one sequential scan per iteration".

3. ``ring_support_dense`` — SUMMA-style dense support counting: adjacency
   row-blocks rotate around the ring (``ppermute``) while each device
   accumulates A_i @ A into its block of (A @ A) ∘ A.  Sequential-neighbor
   traffic instead of all-to-all: the scan(N) discipline applied to ICI.
"""

from __future__ import annotations

from functools import partial
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.peel import _tri_alive, peel_classes

_BIG = jnp.int32(np.iinfo(np.int32).max // 2)


# ---------------------------------------------------------------------------
# 1. LowerBounding at pod scale
# ---------------------------------------------------------------------------

def pad_parts(
    parts: Sequence[tuple[np.ndarray, np.ndarray]], n_devices: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack per-part (sup, tris) into device-shardable padded arrays.

    Returns (sup_p, tris_p, alive_p): shapes (P, Em), (P, Tm, 3), (P, Em)
    with P a multiple of n_devices.  Padding edges are dead; padding
    triangles point at the per-part drop slot Em.
    """
    n_parts = len(parts)
    P_total = max(1, -(-n_parts // n_devices) * n_devices)
    Em = max([len(s) for s, _ in parts] + [1])
    Tm = max([len(t) for _, t in parts] + [1])
    sup_p = np.zeros((P_total, Em), np.int32)
    tris_p = np.full((P_total, Tm, 3), Em, np.int32)
    alive_p = np.zeros((P_total, Em), bool)
    for i, (sup, tris) in enumerate(parts):
        sup_p[i, : len(sup)] = sup
        alive_p[i, : len(sup)] = True
        if len(tris):
            tris_p[i, : len(tris)] = tris
    return sup_p, tris_p, alive_p


def distributed_local_truss(mesh, sup_p, tris_p, alive_p, axis: str = "data"):
    """Peel every part locally, parts sharded over ``axis``; returns phi_p."""

    def local(sup, tris, alive):
        phi, _ = jax.vmap(lambda s, t, a: peel_classes(s, t, a))(sup, tris, alive)
        return phi

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=P(axis),
        check_vma=False,  # data-dependent trip counts differ per shard
    )
    return fn(sup_p, tris_p, alive_p)


# ---------------------------------------------------------------------------
# 2. Sharded-triangle bulk peel (one big graph)
# ---------------------------------------------------------------------------

def _peel_sharded_body(sup0, tris_loc, alive0, axis: str):
    """Runs on each device: triangle shard local, edge state replicated."""
    m = sup0.shape[0]

    def cond(state):
        alive, sup, phi, k = state
        return jnp.any(alive)

    def body(state):
        alive, sup, phi, k = state
        rm = alive & (sup <= k - 2)
        has_rm = jnp.any(rm)

        def remove(_):
            alive2 = alive & ~rm
            phi2 = jnp.where(rm, k, phi)
            died = _tri_alive(alive, tris_loc) & ~_tri_alive(alive2, tris_loc)
            dec = jnp.zeros(m + 1, jnp.int32)
            for c in range(3):
                e = tris_loc[:, c]
                dec = dec.at[e].add((died & alive2[e]).astype(jnp.int32), mode="drop")
            dec = jax.lax.psum(dec, axis)       # the one all-reduce per round
            return alive2, sup - dec[:m], phi2, k

        def jump(_):
            min_sup = jnp.min(jnp.where(alive, sup, _BIG))
            return alive, sup, phi, jnp.maximum(k + 1, min_sup + 2)

        return jax.lax.cond(has_rm, remove, jump, operand=None)

    state0 = (alive0, sup0, jnp.zeros(m, jnp.int32), jnp.int32(2))
    alive, sup, phi, k = jax.lax.while_loop(cond, body, state0)
    return phi


def peel_classes_sharded(mesh, sup0, tris, alive0, axis: str = "data"):
    """Trussness of one big graph with the triangle list sharded on ``axis``.

    ``tris`` (T, 3) must be padded to a multiple of the axis size (padding
    rows point at edge id m = drop slot).
    """
    fn = jax.shard_map(
        partial(_peel_sharded_body, axis=axis), mesh=mesh,
        in_specs=(P(), P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn(sup0, tris, alive0)


def pad_triangles(tris: np.ndarray, m: int, multiple: int) -> np.ndarray:
    t = len(tris)
    t_pad = max(1, -(-t // multiple)) * multiple
    out = np.full((t_pad, 3), m, np.int32)
    if t:
        out[:t] = tris
    return out


# ---------------------------------------------------------------------------
# 3. Ring (SUMMA) dense support counting
# ---------------------------------------------------------------------------

def ring_support_dense(mesh, A: jnp.ndarray, axis: str = "data"):
    """S = (A @ A) ∘ A with A row-sharded; neighbor-ring collective schedule.

    A: (n, n) 0/1 matrix (float dtype), n divisible by the axis size.
    Returns S with S[u, v] = common-neighbor count for the edge (u, v)
    (zero off-edges) — per-edge support for the dense-core regime.
    """
    p = mesh.shape[axis]
    perm = [(j, (j + 1) % p) for j in range(p)]

    def body(a_loc):                      # (nb, n) block of rows
        nb = a_loc.shape[0]
        idx0 = jax.lax.axis_index(axis)

        def step(i, carry):
            blk, acc = carry              # blk holds rows of device (idx0 - i) % p
            src = (idx0 - i) % p
            cols = jax.lax.dynamic_slice(a_loc, (0, src * nb), (nb, nb))
            acc = acc + cols @ blk        # (nb, nb) @ (nb, n)
            blk = jax.lax.ppermute(blk, axis, perm)
            return blk, acc

        _, acc = jax.lax.fori_loop(0, p, step, (a_loc, jnp.zeros_like(a_loc)))
        return acc * a_loc

    fn = jax.shard_map(
        body, mesh=mesh, in_specs=P(axis, None), out_specs=P(axis, None)
    )
    return fn(A)


def allgather_support_dense(mesh, A: jnp.ndarray, axis: str = "data"):
    """Baseline: same computation via one big all-gather (no ring overlap).

    Used by EXPERIMENTS.md §Perf to contrast collective schedules.
    """

    def body(a_loc):
        a_full = jax.lax.all_gather(a_loc, axis, tiled=True)   # (n, n)
        return (a_loc @ a_full) * a_loc

    fn = jax.shard_map(
        body, mesh=mesh, in_specs=P(axis, None), out_specs=P(axis, None)
    )
    return fn(A)
