"""Edge-support (per-edge triangle count) computation.

This is the paper's computational hot spot (Alg 2 Step 2 / Alg 3 Step 6).
The vectorized form keeps the paper's O(m^1.5) bound (Theorem 1):

  * every edge is oriented low-rank -> high-rank (rank = (deg, id) order), so
    out-degrees are O(sqrt(m));
  * for each oriented edge (a->b), every out-neighbor w of a is tested for
    membership in N+(b) — a *binary search* into the sorted CSR row of b
    (the TPU-idiomatic replacement for the paper's hashtable);
  * a hit identifies triangle {a,b,w} exactly once (forward algorithm) and
    credits support to all three edge ids.

Shapes are static: edges are processed in fixed-size chunks of C edges, each
expanded to (C, D) wedge candidates.  A single global D = max oriented
out-degree would let one hub vertex in a power-law graph inflate every chunk
by orders of magnitude, so the device path is *skew-aware*: oriented edges
are bucketed by the power-of-two out-degree of their source row and each
bucket runs the wedge enumeration with its own D (DESIGN.md §4).  Total work
stays O(m^1.5); memory per bucket is O(C_b * D_b) with C_b sized to a fixed
element budget.

Three entry points share the same logic:
  * ``edge_support_np``   — numpy, host-side (oracle + preprocessing);
  * ``edge_support_jax``  — jit'd lax.scan over bucketed chunks (device path);
  * ``edge_support_auto`` — dispatch: dense-core partitions go to the
    dense-tile kernel (kernels/triangle_count), sparse ones to the bucketed
    wedge path; see DESIGN.md §2.

``triangle_incidence_np`` builds the edge→triangle incidence CSR consumed by
the frontier-compacted peeling engine (core/peel.py, DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph


def _search_iters(max_row: int) -> int:
    return max(1, math.ceil(math.log2(max_row + 1))) if max_row > 0 else 1


# ---------------------------------------------------------------------------
# numpy path
# ---------------------------------------------------------------------------

def _row_lower_bound_np(nbrs, lo, hi, target, iters):
    lo = lo.astype(np.int64).copy()
    hi = hi.astype(np.int64).copy()
    n_entries = len(nbrs)
    for _ in range(iters):
        mid = (lo + hi) >> 1
        midc = np.minimum(mid, max(n_entries - 1, 0))
        less = np.where(lo < hi, nbrs[midc] < target, False)
        lo = np.where(less, mid + 1, lo)
        hi = np.where(less, hi, np.where(lo < hi, mid, hi))
    return lo


def _wedge_hits_np(g: Graph, e_lo: int, e_hi: int):
    """For edge ids [e_lo, e_hi): returns (eid, e_aw, e_bw, hit) flat arrays."""
    eids = np.arange(e_lo, e_hi, dtype=np.int64)
    return _wedge_hits_ids_np(g, eids, g.max_out_deg)


def _wedge_hits_ids_np(g: Graph, eids: np.ndarray, D: int):
    """Wedge enumeration for an explicit edge-id set with wedge width ``D``.

    ``D`` must cover the out-degree of every source row of ``eids`` — the
    skew-aware callers pass a per-bucket ``D`` (DESIGN.md §4) instead of the
    global ``max_out_deg``.
    """
    a = g.src[eids].astype(np.int64)
    b = g.dst[eids].astype(np.int64)
    C = len(a)
    if C == 0 or D == 0:
        z = np.zeros(0, np.int64)
        return z, z, z, np.zeros(0, bool)
    slot = np.arange(D, dtype=np.int64)[None, :]
    row_start = g.indptr[a].astype(np.int64)[:, None]
    row_len = (g.indptr[a + 1] - g.indptr[a]).astype(np.int64)[:, None]
    valid = slot < row_len
    pos_aw = np.minimum(row_start + slot, max(len(g.nbrs) - 1, 0))
    w = g.nbrs[pos_aw].astype(np.int64)
    # binary search w in row b
    lo = np.broadcast_to(g.indptr[b].astype(np.int64)[:, None], (C, D))
    hi = np.broadcast_to(g.indptr[b + 1].astype(np.int64)[:, None], (C, D))
    iters = _search_iters(g.max_out_deg)
    p = _row_lower_bound_np(g.nbrs, lo.reshape(-1), hi.reshape(-1), w.reshape(-1), iters)
    p = p.reshape(C, D)
    in_row = p < g.indptr[b + 1].astype(np.int64)[:, None]
    pc = np.minimum(p, max(len(g.nbrs) - 1, 0))
    hit = valid & in_row & (g.nbrs[pc] == w)
    eid = np.broadcast_to(eids[:, None], (C, D))
    e_aw = g.nbr_eid[pos_aw].astype(np.int64)
    e_bw = g.nbr_eid[pc].astype(np.int64)
    f = hit.reshape(-1)
    return eid.reshape(-1)[f], e_aw.reshape(-1)[f], e_bw.reshape(-1)[f], f


def edge_support_np(g: Graph, chunk: int = 1 << 16) -> np.ndarray:
    """Support of every canonical edge (numpy, chunked)."""
    sup = np.zeros(g.m, dtype=np.int64)
    for e_lo in range(0, g.m, chunk):
        e_hi = min(e_lo + chunk, g.m)
        e_ab, e_aw, e_bw, _ = _wedge_hits_np(g, e_lo, e_hi)
        np.add.at(sup, e_ab, 1)
        np.add.at(sup, e_aw, 1)
        np.add.at(sup, e_bw, 1)
    return sup


def list_triangles_np(g: Graph, chunk: int = 1 << 16) -> np.ndarray:
    """Static triangle list: (T, 3) int32 edge-id triples, each triangle once."""
    out = []
    for e_lo in range(0, g.m, chunk):
        e_hi = min(e_lo + chunk, g.m)
        e_ab, e_aw, e_bw, _ = _wedge_hits_np(g, e_lo, e_hi)
        out.append(np.stack([e_ab, e_aw, e_bw], axis=1))
    if not out:
        return np.zeros((0, 3), np.int32)
    return np.concatenate(out, axis=0).astype(np.int32)


def list_triangles(
    g: Graph, chunk: int = 1 << 14, budget: int = 1 << 18
) -> np.ndarray:
    """Skew-aware triangle listing (host path of DESIGN.md §4).

    ``list_triangles_np`` materializes a (chunk, max_out_deg) wedge tensor,
    so one hub row inflates every chunk on power-law graphs.  This variant
    reuses ``wedge_bucket_plan``: oriented edges are grouped by the pow2
    out-degree of their source row and each bucket enumerates with its own
    ``D``, keeping the materialized wedge area at Σ_b C_b·D_b instead of
    m·D_max.  Same triangles (each exactly once), different row order.
    """
    plan = wedge_bucket_plan(g, chunk, budget)
    out = []
    for bucket in plan:
        ids = bucket.eids[: bucket.n_real].astype(np.int64)
        for lo in range(0, len(ids), bucket.chunk):
            e_ab, e_aw, e_bw, _ = _wedge_hits_ids_np(
                g, ids[lo : lo + bucket.chunk], bucket.D)
            if len(e_ab):
                out.append(np.stack([e_ab, e_aw, e_bw], axis=1))
    if not out:
        return np.zeros((0, 3), np.int32)
    return np.concatenate(out, axis=0).astype(np.int32)


def support_from_triangle_list(tris: np.ndarray, m: int) -> np.ndarray:
    """sup(e) from a static triangle list (all edges alive).

    Peeling needs the triangle list anyway, so deriving the initial supports
    from it saves a second full wedge enumeration.
    """
    sup = np.zeros(m, dtype=np.int64)
    if len(tris):
        flat = np.asarray(tris).reshape(-1)
        counts = np.bincount(flat[flat < m], minlength=m)
        sup[: len(counts)] += counts[:m]
    return sup


# ---------------------------------------------------------------------------
# edge -> triangle incidence CSR (frontier peel preprocessing, DESIGN.md §3)
# ---------------------------------------------------------------------------

def triangle_incidence_np(tris: np.ndarray, m: int) -> tuple[np.ndarray, np.ndarray]:
    """CSR index from edge id to the ids of triangles containing it.

    Args:
      tris: (T, 3) edge-id triples; rows may reference the drop slot (id >= m,
        used for padding) — those entries are excluded.
      m: number of real edges.

    Returns:
      (tri_indptr, tri_ids): ``tri_ids[tri_indptr[e]:tri_indptr[e+1]]`` are
      the triangle row indices containing edge ``e``.  len(tri_ids) == 3T for
      an unpadded list (each triangle appears in exactly 3 rows).
    """
    tris = np.asarray(tris)
    if len(tris) == 0 or m == 0:
        return np.zeros(m + 1, np.int32), np.zeros(0, np.int32)
    flat_e = tris.reshape(-1).astype(np.int64)
    flat_t = np.repeat(np.arange(len(tris), dtype=np.int64), 3)
    keep = flat_e < m
    flat_e, flat_t = flat_e[keep], flat_t[keep]
    order = np.argsort(flat_e, kind="stable")
    tri_ids = flat_t[order].astype(np.int32)
    indptr = np.zeros(m + 1, dtype=np.int64)
    np.add.at(indptr, flat_e + 1, 1)
    return np.cumsum(indptr).astype(np.int32), tri_ids


def triangle_density(m: int, n_tris: int) -> float:
    """Incidence entries per edge slot, 3T / E — the routing statistic the
    fused frontier-peel kernel shares with the dense-core dispatch
    (DESIGN.md §13).  Each fused removal round streams the FULL triangle
    list, so the dense sweep amortizes its one-hot matmuls only when the
    lane is triangle-dense; below ~1 entry per edge the sparse
    gather/scatter chain wins."""
    if m <= 0:
        return 0.0
    return 3.0 * n_tris / m


# ---------------------------------------------------------------------------
# JAX path
# ---------------------------------------------------------------------------

def _row_lower_bound_jax(nbrs, lo, hi, target, iters):
    n_entries = nbrs.shape[0]

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) >> 1
        midc = jnp.minimum(mid, max(n_entries - 1, 0))
        less = jnp.where(lo < hi, nbrs[midc] < target, False)
        new_lo = jnp.where(less, mid + 1, lo)
        new_hi = jnp.where(less, hi, jnp.where(lo < hi, mid, hi))
        return new_lo, new_hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo


@partial(jax.jit, static_argnames=("D", "iters", "chunk"))
def _support_scan(eids_pad, src, dst, indptr, nbrs, nbr_eid, *, D, iters, chunk):
    """Partial sup(e) from the wedges of the given oriented edges.

    Args:
      eids_pad: (E_pad,) edge ids to enumerate, padded with ``m`` sentinels to
        a multiple of ``chunk``.
      src, dst: (m + 1,) oriented endpoints with a zero pad slot at index m.
      D: static wedge-slot bound — max out-degree of the *source rows of this
        bucket*, not of the whole graph (the skew-aware part, DESIGN.md §4).

    Returns sup over (m + 1) slots; the last slot absorbs masked scatters.
    """
    m = src.shape[0] - 1
    n_chunks = eids_pad.shape[0] // chunk
    sup0 = jnp.zeros(m + 1, jnp.int32)

    def one_chunk(sup, c):
        eids = jax.lax.dynamic_slice(eids_pad, (c * chunk,), (chunk,))
        live = eids < m
        a = src[eids]
        b = dst[eids]
        slot = jnp.arange(D, dtype=jnp.int32)[None, :]
        row_start = indptr[a][:, None]
        row_len = (indptr[a + 1] - indptr[a])[:, None]
        valid = (slot < row_len) & live[:, None]
        pos_aw = jnp.minimum(row_start + slot, max(nbrs.shape[0] - 1, 0))
        w = nbrs[pos_aw]
        lo = jnp.broadcast_to(indptr[b][:, None], (chunk, D))
        hi = jnp.broadcast_to(indptr[b + 1][:, None], (chunk, D))
        p = _row_lower_bound_jax(nbrs, lo.reshape(-1), hi.reshape(-1), w.reshape(-1), iters)
        p = p.reshape(chunk, D)
        in_row = p < indptr[b + 1][:, None]
        pc = jnp.minimum(p, max(nbrs.shape[0] - 1, 0))
        hit = valid & in_row & (nbrs[pc] == w)
        sink = jnp.int32(m)
        e_ab = jnp.where(hit, eids[:, None], sink)
        e_aw = jnp.where(hit, nbr_eid[pos_aw], sink)
        e_bw = jnp.where(hit, nbr_eid[pc], sink)
        ones = jnp.ones_like(e_ab, dtype=jnp.int32)
        sup = sup.at[e_ab].add(ones, mode="drop")
        sup = sup.at[e_aw].add(ones, mode="drop")
        sup = sup.at[e_bw].add(ones, mode="drop")
        return sup, None

    sup, _ = jax.lax.scan(one_chunk, sup0, jnp.arange(n_chunks, dtype=jnp.int32))
    return sup


def _pow2_ceil(x: int) -> int:
    return 1 << max(0, math.ceil(math.log2(max(1, x))))


def _pow4_ceil(x: int) -> int:
    """Next power of four — the coarse padding grid of the OOC batch engine
    (DESIGN.md §8): fewer distinct static shapes than pow2, at most 4x pad."""
    return 1 << (2 * max(0, math.ceil(math.log2(max(1, x)) / 2)))


@dataclasses.dataclass(frozen=True)
class WedgeBucket:
    """One power-of-two out-degree class of oriented edges."""

    eids: np.ndarray      # (E_pad,) edge ids, padded with m sentinels
    n_real: int           # real (unpadded) edge count
    D: int                # wedge-slot bound for this bucket (pow2)
    chunk: int            # scan chunk size

    @property
    def capacity(self) -> int:
        """Wedge-tensor elements this bucket materializes in total."""
        return len(self.eids) * self.D


def wedge_bucket_plan(
    g: Graph, chunk: int = 1 << 14, budget: int = 1 << 18
) -> list[WedgeBucket]:
    """Group oriented edges by the pow2 out-degree of their source row.

    Every bucket runs the wedge enumeration with its own D = 2^b covering
    source rows of length in (2^(b-1), 2^b], so a single hub vertex no longer
    inflates the wedge tensor of every chunk (the dense blow-up of the
    global-D path on power-law graphs).  ``budget`` bounds chunk*D elements
    per scan step, keeping peak memory flat across buckets.
    """
    if g.m == 0:
        return []
    row_len = (g.indptr[g.src + 1] - g.indptr[g.src]).astype(np.int64)
    # bucket index: ceil(log2(row_len)), row_len >= 1 always (dst is in src's row)
    b_idx = np.zeros(g.m, dtype=np.int64)
    nz = row_len > 1
    b_idx[nz] = np.ceil(np.log2(row_len[nz])).astype(np.int64)
    plan: list[WedgeBucket] = []
    for b in np.unique(b_idx):
        ids = np.nonzero(b_idx == b)[0].astype(np.int32)
        D = 1 << int(b)
        # chunk never exceeds the bucket itself — padding a 2-edge bucket to
        # a 16k chunk would reintroduce the blow-up bucketing removes
        c = max(1, min(chunk, budget // D, _pow2_ceil(len(ids))))
        e_pad = -(-len(ids) // c) * c
        ids_pad = np.full(e_pad, g.m, np.int32)
        ids_pad[: len(ids)] = ids
        plan.append(WedgeBucket(eids=ids_pad, n_real=len(ids), D=D, chunk=c))
    return plan


def edge_support_jax(
    g: Graph, chunk: int = 1 << 14, *, bucketed: bool = True,
    budget: int = 1 << 18,
) -> jnp.ndarray:
    """Device-path support computation (jit'd, static shapes).

    ``bucketed=True`` (default) runs the skew-aware per-bucket wedge scans;
    ``bucketed=False`` restores the single global-D scan (the seed behavior,
    kept for benchmarks and as a fallback).
    """
    if g.m == 0:
        return jnp.zeros(0, jnp.int32)
    src = jnp.asarray(np.concatenate([g.src, np.zeros(1, np.int32)]))
    dst = jnp.asarray(np.concatenate([g.dst, np.zeros(1, np.int32)]))
    indptr = jnp.asarray(g.indptr)
    nbrs = jnp.asarray(g.nbrs)
    nbr_eid = jnp.asarray(g.nbr_eid)
    iters = _search_iters(g.max_out_deg)
    if bucketed:
        plan = wedge_bucket_plan(g, chunk, budget)
    else:
        c = max(8, min(chunk, _pow2_ceil(g.m)))
        e_pad = -(-g.m // c) * c
        ids_pad = np.full(e_pad, g.m, np.int32)
        ids_pad[: g.m] = np.arange(g.m, dtype=np.int32)
        plan = [WedgeBucket(ids_pad, g.m, max(g.max_out_deg, 1), c)]
    sup = jnp.zeros(g.m + 1, jnp.int32)
    for bucket in plan:
        # trusscheck: allow[TRK104] -- bucket eid lengths and D/chunk sit on the pow2 grid wedge_bucket_plan pads to, so distinct shapes (hence compiles) are O(log) per run by design
        sup = sup + _support_scan(
            jnp.asarray(bucket.eids), src, dst, indptr, nbrs, nbr_eid,
            D=bucket.D, iters=iters, chunk=bucket.chunk,
        )
    return sup[: g.m]


# ---------------------------------------------------------------------------
# dense/sparse dispatch (DESIGN.md §2)
# ---------------------------------------------------------------------------

def dense_core_stats(g: Graph) -> tuple[np.ndarray, float]:
    """(sorted active vertices, edge density over active vertices)."""
    if g.m == 0:
        return np.zeros(0, np.int64), 0.0
    verts = np.unique(g.edges.reshape(-1)).astype(np.int64)
    n_act = len(verts)
    density = 2.0 * g.m / (n_act * (n_act - 1)) if n_act > 1 else 0.0
    return verts, density


def edge_support_auto(
    g: Graph,
    *,
    dense_threshold: float = 0.125,
    dense_max_n: int = 4096,
) -> np.ndarray:
    """Support with sparse/dense routing (DESIGN.md §2).

    Dense-core partitions (active-vertex density above ``dense_threshold``
    and small enough for an adjacency tile set) go to the blocked dense
    matmul path — the Pallas MXU kernel on TPU, its jnp reference elsewhere.
    Sparse graphs take the bucketed wedge enumeration.
    """
    if g.m == 0:
        return np.zeros(0, np.int64)
    verts, density = dense_core_stats(g)
    n_act = len(verts)
    if n_act <= dense_max_n and density >= dense_threshold:
        from repro.kernels.triangle_count.ops import dense_edge_support

        relabel = np.zeros(int(verts.max()) + 1, np.int64)
        relabel[verts] = np.arange(n_act)
        compact = relabel[g.edges.astype(np.int64)].astype(np.int32)
        use_kernel = jax.default_backend() == "tpu"
        return dense_edge_support(
            n_act, compact, use_kernel=use_kernel, interpret=not use_kernel
        )
    return np.asarray(edge_support_jax(g)).astype(np.int64)


# ---------------------------------------------------------------------------
# Graph-store triangle spilling (DESIGN.md §15): the incremental per-round
# triangle list is the largest single array the out-of-core round loop holds
# across a yield, so it rides the same chunked store as the graph arrays.
# ---------------------------------------------------------------------------

def spill_triangles(store, key: str, tris: np.ndarray) -> None:
    """Spill a round's triangle list (local edge-id triples) to ``store``
    under ``key``; an existing list under the key is replaced."""
    store.put(key, np.ascontiguousarray(tris, dtype=np.int64).reshape(-1, 3))


def load_triangles(store, key: str) -> np.ndarray:
    """Reload a triangle list spilled by :func:`spill_triangles`."""
    return np.asarray(store.get(key), dtype=np.int64).reshape(-1, 3)


def iter_triangle_chunks(store, key: str):
    """Stream a spilled triangle list chunk-wise: yields (rows, 3) int64
    blocks sized by the store's chunk granularity, so a consumer's peak
    working set is one chunk instead of the whole 3·T list (the OOC-store
    fix of DESIGN.md §16)."""
    for part in store.get_chunks(key):
        yield np.asarray(part, dtype=np.int64).reshape(-1, 3)


def stream_spill_triangles(store, key: str):
    """An appendable (rows, 3) triangle writer — the streaming counterpart
    of :func:`spill_triangles`.  The key is registered at ``close()``; on a
    chunked store, chunk files flush incrementally so the producer never
    holds the full list either."""
    return store.stream_put(key, np.int64, (3,))
