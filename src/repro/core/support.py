"""Edge-support (per-edge triangle count) computation.

This is the paper's computational hot spot (Alg 2 Step 2 / Alg 3 Step 6).
The vectorized form keeps the paper's O(m^1.5) bound (Theorem 1):

  * every edge is oriented low-rank -> high-rank (rank = (deg, id) order), so
    out-degrees are O(sqrt(m));
  * for each oriented edge (a->b), every out-neighbor w of a is tested for
    membership in N+(b) — a *binary search* into the sorted CSR row of b
    (the TPU-idiomatic replacement for the paper's hashtable);
  * a hit identifies triangle {a,b,w} exactly once (forward algorithm) and
    credits support to all three edge ids.

Shapes are static: edges are processed in fixed-size chunks of C edges, each
expanded to (C, D) wedge candidates where D = max oriented out-degree.
Total work O(m * D) = O(m^1.5); memory O(C * D).

Two implementations share the same logic:
  * ``edge_support_np``   — numpy, host-side (oracle + preprocessing);
  * ``edge_support_jax``  — jit'd lax.scan over chunks (device path).
The dense-tile Pallas kernel (kernels/triangle_count) covers the dense-core
regime; see DESIGN.md §2.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph


def _search_iters(max_row: int) -> int:
    return max(1, math.ceil(math.log2(max_row + 1))) if max_row > 0 else 1


# ---------------------------------------------------------------------------
# numpy path
# ---------------------------------------------------------------------------

def _row_lower_bound_np(nbrs, lo, hi, target, iters):
    lo = lo.astype(np.int64).copy()
    hi = hi.astype(np.int64).copy()
    n_entries = len(nbrs)
    for _ in range(iters):
        mid = (lo + hi) >> 1
        midc = np.minimum(mid, max(n_entries - 1, 0))
        less = np.where(lo < hi, nbrs[midc] < target, False)
        lo = np.where(less, mid + 1, lo)
        hi = np.where(less, hi, np.where(lo < hi, mid, hi))
    return lo


def _wedge_hits_np(g: Graph, e_lo: int, e_hi: int):
    """For edge ids [e_lo, e_hi): returns (eid, e_aw, e_bw, hit) flat arrays."""
    a = g.src[e_lo:e_hi].astype(np.int64)
    b = g.dst[e_lo:e_hi].astype(np.int64)
    C = len(a)
    D = g.max_out_deg
    if C == 0 or D == 0:
        z = np.zeros(0, np.int64)
        return z, z, z, np.zeros(0, bool)
    slot = np.arange(D, dtype=np.int64)[None, :]
    row_start = g.indptr[a].astype(np.int64)[:, None]
    row_len = (g.indptr[a + 1] - g.indptr[a]).astype(np.int64)[:, None]
    valid = slot < row_len
    pos_aw = np.minimum(row_start + slot, max(len(g.nbrs) - 1, 0))
    w = g.nbrs[pos_aw].astype(np.int64)
    # binary search w in row b
    lo = np.broadcast_to(g.indptr[b].astype(np.int64)[:, None], (C, D))
    hi = np.broadcast_to(g.indptr[b + 1].astype(np.int64)[:, None], (C, D))
    iters = _search_iters(g.max_out_deg)
    p = _row_lower_bound_np(g.nbrs, lo.reshape(-1), hi.reshape(-1), w.reshape(-1), iters)
    p = p.reshape(C, D)
    in_row = p < g.indptr[b + 1].astype(np.int64)[:, None]
    pc = np.minimum(p, max(len(g.nbrs) - 1, 0))
    hit = valid & in_row & (g.nbrs[pc] == w)
    eid = np.broadcast_to(np.arange(e_lo, e_hi, dtype=np.int64)[:, None], (C, D))
    e_aw = g.nbr_eid[pos_aw].astype(np.int64)
    e_bw = g.nbr_eid[pc].astype(np.int64)
    f = hit.reshape(-1)
    return eid.reshape(-1)[f], e_aw.reshape(-1)[f], e_bw.reshape(-1)[f], f


def edge_support_np(g: Graph, chunk: int = 1 << 16) -> np.ndarray:
    """Support of every canonical edge (numpy, chunked)."""
    sup = np.zeros(g.m, dtype=np.int64)
    for e_lo in range(0, g.m, chunk):
        e_hi = min(e_lo + chunk, g.m)
        e_ab, e_aw, e_bw, _ = _wedge_hits_np(g, e_lo, e_hi)
        np.add.at(sup, e_ab, 1)
        np.add.at(sup, e_aw, 1)
        np.add.at(sup, e_bw, 1)
    return sup


def list_triangles_np(g: Graph, chunk: int = 1 << 16) -> np.ndarray:
    """Static triangle list: (T, 3) int32 edge-id triples, each triangle once."""
    out = []
    for e_lo in range(0, g.m, chunk):
        e_hi = min(e_lo + chunk, g.m)
        e_ab, e_aw, e_bw, _ = _wedge_hits_np(g, e_lo, e_hi)
        out.append(np.stack([e_ab, e_aw, e_bw], axis=1))
    if not out:
        return np.zeros((0, 3), np.int32)
    return np.concatenate(out, axis=0).astype(np.int32)


# ---------------------------------------------------------------------------
# JAX path
# ---------------------------------------------------------------------------

def _row_lower_bound_jax(nbrs, lo, hi, target, iters):
    n_entries = nbrs.shape[0]

    def body(_, carry):
        lo, hi = carry
        mid = (lo + hi) >> 1
        midc = jnp.minimum(mid, max(n_entries - 1, 0))
        less = jnp.where(lo < hi, nbrs[midc] < target, False)
        new_lo = jnp.where(less, mid + 1, lo)
        new_hi = jnp.where(less, hi, jnp.where(lo < hi, mid, hi))
        return new_lo, new_hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo


@partial(jax.jit, static_argnames=("D", "iters", "chunk"))
def _support_scan(src, dst, indptr, nbrs, nbr_eid, m_real, *, D, iters, chunk):
    """sup(e) for all edges; src/dst padded to a multiple of ``chunk``."""
    m_pad = src.shape[0]
    n_chunks = m_pad // chunk
    sup0 = jnp.zeros(m_pad + 1, jnp.int32)  # +1 slot absorbs padded scatters

    def one_chunk(sup, c):
        e0 = c * chunk
        eids = e0 + jnp.arange(chunk, dtype=jnp.int32)
        live = eids < m_real
        a = src[eids]
        b = dst[eids]
        slot = jnp.arange(D, dtype=jnp.int32)[None, :]
        row_start = indptr[a][:, None]
        row_len = (indptr[a + 1] - indptr[a])[:, None]
        valid = (slot < row_len) & live[:, None]
        pos_aw = jnp.minimum(row_start + slot, max(nbrs.shape[0] - 1, 0))
        w = nbrs[pos_aw]
        lo = jnp.broadcast_to(indptr[b][:, None], (chunk, D))
        hi = jnp.broadcast_to(indptr[b + 1][:, None], (chunk, D))
        p = _row_lower_bound_jax(nbrs, lo.reshape(-1), hi.reshape(-1), w.reshape(-1), iters)
        p = p.reshape(chunk, D)
        in_row = p < indptr[b + 1][:, None]
        pc = jnp.minimum(p, max(nbrs.shape[0] - 1, 0))
        hit = valid & in_row & (nbrs[pc] == w)
        sink = jnp.int32(sup.shape[0] - 1)
        e_ab = jnp.where(hit, eids[:, None], sink)
        e_aw = jnp.where(hit, nbr_eid[pos_aw], sink)
        e_bw = jnp.where(hit, nbr_eid[pc], sink)
        ones = jnp.ones_like(e_ab, dtype=jnp.int32)
        sup = sup.at[e_ab].add(ones, mode="drop")
        sup = sup.at[e_aw].add(ones, mode="drop")
        sup = sup.at[e_bw].add(ones, mode="drop")
        return sup, None

    sup, _ = jax.lax.scan(one_chunk, sup0, jnp.arange(n_chunks, dtype=jnp.int32))
    return sup[:-1]


def edge_support_jax(g: Graph, chunk: int = 1 << 14) -> jnp.ndarray:
    """Device-path support computation (jit'd, static shapes)."""
    if g.m == 0:
        return jnp.zeros(0, jnp.int32)
    chunk = min(chunk, max(256, 1 << math.ceil(math.log2(g.m))))
    m_pad = ((g.m + chunk - 1) // chunk) * chunk
    pad = m_pad - g.m
    src = jnp.asarray(np.concatenate([g.src, np.zeros(pad, np.int32)]))
    dst = jnp.asarray(np.concatenate([g.dst, np.zeros(pad, np.int32)]))
    sup = _support_scan(
        src, dst, jnp.asarray(g.indptr), jnp.asarray(g.nbrs),
        jnp.asarray(g.nbr_eid), jnp.int32(g.m),
        D=max(g.max_out_deg, 1), iters=_search_iters(g.max_out_deg), chunk=chunk,
    )
    return sup[: g.m]
