"""Vertex partitioners for the I/O-efficient algorithms (paper Section 5.1).

The paper uses the linear-time partitioners of Chu & Cheng [13], which split
the current graph into p >= 2|G|/M parts whose *neighborhood subgraphs* fit
in memory M.  We provide the two practical variants:

* ``sequential_partition`` — contiguous vertex-id blocks sized so that the
  estimated NS working set (sum of incident degrees) stays under budget
  (Chu–Cheng's first, scan-order partitioner).
* ``random_partition`` — hash vertices into p parts (Chu–Cheng's randomized
  partitioner: O(m/M) iterations w.h.p., no seed-set memory).

``budget`` is expressed in *edge entries* (the 2012 paper's M measured in
bytes; on TPU the analogue is per-device working-set entries).
"""

from __future__ import annotations

from typing import Iterator, List

import numpy as np

from repro.core.graph import Graph


def _ns_cost(g: Graph) -> np.ndarray:
    """Per-vertex NS working-set estimate: its full incident degree."""
    return g.deg.astype(np.int64)


def sequential_partition(g: Graph, budget: int) -> List[np.ndarray]:
    """Contiguous vertex blocks with estimated NS size <= budget each."""
    cost = _ns_cost(g)
    active = np.nonzero(cost > 0)[0]
    if len(active) == 0:
        return []
    parts: List[np.ndarray] = []
    cur: list[int] = []
    acc = 0
    for v in active:
        c = int(cost[v])
        if cur and acc + c > budget:
            parts.append(np.asarray(cur, dtype=np.int32))
            cur, acc = [], 0
        cur.append(int(v))
        acc += c
    if cur:
        parts.append(np.asarray(cur, dtype=np.int32))
    return parts


def random_partition(g: Graph, budget: int, seed: int = 0) -> List[np.ndarray]:
    """Hash vertices into ceil(total_cost / budget) parts (randomized)."""
    cost = _ns_cost(g)
    active = np.nonzero(cost > 0)[0]
    if len(active) == 0:
        return []
    total = int(cost[active].sum())
    p = max(1, int(np.ceil(total / max(budget, 1))))
    rng = np.random.default_rng(seed)
    assign = rng.integers(0, p, size=len(active))
    return [active[assign == i].astype(np.int32) for i in range(p) if (assign == i).any()]


PARTITIONERS = {
    "sequential": sequential_partition,
    "random": random_partition,
}
