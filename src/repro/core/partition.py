"""Vertex partitioners + partition batches for the I/O-efficient algorithms.

The paper (Section 5.1) uses the linear-time partitioners of Chu & Cheng
[13], which split the current graph into p >= 2|G|/M parts whose
*neighborhood subgraphs* fit in memory M.  We provide the two practical
variants:

* ``sequential_partition`` — contiguous vertex-id blocks sized so that the
  estimated NS working set (sum of incident degrees) stays under budget
  (Chu–Cheng's first, scan-order partitioner).
* ``random_partition`` — hash vertices into p parts (Chu–Cheng's randomized
  partitioner: O(m/M) iterations w.h.p., no seed-set memory), then spill the
  overflow of cost-heavy bins so every bin respects the budget.
* ``locality_partition`` — triangle-aware greedy cost-bounded growth over
  the full adjacency: parts grow around the highest estimated-triangle-
  volume vertices (``graph.closed_wedge_estimate``) and admit candidates by
  closed-wedge gain, so each part captures its own triangles instead of
  spraying them across parts.  In the spirit of PKT's observation (Kabir &
  Madduri) that most triangle work concentrates in a small cohesive region;
  more internal triangles per round means fewer O(|E|/M) partition rounds
  (DESIGN.md §9, §11).

``budget`` is expressed in *edge entries* (the 2012 paper's M measured in
bytes; on TPU the analogue is per-device working-set entries).

On top of the partitioners this module builds :class:`PartitionBatch` — the
device-resident form of one partition round (DESIGN.md §8):

* every NS(P) is extracted in one O(m log m) sweep (``ns_edge_lists``) and
  compacted to local vertex ids;
* parts are bin-packed into power-of-two-capacity lanes (a lane is a
  disjoint union of part slices — trussness is per-component, so one peel
  of a packed lane equals the per-part peels) and padded to a single static
  shape (edges, triangles, incidence CSR), so the batched local peel
  (``peel.peel_classes_batched``) runs every lane of a bucket in ONE device
  call with one compile per pow2 bucket shape;
* padding lanes are dead (``alive`` False, triangles pointing at the
  per-lane drop slot), so they can never contribute support.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.graph import (Graph, closed_wedge_estimate, compact_index,
                              undirected_csr, wedge_weight)


class PartitionBudgetWarning(UserWarning):
    """A single vertex's NS estimate exceeds the partition budget.

    The sequential partitioner must still emit such a vertex as a singleton
    part, so the part's working set overshoots the budget; the driver's
    ``max_part_edges`` accounting records the actual overshoot.
    """

    def __init__(self, n_over: int, budget: int, max_cost: int):
        self.n_over = n_over
        self.budget = budget
        self.max_cost = max_cost
        super().__init__(
            f"{n_over} vertex(es) have NS cost above budget={budget} "
            f"(max cost {max_cost}); emitting over-budget singleton parts")


def _ns_cost(g: Graph) -> np.ndarray:
    """Per-vertex NS working-set estimate: its full incident degree."""
    return g.deg.astype(np.int64)


def _warn_over_budget(cost: np.ndarray, active: np.ndarray, budget: int,
                      stacklevel: int = 3) -> None:
    """Consistent PartitionBudgetWarning across all partitioners: a vertex
    whose own NS estimate exceeds the budget must become an over-budget
    singleton part no matter how vertices are assigned."""
    over = cost[active] > budget
    if over.any():
        warnings.warn(
            PartitionBudgetWarning(int(over.sum()), int(budget),
                                   int(cost[active][over].max())),
            stacklevel=stacklevel)


def _pack_cost_bounded(vertices, cost: np.ndarray,
                       budget: int) -> List[np.ndarray]:
    """Greedy scan-order packing: split ``vertices`` into consecutive
    groups whose summed cost stays within ``budget`` (an over-budget
    vertex becomes a singleton group)."""
    parts: List[np.ndarray] = []
    cur: list[int] = []
    acc = 0
    for v in vertices:
        c = int(cost[v])
        if cur and acc + c > budget:
            parts.append(np.asarray(cur, dtype=np.int32))
            cur, acc = [], 0
        cur.append(int(v))
        acc += c
    if cur:
        parts.append(np.asarray(cur, dtype=np.int32))
    return parts


def round_up_to_multiple(count: int, multiple: int) -> int:
    """Smallest positive count >= ``count`` divisible by ``multiple`` — the
    lane/row padding rule shared by the device-count-aware lane packing
    below and every sharded entry point (``distributed.pad_bucket_lanes``,
    the candidate-peel triangle rows; DESIGN.md §10)."""
    return max(1, -(-count // multiple)) * multiple


def _first_fit_decreasing(sizes: Sequence[int],
                          capacity: int) -> List[List[int]]:
    """Pack item indices into bins of ``capacity``, first-fit-decreasing
    (an item above the capacity still gets its own bin).  Used by the lane
    packer; the locality partitioner's region merge uses the triangle-aware
    2-D variant below."""
    order = sorted(range(len(sizes)), key=lambda i: -sizes[i])
    bins: List[List[int]] = []
    room: List[int] = []
    for i in order:
        s = sizes[i]
        for j in range(len(bins)):
            if room[j] >= s:
                bins[j].append(i)
                room[j] -= s
                break
        else:
            bins.append([i])
            room.append(capacity - s)
    return bins


def _first_fit_decreasing_2d(costs: Sequence[int], tris: Sequence[int],
                             cap_cost: int, cap_tri: int) -> List[List[int]]:
    """First-fit-decreasing on cost with a soft triangle-budget dimension.

    The cost dimension is the *validity* constraint (a part's NS working
    set must fit the budget) and keeps the classic FFD insertion order and
    guarantee: a new bin opens exactly when the cost fits nowhere, so no
    two bins are at most half full and the bin count stays < 2·OPT + 1
    on the cost dimension.  The triangle dimension steers placement among
    the cost-feasible bins — first bin where BOTH fit, else the
    cost-feasible bin with the most triangle room — so triangle-dense
    fragments spread across bins (balanced device peels) instead of
    piling into the first one.
    """
    order = sorted(range(len(costs)), key=lambda i: (-costs[i], -tris[i]))
    bins: List[List[int]] = []
    room_c: List[int] = []
    room_t: List[int] = []
    for i in order:
        placed = -1
        for j in range(len(bins)):
            if room_c[j] >= costs[i] and room_t[j] >= tris[i]:
                placed = j
                break
        if placed < 0:
            feasible = [j for j in range(len(bins)) if room_c[j] >= costs[i]]
            if feasible:
                placed = max(feasible, key=lambda j: room_t[j])
        if placed < 0:
            bins.append([i])
            room_c.append(cap_cost - costs[i])
            room_t.append(cap_tri - tris[i])
        else:
            bins[placed].append(i)
            room_c[placed] -= costs[i]
            room_t[placed] -= tris[i]
    return bins


def sequential_partition(g: Graph, budget: int) -> List[np.ndarray]:
    """Contiguous vertex blocks with estimated NS size <= budget each."""
    cost = _ns_cost(g)
    active = np.nonzero(cost > 0)[0]
    if len(active) == 0:
        return []
    _warn_over_budget(cost, active, budget)
    return _pack_cost_bounded(active, cost, budget)


def random_partition(g: Graph, budget: int, seed: int = 0) -> List[np.ndarray]:
    """Hash vertices into ceil(total_cost / budget) parts (randomized).

    Hashing ignores per-vertex NS cost, so on skewed graphs a bin's summed
    cost can exceed the budget by large factors; each overflowing bin keeps
    its largest under-budget prefix (at least one vertex — the over-budget
    singleton case warns via :class:`PartitionBudgetWarning`) and the spill
    is repacked cost-bounded, so every emitted part respects the budget the
    same way ``sequential_partition`` does.
    """
    cost = _ns_cost(g)
    active = np.nonzero(cost > 0)[0]
    if len(active) == 0:
        return []
    _warn_over_budget(cost, active, budget)
    total = int(cost[active].sum())
    p = max(1, int(np.ceil(total / max(budget, 1))))
    rng = np.random.default_rng(seed)
    assign = rng.integers(0, p, size=len(active))
    parts: List[np.ndarray] = []
    spill: List[np.ndarray] = []
    for i in range(p):
        P = active[assign == i]
        if len(P) == 0:
            continue
        csum = np.cumsum(cost[P])
        k = max(int(np.searchsorted(csum, budget, side="right")), 1)
        parts.append(P[:k].astype(np.int32))
        if k < len(P):
            spill.append(P[k:])
    if spill:
        # repack the overflow cost-bounded (largest first so heavy vertices
        # anchor their own bins); deterministic given the seed
        sp = np.concatenate(spill)
        sp = sp[np.argsort(-cost[sp], kind="stable")]
        parts.extend(_pack_cost_bounded(sp, cost, budget))
    return parts


# Zone sizing of one locality round: parts are grown until the covered NS
# cost reaches max(zone_mult * budget, total_cost / _ZONE_FRACTION).
# Small multiples keep each round's scan focused on the surviving triangle
# mass (high per-round capture, DESIGN.md §11); the fraction floor bounds
# the round count on graphs much larger than the budget.  The multiple
# adapts to the *observed* capture of the previous round (``prev_locality``
# below): _ZONE_BUDGET_MULT is the cold-start default, and the adaptive
# range spans [_ZONE_MULT_MIN, _ZONE_MULT_MAX].
_ZONE_BUDGET_MULT = 4
_ZONE_FRACTION = 16
_ZONE_MULT_MIN = 2.0
_ZONE_MULT_MAX = 16.0


def _zone_mult(prev_locality: float | None) -> float:
    """Zone multiple from the previous round's observed ``tri_locality``.

    High capture means the zoned cover is keeping triangles internal — a
    larger zone amortizes the per-round NS sweep over more progress; low
    capture means the zone is spraying triangles across parts, so shrink
    it back toward the budget and refocus on the dense core.  Linear in
    the observed fraction, clamped to [_ZONE_MULT_MIN, _ZONE_MULT_MAX];
    the cold-start round (no observation yet) keeps the historical 4x.
    """
    if prev_locality is None:
        return float(_ZONE_BUDGET_MULT)
    frac = min(1.0, max(0.0, float(prev_locality)))
    return _ZONE_MULT_MIN + (_ZONE_MULT_MAX - _ZONE_MULT_MIN) * frac


def locality_partition(
    g: Graph, budget: int, prev_locality: float | None = None,
) -> List[np.ndarray]:
    """Triangle-aware zoned growth over the adjacency (DESIGN.md §11).

    One call partitions the current *zone* — the triangle-densest region of
    the working graph, up to ``max(4 * budget, total_cost / 16)`` of covered
    NS cost — and defers the rest of the graph to later rounds.  The paper's
    partition loop already repeats until no edges remain, so a partial cover
    is sound (Lemma 1 per part; uncovered edges simply stay in the working
    graph), and it is what keeps each round's scan on triangles it can
    actually capture: a whole-graph cover at the deep ``m/32`` budget is
    forced to spray the cohesive core across ~``total/budget`` parts, so the
    same surviving triangles get re-scanned round after round.

    Within the zone, each part grows from the unassigned vertex with the
    largest estimated triangle volume (``graph.closed_wedge_estimate``, a
    degree-capped wedge count over the edge list).  The growth keeps a
    persistent
    candidate pool — every unassigned neighbor of the part so far — and
    admits candidates by **closed-wedge gain**: when vertex ``v`` joins the
    part, each unassigned neighbor ``u`` accrues
    ``min(deg(u), deg(v)) - 1`` (the wedges (u, v, ·) that co-locating u
    would close into part-internal triangles), with edges-into-part and
    cheap cost as tiebreaks.  Admission charges **marginal NS cost**
    ``deg(u) - edges_into_part(u)``: the edges u shares with the part are
    already in NS(P), so the accumulated charge equals the true ``|NS(P)|``
    (the working set the budget actually protects) instead of the
    ``Σ deg`` over-estimate — cohesive parts legitimately hold more
    vertices.  An over-budget candidate is skipped (a hub seeds its own
    part later — or joins once enough of its neighborhood is in and its
    marginal cost fits).  This is the PKT observation (Kabir & Madduri,
    *Shared-memory Graph Truss Decomposition*) — triangle volume, not edge
    count, is what work division must balance — applied to the paper's
    Section-5.1 partitioning step.

    Grown fragments are merged first-fit over (NS cost, triangle estimate)
    (:func:`_first_fit_decreasing_2d`): the cost budget stays the hard
    validity constraint (fragment costs are true NS sizes, and a union's NS
    is at most the sum), while the per-part triangle estimate is balanced
    toward ``total_tri * budget / total_cost`` so triangle-dense fragments
    spread across bins instead of piling up.  ``OocStats.tri_locality``
    reports the captured-triangle fraction per run; ``tri_est_error`` the
    estimate's accuracy.
    """
    cost = _ns_cost(g)
    active = np.nonzero(cost > 0)[0]
    if len(active) == 0:
        return []
    _warn_over_budget(cost, active, budget)
    indptr, nbrs = undirected_csr(g)
    indptr = np.asarray(indptr, dtype=np.int64)
    nbrs64 = np.asarray(nbrs, dtype=np.int64)
    deg = g.deg.astype(np.int64)
    tri_est = closed_wedge_estimate(g)
    unassigned = cost > 0
    zone_cost = max(int(_zone_mult(prev_locality) * budget),
                    int(cost[active].sum()) // _ZONE_FRACTION)
    # seeds in descending triangle-volume order (NS cost as tiebreak): the
    # triangle-dense core is captured while the zone is still empty, the
    # sparse periphery mops up in later rounds
    seed_order = active[np.lexsort((-cost[active], -tri_est[active]))]
    seed_pos = 0
    # per-part candidate scores, reset lazily via the stamp (the arrays are
    # only trusted where stamp == part id)
    gain = np.zeros(g.n, dtype=np.int64)      # closed-wedge gain vs part
    ecnt = np.zeros(g.n, dtype=np.int64)      # edges into the part
    stamp = np.full(g.n, -1, dtype=np.int64)
    parts: List[np.ndarray] = []
    part_cost: List[int] = []                 # true |NS| per grown fragment
    part_tri: List[int] = []
    covered = 0
    while covered < zone_cost:
        while seed_pos < len(seed_order) and not unassigned[seed_order[seed_pos]]:
            seed_pos += 1
        if seed_pos >= len(seed_order):
            break
        s = int(seed_order[seed_pos])
        part_id = len(parts)
        unassigned[s] = False
        acc = int(cost[s])
        chunks = [np.array([s], dtype=np.int64)]
        newly = chunks[0]
        pool = np.zeros(0, dtype=np.int64)
        while acc < budget:
            # score the unassigned neighbors of the newly admitted vertices
            starts = indptr[newly]
            cnt = indptr[newly + 1] - starts
            tot = int(cnt.sum())
            if tot:
                flat = np.repeat(starts - (np.cumsum(cnt) - cnt), cnt) \
                    + np.arange(tot)
                cand = nbrs64[flat]
                src = np.repeat(newly, cnt)
                keep = unassigned[cand]
                cand, src = cand[keep], src[keep]
            else:
                cand = src = np.zeros(0, dtype=np.int64)
            if len(cand):
                uniq = np.unique(cand)
                stale = stamp[uniq] != part_id
                gain[uniq[stale]] = 0
                ecnt[uniq[stale]] = 0
                stamp[uniq] = part_id
                w = wedge_weight(deg[cand], deg[src])
                np.add.at(gain, cand, w)
                np.add.at(ecnt, cand, 1)
                pool = np.unique(np.concatenate([pool, uniq]))
            pool = pool[unassigned[pool]]
            if len(pool) == 0:
                break
            # closed-wedge gain first, edges-into-part then cheap marginal
            # cost as tiebreaks.  Candidates whose marginal cost exceeds the
            # remaining budget are skipped, then the maximal scored prefix
            # that fits is admitted; the rest stay pooled for the next
            # level or part.  (The prefix charges each candidate's marginal
            # cost against the part BEFORE the batch — edges between
            # co-admitted candidates are charged twice, so the accumulated
            # charge only over-estimates |NS(P)|: the budget holds.)
            mc = np.maximum(cost[pool] - ecnt[pool], 0)
            order = np.lexsort((mc, -ecnt[pool], -gain[pool]))
            ranked = pool[order]
            mcr = mc[order]
            fit1 = mcr <= budget - acc
            ranked, mcr = ranked[fit1], mcr[fit1]
            fits = acc + np.cumsum(mcr) <= budget
            take = ranked[fits]
            if len(take) == 0:
                break
            unassigned[take] = False
            acc += int(mcr[fits].sum())
            chunks.append(take)
            newly = take
        P = np.concatenate(chunks)
        parts.append(P.astype(np.int32))
        part_cost.append(acc)
        part_tri.append(int(tri_est[P].sum()))
        covered += acc
    # Merge the grown fragments first-fit over (NS cost, triangle
    # estimate): once a seed's cohesive surroundings are claimed, later
    # seeds fragment — packing fragments into budget-capacity bins keeps
    # the part count near ceil(covered / budget) instead of one scan per
    # fragment.  A union of fragments is still a valid part (|NS| is
    # subadditive, the triangle estimate additive), and co-locating
    # fragments can only turn crossing edges internal and capture more
    # triangles; the soft triangle capacity spreads triangle volume evenly
    # across the merged bins.
    if len(parts) > 1:
        total_c = sum(part_cost)
        cap_tri = max(1, -(-sum(part_tri) * budget // max(total_c, 1)))
        bins = _first_fit_decreasing_2d(part_cost, part_tri, budget, cap_tri)
        parts = [np.concatenate([parts[i] for i in b]) for b in bins]
    return parts


PARTITIONERS = {
    "sequential": sequential_partition,
    "random": random_partition,
    "locality": locality_partition,
}


# ---------------------------------------------------------------------------
# Partition batches (DESIGN.md §8)
# ---------------------------------------------------------------------------

def ns_edge_lists(
    g: Graph, parts: Sequence[np.ndarray],
    part_of: np.ndarray | None = None,
) -> List[Tuple[np.ndarray, np.ndarray]]:
    """All NS(P_i) edge lists in one sweep: O(m log m) instead of p·O(n+m).

    An edge belongs to NS(P) for the part(s) of its endpoints (at most two),
    and is *internal* exactly when both endpoints share a part — so one
    part-assignment array plus one sort yields every per-part
    ``(edge_ids, internal)`` pair that ``graph.neighborhood_subgraph`` would
    produce, with edge ids ascending (parent canonical order preserved).
    Vertices outside every part contribute nothing.  ``part_of`` may be
    passed when the caller already built the vertex→part array.
    """
    if part_of is None:
        part_of = np.full(g.n, -1, dtype=np.int64)
        for i, P in enumerate(parts):
            part_of[np.asarray(P, dtype=np.int64)] = i
    e = g.edges.astype(np.int64)
    pu = part_of[e[:, 0]]
    pv = part_of[e[:, 1]]
    internal_flag = (pu == pv) & (pu >= 0)
    eids = np.arange(g.m, dtype=np.int64)
    dup = (pv != pu) & (pv >= 0)
    owner = np.concatenate([pu, pv[dup]])
    owner_e = np.concatenate([eids, eids[dup]])
    keep = owner >= 0
    owner, owner_e = owner[keep], owner_e[keep]
    order = np.lexsort((owner_e, owner))
    owner, owner_e = owner[order], owner_e[order]
    bounds = np.searchsorted(owner, np.arange(len(parts) + 1))
    out: List[Tuple[np.ndarray, np.ndarray]] = []
    for i in range(len(parts)):
        ids = owner_e[bounds[i]:bounds[i + 1]].astype(np.int32)
        out.append((ids, internal_flag[ids]))
    return out


@dataclasses.dataclass
class PartBucket:
    """One static shape class of NS parts, packed and stacked lane-wise.

    Every array is (B, ...) with B the (pow2-padded) lane count.  A lane
    holds one or more parts laid out as disjoint edge-id slices — NS(P)
    subgraphs are independent subproblems (each slice's triangles reference
    only its own slots), and trussness is per-connected-component, so one
    peel of the packed lane equals the per-part peels.  ``part_of`` records
    the slice ownership.  Local edge id ``cap_e`` is the per-lane drop slot:
    padding triangles point at it and masked scatters land there, so padded
    slots never receive support.
    """

    cap_e: int            # padded local edge capacity per lane (pow2)
    cap_t: int            # padded triangle capacity per lane (pow2)
    n_parts: int          # parts packed into this bucket's lanes
    n_real_lanes: int     # lanes carrying parts (beyond: dead pow2 padding)
    sup: np.ndarray       # (B, cap_e) int32 initial supports
    tris: np.ndarray      # (B, cap_t, 3) int32; padding rows -> cap_e
    alive: np.ndarray     # (B, cap_e) bool; padding slots/lanes False
    indptr: np.ndarray    # (B, cap_e + 1) int32 edge->triangle incidence CSR
    tids: np.ndarray      # (B, 3 * cap_t) int32 incidence payload
    edge_ids: np.ndarray  # (B, cap_e) int64 parent edge ids; -1 on padding
    internal: np.ndarray  # (B, cap_e) bool: both endpoints in the part
    part_of: np.ndarray   # (B, cap_e) int32 part index per slot; -1 padding
    real_edges: int       # total unpadded edges across real lanes

    @property
    def n_lanes(self) -> int:
        return self.sup.shape[0]

    @property
    def shape_key(self) -> tuple[int, int, int]:
        """The compile-cache key: one jit trace per distinct value."""
        return (self.n_lanes, self.cap_e, self.cap_t)

    @property
    def padded_slots(self) -> int:
        return int(self.sup.size)


@dataclasses.dataclass
class PartitionBatch:
    """All NS(P) of one partition round, bucketed and padded for the device."""

    buckets: List[PartBucket]
    n_parts: int
    real_edges: int       # Σ NS edge counts (the round's scan volume)
    padded_slots: int     # Σ lane slots actually materialized
    max_part_edges: int   # largest single NS (budget-accounting check)
    tri_total: int = 0    # triangles enumerated on the working graph
    tri_assigned: int = 0  # of those, captured by some part (>= 2 vertices)
    tri_est: int = 0      # wedge-based triangle estimate of the working
    #                       graph (the partitioner's cost model; compare
    #                       against tri_total via OocStats.tri_est_error)
    tri_peak_rows: int = 0  # peak host-resident triangle rows while this
    #                         batch was built: the full list when ``tris``
    #                         came in as an array, retained-assigned rows
    #                         plus one store chunk when chunk-streamed

    @property
    def tri_locality(self) -> float:
        """Fraction of the round's triangles captured inside a part — the
        locality score the partitioner optimizes (1.0 = no triangle spans
        three parts)."""
        return self.tri_assigned / self.tri_total if self.tri_total else 1.0


def split_bucket_lanes(bucket: PartBucket, factor: int) -> List[PartBucket]:
    """Split a bucket along its lane axis into up to ``factor`` sub-buckets.

    Lanes are independent subproblems — each lane's triangles and incidence
    CSR reference only its own slots — so dispatching the sub-buckets one
    at a time is peel-equivalent to the single dispatch while cutting the
    device-resident footprint per launch by ``factor``.  This is the
    lane-split rung of the OOC retry ladder (DESIGN.md §12): after a device
    OOM the round's host arrays (which survive the donation) are re-peeled
    in smaller launches.  ``factor`` is clamped to the lane count; pow2
    factors keep sub-bucket lane counts on the pow2 shape grid, so a retry
    costs at most a handful of extra compiles.
    """
    B = bucket.n_lanes
    factor = max(1, min(int(factor), B))
    if factor == 1:
        return [bucket]
    step = -(-B // factor)
    out: List[PartBucket] = []
    for lo in range(0, B, step):
        hi = min(lo + step, B)
        eid = bucket.edge_ids[lo:hi]
        part = bucket.part_of[lo:hi]
        live_parts = np.unique(part[part >= 0])
        out.append(PartBucket(
            cap_e=bucket.cap_e, cap_t=bucket.cap_t,
            n_parts=int(len(live_parts)),
            n_real_lanes=int(max(0, min(hi, bucket.n_real_lanes) - lo)),
            sup=bucket.sup[lo:hi], tris=bucket.tris[lo:hi],
            alive=bucket.alive[lo:hi], indptr=bucket.indptr[lo:hi],
            tids=bucket.tids[lo:hi], edge_ids=eid,
            internal=bucket.internal[lo:hi], part_of=part,
            real_edges=int((eid >= 0).sum()),
        ))
    return out


def assign_triangles(
    g: Graph, tris: np.ndarray, part_of: np.ndarray
) -> np.ndarray:
    """Part index of every triangle; -1 when its vertices span 3 parts.

    A triangle of the working graph lies inside NS(P) exactly when at least
    two of its three vertices are in P — and two disjoint parts cannot both
    hold two of three vertices, so the assignment is unique.  This lets one
    whole-graph triangle enumeration per round replace a wedge enumeration
    per part.
    """
    if len(tris) == 0:
        return np.zeros(0, np.int64)
    e = g.edges.astype(np.int64)
    u = e[tris[:, 0], 0]
    v = e[tris[:, 0], 1]
    x = e[tris[:, 1], 0]
    y = e[tris[:, 1], 1]
    w = np.where((x == u) | (x == v), y, x)   # the third vertex
    pu, pv, pw = part_of[u], part_of[v], part_of[w]
    two = np.where(pu == pv, pu, np.where(pu == pw, pu,
                   np.where(pv == pw, pv, -1)))
    return two


def build_partition_batch(
    g: Graph,
    parts: Sequence[np.ndarray],
    *,
    with_incidence: bool = True,
    pad_lanes_pow2: bool = True,
    lane_capacity: int | None = None,
    lane_multiple: int = 1,
    tris: np.ndarray | None = None,
    shape_ladder: Sequence[tuple[int, int, int]] | None = None,
) -> PartitionBatch:
    """Extract, compact, pack and pad every NS(P) of one round.

    The round's triangles are enumerated ONCE on the working graph and
    routed to parts (``assign_triangles``); parts are then grouped into
    pow4 size classes and first-fit-decreasing packed into lanes of the
    class capacity (each lane a disjoint union of part slices, see
    :class:`PartBucket`), with the lane count padded to a pow2.  One round
    therefore compiles at most one shape per occupied size class, and the
    shape grid across rounds is the fixed pow4/pow2 lattice of
    (lanes, cap_e, cap_t) — the compile-cache keying that keeps the engine
    at O(log) distinct compiles per run instead of the seed's one compile
    per part, while an outlier hub part only widens its own class's lanes.

    ``lane_capacity`` forces every part into one class of that capacity
    (parts larger than it still get a lane; used to pin shapes externally).
    ``with_incidence=False`` skips the per-lane incidence CSR and supports
    (the triangle-credit support counter only needs the triangle lists).

    ``lane_multiple > 1`` (the mesh device count for the sharded dispatch,
    DESIGN.md §10, so every shard receives the same number of lanes)
    switches to *waste-aware* packing: every part goes into ONE capacity
    class sized to the observed per-lane cap
    ``pow2_ceil(max(max_part, total / lane_multiple))`` and the lane count
    is padded only to the device multiple — never pow2 first.  The old
    order (pow4 size classes, each pow2-lane-padded, each *then* rounded
    up to the device multiple) charged every occupied class its own
    ``lane_multiple`` dead-lane tax, which is what pushed
    ``padding_waste`` from ~0.39 to ~0.67 on the table4shard rows.  The
    single class keeps FFD dense (leftover per lane is bounded by the
    largest co-packed part) and aims the lane count at one lane per
    device, so the dead-lane tax is paid at most once per round.  The
    remaining padding is counted in ``padded_slots`` and hence in
    ``OocStats.padding_waste``.

    ``shape_ladder`` (sharded packing only) is the round pipeline's SHAPE
    LADDER (DESIGN.md §13): a list of ``(cap_e, cap_t, lanes)`` shapes the
    run has already compiled the shard_map peel for.  If the round's
    natural single-class shape fits inside a ladder entry, the TIGHTEST
    fitting entry (smallest ``cap_e * cap_t`` footprint) is used verbatim
    — the dispatch becomes a compile-cache hit instead of a pod-wide
    re-trace + recompile stall, at the cost of some dead padding whose
    per-device share is ``1/n_dev``.  A round that fits no entry packs at
    its natural shape (the caller then adds that shape to the ladder), so
    unlike a monotone ratchet, small late rounds never pay the widest
    round's flops.  The single-device packing deliberately has no ladder:
    with nobody to absorb the padding, the dense per-round shapes minimize
    flops and the pow2/pow4 lattice already bounds its compile count.
    The extra padding is charged to ``padded_slots`` like any other
    padding.

    ``tris`` is a precomputed (T, 3) triangle list of the FULL working
    graph ``g`` (edge-id triples in ``g``'s numbering): the incremental
    round pipeline (``bottom_up._partition_rounds``) filters the previous
    round's list against the surviving edges instead of re-enumerating,
    and passes it here — the enumeration below is skipped and the list is
    scope-filtered to the round's NS union so ``tri_total`` keeps meaning
    "triangles the round read".  ``tris`` may also be an *iterable of
    (rows, 3) chunks* (the spilled-list streaming path, DESIGN.md §16):
    chunks are consumed one at a time and reduced to their part-assigned
    rows before the next is read, so the host never holds the whole list;
    the observed peak is reported as ``PartitionBatch.tri_peak_rows``.
    """
    from repro.core.support import (_pow2_ceil, _pow4_ceil, list_triangles,
                                    support_from_triangle_list,
                                    triangle_incidence_np)

    if lane_capacity is not None and lane_capacity <= 0:
        raise ValueError(
            f"lane_capacity must be positive or None, got {lane_capacity!r}; "
            f"0 is not 'unset' — pass None for natural pow4 size classes")

    # ONE skew-aware triangle enumeration per round, scoped to the round's
    # NS union — the subgraph of edges with >= 1 endpoint in some part,
    # i.e. exactly what the paper's round reads.  A triangle needs >= 2
    # vertices in one part to be assignable, so all its edges are then in
    # that part's NS and the scoped enumeration finds it; with a full
    # vertex cover (sequential/random partitioners) the scope is the whole
    # working graph and nothing changes.  A zoned cover (locality
    # partitioner, DESIGN.md §11) skips the deferred region entirely —
    # less scan work, and ``tri_total`` counts only triangles the round
    # actually read.  Each found triangle is routed to the unique part
    # holding >= 2 of its vertices (assign_triangles) instead of
    # re-enumerating wedges per part.
    part_of = np.full(g.n, -1, dtype=np.int64)
    for i, P in enumerate(parts):
        part_of[np.asarray(P, dtype=np.int64)] = i
    e64 = g.edges.astype(np.int64)
    in_ns = (part_of[e64[:, 0]] >= 0) | (part_of[e64[:, 1]] >= 0)
    full_scope = bool(in_ns.all())
    # detach: the scoped scan graph is transient (one batch build) and must
    # never allocate store namespaces or spill plans of its own
    g_scan = g if full_scope else g.remove_edges(~in_ns, detach=True)
    tri_peak_rows = 0
    if tris is not None and not isinstance(tris, np.ndarray):
        # chunk-streamed incremental path (DESIGN.md §16): ``tris`` is an
        # iterable of (rows, 3) chunks of the spilled list.  Each chunk is
        # scope-filtered, routed, and reduced to its part-assigned rows
        # before the next chunk is read, so peak residency is the retained
        # bucket payload plus one store chunk — never the full 3·T list.
        # Unassigned (3-part) rows are dropped here instead of being sorted
        # in front of part 0 like the array path does; the bounds slices
        # below never read them either way.
        kept_t: List[np.ndarray] = []
        kept_p: List[np.ndarray] = []
        tri_total = tri_assigned = kept_rows = 0
        for chunk in tris:
            tc = np.asarray(chunk, np.int64).reshape(-1, 3)
            tri_peak_rows = max(tri_peak_rows, kept_rows + int(len(tc)))
            if not full_scope and len(tc):
                tc = tc[in_ns[tc].all(axis=1)]
            tri_total += int(len(tc))
            tp = assign_triangles(g, tc, part_of)
            keep = tp >= 0
            tc, tp = tc[keep], tp[keep]
            tri_assigned += int(len(tc))
            kept_rows += int(len(tc))
            if len(tc):
                kept_t.append(tc)
                kept_p.append(tp)
        tri_peak_rows = max(tri_peak_rows, kept_rows)
        tris_g = (np.concatenate(kept_t) if kept_t
                  else np.zeros((0, 3), np.int64))
        tri_part = (np.concatenate(kept_p) if kept_p
                    else np.zeros(0, np.int64))
    else:
        if tris is not None:
            # incremental path: the caller's filtered full-graph list
            # replaces the enumeration; scope it the way the scoped scan
            # would
            tris_g = np.asarray(tris, np.int64).reshape(-1, 3)
            if not full_scope and len(tris_g):
                tris_g = tris_g[in_ns[tris_g].all(axis=1)]
        else:
            tris_g = np.asarray(list_triangles(g_scan),
                                np.int64).reshape(-1, 3)
            if not full_scope and len(tris_g):
                ns_eids = np.nonzero(in_ns)[0]
                tris_g = ns_eids[tris_g]       # back to g's edge ids
        tri_part = assign_triangles(g, tris_g, part_of)
        tri_total = int(len(tris_g))
        tri_assigned = int((tri_part >= 0).sum())
        tri_peak_rows = tri_total
    # the cost model's prediction for this round's scope, recorded next to
    # the ground truth so OocStats.tri_est_error can report its accuracy
    tri_est = int(closed_wedge_estimate(g_scan).sum()) // 3
    order = np.argsort(tri_part, kind="stable")
    tris_sorted = tris_g[order]
    bounds = np.searchsorted(tri_part[order],
                             np.arange(len(parts) + 1))

    per_part = []
    for i, (ids, internal) in enumerate(ns_edge_lists(g, parts, part_of)):
        if len(ids) == 0:
            continue
        tri_i = tris_sorted[bounds[i]:bounds[i + 1]]
        # global edge ids -> part-local slots (ids is ascending, and every
        # edge of an assigned triangle is in NS(P) by construction)
        local = compact_index(ids, tri_i)
        per_part.append((ids, internal, len(ids), local))

    if not per_part:
        return PartitionBatch(buckets=[], n_parts=0, real_edges=0,
                              padded_slots=0, max_part_edges=0,
                              tri_total=tri_total, tri_assigned=tri_assigned,
                              tri_est=tri_est, tri_peak_rows=tri_peak_rows)

    # size classes on the pow4 grid: lanes of a class are sized to ITS
    # largest member, so one outlier hub part (the PartitionBudgetWarning
    # case) does not inflate every small part's lane; the fixed grid also
    # lets shapes recur across rounds
    groups: dict[int, List[int]] = {}
    floor_t, floor_l = 1, 1
    if lane_multiple > 1:
        # waste-aware sharded packing: one observed-cap class (docstring)
        sizes = [item[2] for item in per_part]
        tri_lens = [len(item[3]) for item in per_part]
        cap = max(max(sizes), -(-sum(sizes) // lane_multiple))
        floor_cap = 1 if lane_capacity is None else lane_capacity
        key = _pow2_ceil(max(cap, floor_cap))
        # shape ladder: adopt the tightest already-compiled shape the
        # round fits inside (trial FFD pack per candidate — part counts
        # are small); natural shape when none fits
        for fe, ft, fl in sorted(shape_ladder or (),
                                 key=lambda s: s[0] * s[1]):
            if fe < max(max(sizes), floor_cap):
                continue
            trial = _first_fit_decreasing(sizes, fe)
            if len(trial) > fl:
                continue
            if max(sum(tri_lens[i] for i in lane) for lane in trial) > ft:
                continue
            key, floor_t, floor_l = fe, ft, fl
            break
        groups[key] = list(range(len(per_part)))
    else:
        for idx, item in enumerate(per_part):
            if lane_capacity is not None and item[2] <= lane_capacity:
                key = lane_capacity
            else:
                key = _pow4_ceil(item[2])
            groups.setdefault(key, []).append(idx)

    buckets: List[PartBucket] = []
    total_real = total_pad = max_part = 0
    for cap_e in sorted(groups):
        members = groups[cap_e]
        # first-fit decreasing: lanes of cap_e edge slots
        packed = _first_fit_decreasing([per_part[i][2] for i in members],
                                       cap_e)
        lanes = [[members[i] for i in lane] for lane in packed]

        lane_T = [sum(len(per_part[i][3]) for i in lane) for lane in lanes]
        # pow4 triangle capacity: coarser than the edge grid, since
        # triangle counts vary widely between rounds and padded rows are
        # memory-only (the frontier gather never visits them)
        cap_t = _pow4_ceil(max(max(lane_T), 1))
        n_real_lanes = len(lanes)
        if lane_multiple > 1:
            # equal lanes per shard when the bucket spans a mesh axis;
            # real lane count, device multiple only — no pow2 inflation.
            # A chosen ladder entry pins the triangle width and lane count
            # too, so the bucket reproduces the compiled shape exactly.
            cap_t = max(cap_t, floor_t)
            B = round_up_to_multiple(max(n_real_lanes, floor_l),
                                     lane_multiple)
        elif pad_lanes_pow2:
            B = _pow2_ceil(n_real_lanes)
        else:
            B = n_real_lanes
        sup_b = np.zeros((B, cap_e), np.int32)
        tris_b = np.full((B, cap_t, 3), cap_e, np.int32)
        alive_b = np.zeros((B, cap_e), bool)
        indptr_b = np.zeros((B, cap_e + 1), np.int32)
        tids_b = np.zeros((B, 3 * cap_t), np.int32)
        eid_b = np.full((B, cap_e), -1, np.int64)
        int_b = np.zeros((B, cap_e), bool)
        part_b = np.full((B, cap_e), -1, np.int32)
        real_edges = 0
        for lane_idx, lane in enumerate(lanes):
            off_e = off_t = 0
            for part_idx in lane:
                ids, internal, m_loc, tris = per_part[part_idx]
                sl = slice(off_e, off_e + m_loc)
                alive_b[lane_idx, sl] = True
                eid_b[lane_idx, sl] = ids
                int_b[lane_idx, sl] = internal
                part_b[lane_idx, sl] = part_idx
                if len(tris):
                    tris_b[lane_idx, off_t : off_t + len(tris)] = tris + off_e
                if with_incidence:
                    sup_b[lane_idx, sl] = support_from_triangle_list(tris, m_loc)
                off_e += m_loc
                off_t += len(tris)
                max_part = max(max_part, m_loc)
            real_edges += off_e
            if with_incidence:
                indptr, tids = triangle_incidence_np(tris_b[lane_idx], cap_e)
                indptr_b[lane_idx] = indptr
                tids_b[lane_idx, : len(tids)] = tids

        buckets.append(PartBucket(
            cap_e=cap_e, cap_t=cap_t, n_parts=len(members),
            n_real_lanes=n_real_lanes, sup=sup_b, tris=tris_b,
            alive=alive_b, indptr=indptr_b, tids=tids_b, edge_ids=eid_b,
            internal=int_b, part_of=part_b, real_edges=real_edges,
        ))
        total_real += real_edges
        total_pad += buckets[-1].padded_slots

    return PartitionBatch(
        buckets=buckets, n_parts=len(per_part), real_edges=total_real,
        padded_slots=total_pad, max_part_edges=max_part,
        tri_total=tri_total, tri_assigned=tri_assigned, tri_est=tri_est,
        tri_peak_rows=tri_peak_rows,
    )
