"""Bottom-up I/O-efficient truss decomposition (paper Section 5, Alg 3-5).

Two stages, adapted to the TPU memory hierarchy (DESIGN.md §2):

Stage 1 — ``lower_bounding`` (Algorithm 3): partition the current graph's
vertices into parts whose neighborhood subgraphs fit the working-set budget;
decompose each NS(P) *locally* (bulk peel, device-side); Lemma 1 makes the
local trussness a global lower bound φ(e).  Internal edges are removed after
each round and emitted to ``G_new``; the loop repeats on the shrinking
remainder until no edges are left.

Stage 2 — ``bottom_up_decompose`` (Algorithm 4 + Procedure 5): for k = 2, 3,
…: extract the candidate subgraph H = NS(U_k), U_k = endpoints of edges with
φ(e) <= k; peel H at threshold (k-2) — the removed internal edges are exactly
Φ_k (Theorem 2); delete them from G_new and continue.

Deviation from the paper (documented in DESIGN.md §7): Algorithm 3 Step 8
flags internal zero-support edges as Φ_2 in *every* round, but from round 2
onward local supports are measured against the already-shrunk working graph,
which can under-count (a crossing edge whose triangle partner was emitted to
G_new in an earlier round shows support 0 yet can have trussness 3).  We flag
Φ_2 exactly in round 1 only (supports there are exact w.r.t. G), and start
stage 2 at k = 2 so any remaining 2-class edges are recovered exactly —
stage-2 candidate supports are always exact w.r.t. G_new.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List

import jax.numpy as jnp
import numpy as np

from repro.core import graph as glib
from repro.core import partition as plib
from repro.core.peel import peel_classes, peel_threshold
from repro.core.support import list_triangles_np, support_from_triangle_list


def _resolve_partitioner(partitioner):
    """Normalize to fn(graph, budget, round_idx) -> parts.

    The randomized partitioner is re-seeded every round (Chu–Cheng's
    guarantee that crossing edges eventually co-locate holds w.h.p. only
    under re-randomization); deterministic ones ignore the round index.
    """
    if callable(partitioner):
        return lambda g, b, r: partitioner(g, b)
    fn = plib.PARTITIONERS[partitioner]
    if partitioner == "random":
        return lambda g, b, r: fn(g, b, seed=r)
    return lambda g, b, r: fn(g, b)


@dataclasses.dataclass
class LowerBoundResult:
    edges: np.ndarray        # canonical edge list of the original graph
    phi: np.ndarray          # trussness; filled with 2 for the exact Phi_2
    lb: np.ndarray           # lower bound phi(e) for G_new edges (>=2)
    in_gnew: np.ndarray      # bool mask: edge still undecided (in G_new)
    rounds: int              # partition rounds (the paper's O(m/M) iterations)
    scans: int               # NS extractions (I/O-scan analogue)
    max_part_edges: int      # largest NS working set seen (budget check)


def _local_truss(sub_edges: np.ndarray, n: int) -> np.ndarray:
    """Trussness of every edge of the subgraph (frontier bulk peel).

    The initial supports come for free from the triangle list (which the peel
    needs anyway), so each NS(P) costs one wedge enumeration, not two.
    """
    g = glib.build_graph(n, sub_edges)
    if g.m == 0:
        return np.zeros(0, np.int64)
    tris = list_triangles_np(g)
    sup = support_from_triangle_list(tris, g.m).astype(np.int32)
    if len(tris) == 0:
        tris = np.full((1, 3), g.m, np.int32)
    phi, _ = peel_classes(jnp.asarray(sup), jnp.asarray(tris), jnp.ones(g.m, bool))
    return np.asarray(phi).astype(np.int64)


def lower_bounding(
    n: int,
    edges: np.ndarray,
    budget: int,
    partitioner: str | Callable = "sequential",
) -> LowerBoundResult:
    """Algorithm 3: per-edge lower bounds + exact round-1 Phi_2."""
    part_fn = _resolve_partitioner(partitioner)
    edges = glib.canonical_edges(edges, n)
    m = len(edges)
    phi = np.zeros(m, dtype=np.int64)
    lb = np.full(m, 2, dtype=np.int64)
    alive = np.ones(m, dtype=bool)          # still in the working graph
    in_gnew = np.zeros(m, dtype=bool)       # emitted to G_new
    rounds = scans = 0
    max_part = 0
    cur_budget = budget

    while alive.any():
        rounds += 1
        cur_ids = np.nonzero(alive)[0]
        g = glib.build_graph(n, edges[cur_ids])
        parts = part_fn(g, cur_budget, rounds)
        if not parts:
            break
        round_removed = np.zeros(len(cur_ids), dtype=bool)
        for P in parts:
            scans += 1
            sub_ids, sub_edges, internal = glib.neighborhood_subgraph(g, P)
            if len(sub_ids) == 0:
                continue
            max_part = max(max_part, len(sub_ids))
            phi_local = _local_truss(sub_edges, n)
            int_ids = sub_ids[internal]               # ids in current graph
            glob_ids = cur_ids[int_ids]               # ids in original graph
            lb[glob_ids] = np.maximum(lb[glob_ids], phi_local[internal])
            if rounds == 1:
                # Exact Phi_2: internal support == global support in G here.
                is_phi2 = phi_local[internal] == 2
                phi[glob_ids[is_phi2]] = 2
                in_gnew[glob_ids[~is_phi2]] = True
            else:
                in_gnew[glob_ids] = True
            round_removed[int_ids] = True
        if not round_removed.any():
            # Stalled: no crossing edge became internal (can happen with a
            # deterministic partitioner).  Paper's remedy is the randomized
            # re-partition; the hard fallback is to grow the working set.
            cur_budget *= 2
            continue
        alive[cur_ids[round_removed]] = False

    return LowerBoundResult(
        edges=edges, phi=phi, lb=lb, in_gnew=in_gnew,
        rounds=rounds, scans=scans, max_part_edges=max_part,
    )


@dataclasses.dataclass
class BottomUpResult:
    edges: np.ndarray
    phi: np.ndarray
    kmax: int
    rounds: int
    scans: int
    candidate_sizes: List[int]   # |H| per k (I/O + working-set accounting)


def bottom_up_decompose(
    n: int,
    edges: np.ndarray,
    budget: int,
    partitioner: str | Callable = "sequential",
) -> BottomUpResult:
    """Algorithm 4: full decomposition under a working-set budget."""
    lbres = lower_bounding(n, edges, budget, partitioner)
    edges = lbres.edges
    phi = lbres.phi.copy()
    lb = lbres.lb
    remaining = lbres.in_gnew.copy()
    cand_sizes: List[int] = []
    scans = lbres.scans

    k = 2
    while remaining.any():
        scans += 1
        # U_k: endpoints of remaining edges whose lower bound admits class k.
        elig = remaining & (lb <= k)
        if not elig.any():
            k += 1
            continue
        u_k = np.zeros(n, dtype=bool)
        eg = edges[elig]
        u_k[eg[:, 0]] = True
        u_k[eg[:, 1]] = True
        # H = NS(U_k) within G_new: every remaining edge with >=1 endpoint in U_k.
        u_in = u_k[edges[:, 0]]
        v_in = u_k[edges[:, 1]]
        in_h = remaining & (u_in | v_in)
        internal = remaining & u_in & v_in
        h_ids = np.nonzero(in_h)[0]
        cand_sizes.append(len(h_ids))
        sub = glib.build_graph(n, edges[h_ids])
        tris = list_triangles_np(sub)
        sup = support_from_triangle_list(tris, sub.m).astype(np.int32)
        if len(tris) == 0:
            tris = np.full((1, 3), sub.m, np.int32)
        # Map internal mask to subgraph ids (canonical order preserved).
        removable = jnp.asarray(internal[h_ids])
        alive, _, removed = peel_threshold(
            jnp.asarray(sup), jnp.asarray(tris),
            jnp.ones(sub.m, bool), removable, jnp.int32(k - 2),
        )
        removed = np.asarray(removed)
        rm_glob = h_ids[removed]
        phi[rm_glob] = k
        remaining[rm_glob] = False
        k += 1

    kmax = int(phi.max()) if len(phi) else 2
    return BottomUpResult(
        edges=edges, phi=phi, kmax=kmax, rounds=lbres.rounds,
        scans=scans, candidate_sizes=cand_sizes,
    )


def partitioned_support(
    n: int,
    edges: np.ndarray,
    budget: int,
    partitioner: str | Callable = "sequential",
) -> np.ndarray:
    """Exact sup(e) w.r.t. the FULL graph, computed under a working-set
    budget (triangle-credit variant of Algorithm 3 used by the top-down
    algorithm; see DESIGN.md §7).

    Invariant: every triangle of G is credited exactly once — in the first
    round in which one of its edges becomes internal (all internal edges of a
    triangle lie in the same part, and a triangle loses an edge from the
    working graph the moment it is first credited).
    """
    part_fn = _resolve_partitioner(partitioner)
    edges = glib.canonical_edges(edges, n)
    m = len(edges)
    sup = np.zeros(m, dtype=np.int64)
    alive = np.ones(m, dtype=bool)
    rounds = 0
    cur_budget = budget

    while alive.any():
        rounds += 1
        cur_ids = np.nonzero(alive)[0]
        g = glib.build_graph(n, edges[cur_ids])
        parts = part_fn(g, cur_budget, rounds)
        if not parts:
            break
        round_removed = np.zeros(len(cur_ids), dtype=bool)
        for P in parts:
            sub_ids, sub_edges, internal = glib.neighborhood_subgraph(g, P)
            if len(sub_ids) == 0:
                continue
            sub = glib.build_graph(n, sub_edges)
            tris = list_triangles_np(sub)  # every NS triangle has an internal edge
            if len(tris):
                # subgraph edge id -> current-graph id -> original id
                to_glob = cur_ids[sub_ids]
                np.add.at(sup, to_glob[tris.reshape(-1)], 1)
            round_removed[sub_ids[internal]] = True
        if not round_removed.any():
            cur_budget *= 2   # stall fallback (see lower_bounding)
            continue
        alive[cur_ids[round_removed]] = False

    return sup
