"""Bottom-up I/O-efficient truss decomposition (paper Section 5, Alg 3-5).

Two stages, adapted to the TPU memory hierarchy (DESIGN.md §2, §8):

Stage 1 — ``lower_bounding`` (Algorithm 3): partition the current graph's
vertices into parts whose neighborhood subgraphs fit the working-set budget;
decompose each NS(P) *locally*; Lemma 1 makes the local trussness a global
lower bound φ(e).  Internal edges are removed after each round and emitted
to ``G_new``; the loop repeats on the shrinking remainder until no edges are
left.

Stage 2 — ``bottom_up_decompose`` (Algorithm 4 + Procedure 5): for ascending
k: extract the candidate subgraph H = NS(U_k), U_k = endpoints of edges with
φ(e) <= k; peel H at threshold (k-2) — the removed internal edges are exactly
Φ_k (Theorem 2); delete them from G_new and continue.  Empty classes are
skipped by jumping k straight to ``min lb`` over the remaining edges.

Engines (DESIGN.md §8):

* ``engine="batched"`` (default) — one :class:`partition.PartitionBatch` per
  round: every NS(P) compacted to local ids, parts grouped into pow4 size
  classes, lane-packed and padded to static shapes, every bucket decomposed
  in ONE device call (``peel.peel_classes_batched``, one compile per bucket
  shape); the
  working graph shrinks via ``Graph.remove_edges`` incremental maintenance
  instead of a per-round rebuild.  Rounds are **double-buffered**
  (DESIGN.md §9): a round's internal-edge removal is known at batch-build
  time, so the ``_partition_rounds`` producer advances the working graph
  and builds round r + 1 on the host while the device still peels round r
  (non-blocking dispatch, results consumed one round late).  Stage-2
  candidates are compacted and peeled on pow4-padded shapes
  (``peel.local_threshold_peel``), so consecutive k values share one
  compiled kernel — and are **pipelined** the same way the stage-1 rounds
  are (DESIGN.md §11): level k+1's candidate is pre-built on the host from
  the pre-result masks (a superset U′ ⊇ U_{k+1}, provably sound) while the
  device peels level k; the edges level k removes are killed at use time
  via the peel's ``alive0`` mask (``OocStats.stage2_overlapped``).
* ``engine="perpart"`` — the seed path (full ``build_graph`` per round, one
  host triangle enumeration and one freshly-shaped device peel per part);
  kept as the before/after benchmark baseline (BENCH_ooc.json).

Deviation from the paper (documented in DESIGN.md §7): Algorithm 3 Step 8
flags internal zero-support edges as Φ_2 in *every* round, but from round 2
onward local supports are measured against the already-shrunk working graph,
which can under-count (a crossing edge whose triangle partner was emitted to
G_new in an earlier round shows support 0 yet can have trussness 3).  We flag
Φ_2 exactly in round 1 only (supports there are exact w.r.t. G), and start
stage 2 at k = 2 so any remaining 2-class edges are recovered exactly —
stage-2 candidate supports are always exact w.r.t. G_new.
"""

from __future__ import annotations

import dataclasses
import hashlib
import inspect
import re
import time
import warnings
from typing import Callable, Dict, Iterator, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.core import faults
from repro.core import graph as glib
from repro.core import partition as plib
from repro.core.store import GraphStore
from repro.core.peel import (local_threshold_peel, peel_classes,
                             peel_classes_batched, peel_threshold)
from repro.core import support as sup_lib
from repro.core.support import (list_triangles, list_triangles_np,
                                support_from_triangle_list)

# The degradation ladder's floor for the per-round working-set budget:
# halving below this cannot meaningfully shrink a dispatch (a single lane
# is already ~this size), so at the floor the failure propagates.
_MIN_ROUND_BUDGET = 64


class _RestartRounds(Exception):
    """Internal control flow of the stage-1 degradation ladder: unwind the
    round generator and restart it from the journaled host state with a
    smaller working-set budget (smaller parts => smaller dispatches).  All
    completed rounds' folds are idempotent scatters, so the restart loses
    at most the failed round's device work."""

    def __init__(self, budget: int):
        super().__init__(f"restart partition rounds at budget={budget}")
        self.budget = budget


@dataclasses.dataclass
class _Engine:
    """Mutable dispatch configuration shared by a run's device launches.

    The degradation ladder rewrites it in place (``mesh = None`` drops the
    run to single-device), so every later dispatch — including stage 2 —
    inherits the degraded routing without re-threading arguments."""

    mesh: object = None
    mesh_axis: object = "data"   # one axis name or a (lane, tri) tuple (§13)
    kernel: str = "auto"         # per-lane peel engine (pallas | xla | auto)

    @property
    def lane_axis(self) -> str:
        ax = self.mesh_axis
        return ax if isinstance(ax, str) else ax[0]

    @property
    def n_dev(self) -> int:
        """Lane-axis size — the multiple the bucket packers pad lanes to."""
        if self.mesh is None:
            return 1
        return int(self.mesh.shape[self.lane_axis])

    @property
    def devices(self) -> int:
        """Total devices spanned: the product over every named mesh axis."""
        if self.mesh is None:
            return 1
        axes = ((self.mesh_axis,) if isinstance(self.mesh_axis, str)
                else tuple(self.mesh_axis))
        d = 1
        for a in axes:
            d *= int(self.mesh.shape[a])
        return d


def _mesh_devices(mesh, mesh_axis) -> int:
    """Total devices a (mesh, mesh_axis) pair spans: the product over the
    named axes.  1 without a mesh; for a single axis name this equals the
    axis size, keeping single-axis checkpoint run keys unchanged."""
    if mesh is None:
        return 1
    axes = (mesh_axis,) if isinstance(mesh_axis, str) else tuple(mesh_axis)
    d = 1
    for a in axes:
        d *= int(mesh.shape[a])
    return d


def _accepts_round(fn) -> bool:
    """Whether a user partitioner asks for (graph, budget, round_idx).

    Only a third *required* positional parameter (or ``*args``) opts in:
    a defaulted third parameter (``def p(g, b, strict=True)``) keeps the
    legacy 2-arg call so pre-existing config kwargs are never hijacked by
    the round index.
    """
    try:
        params = list(inspect.signature(fn).parameters.values())
    except (TypeError, ValueError):      # no introspectable signature
        return False
    required = sum(
        p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
        and p.default is p.empty
        for p in params)
    return (required >= 3
            or any(p.kind == p.VAR_POSITIONAL for p in params))


class _AdaptiveLocality:
    """Stateful wrapper feeding observed triangle locality back into the
    zoned partitioner (DESIGN.md §11): ``_partition_rounds`` calls
    :meth:`observe` with each built batch, and the next round's zone cap
    scales with the capture fraction the previous round actually achieved
    (``partition._zone_mult``) instead of the fixed 4x constant."""

    def __init__(self, fn):
        self._fn = fn
        self.prev_locality: float | None = None

    def __call__(self, g, budget, round_idx):
        return self._fn(g, budget, prev_locality=self.prev_locality)

    def observe(self, batch: "plib.PartitionBatch") -> None:
        if batch.tri_total:
            self.prev_locality = batch.tri_locality


def _zone_state(part_fn):
    """Journal payload of the partitioner's adaptive zone state.

    The locality partitioner (:class:`_AdaptiveLocality`) carries one
    float of cross-round feedback — the previous round's observed triangle
    locality, which sizes the next round's zone cap.  A journal snapshot
    that omits it makes a resumed run re-plan its rounds from the cold
    default instead of reproducing the original sequence (φ stays exact
    either way, but perf and the round/locality counters diverge —
    DESIGN.md §16).  Stateless partitioners snapshot as None.
    """
    state = getattr(part_fn, "prev_locality", None)
    return None if state is None else float(state)


def _restore_zone_state(part_fn, state) -> None:
    """Reinstall a journaled :func:`_zone_state` into the partitioner."""
    if state is not None and hasattr(part_fn, "prev_locality"):
        part_fn.prev_locality = float(state)


def _resolve_partitioner(partitioner, seed: int = 0):
    """Normalize to fn(graph, budget, round_idx) -> parts.

    The randomized partitioner is re-seeded every round (Chu–Cheng's
    guarantee that crossing edges eventually co-locate holds w.h.p. only
    under re-randomization); ``seed`` offsets the per-round reseed so the
    drivers' ``partitioner_seed=`` reaches ``random_partition`` (with the
    default 0 the schedule is the historical ``seed=round_idx`` one).
    Deterministic partitioners ignore both.  User callables with a third
    required positional parameter (or ``*args``) receive the round index
    too, so custom partitioners can vary per round the way the built-in
    "random" reseed does; 2-arg callables — including ones with defaulted
    config parameters — keep the legacy (graph, budget) call.

    The built-in "locality" partitioner resolves to a stateful
    :class:`_AdaptiveLocality` whose ``observe`` hook the round generator
    drives; resolving fresh per run keeps the feedback run-local.
    """
    if callable(partitioner):
        if _accepts_round(partitioner):
            return lambda g, b, r: partitioner(g, b, r)
        return lambda g, b, r: partitioner(g, b)
    fn = plib.PARTITIONERS[partitioner]
    if partitioner == "random":
        return lambda g, b, r: fn(g, b, seed=seed + r)
    if partitioner == "locality":
        return _AdaptiveLocality(fn)
    return lambda g, b, r: fn(g, b)


@dataclasses.dataclass
class OocStats:
    """Work counters of one out-of-core run (mirrors ``PeelStats``).

    ``compiles`` counts distinct padded shapes this run traced — the cost
    the bucket padding exists to bound (the seed per-part path compiled once
    per part shape).  The jit cache is process-global, so the counter is an
    upper bound on actual XLA work.  ``padding_waste`` is the fraction of
    materialized lane slots that held no real edge.
    """

    rounds: int = 0           # partition rounds (the paper's O(m/M) scans)
    scans: int = 0            # NS/candidate extractions (I/O-scan analogue)
    batches: int = 0          # device launches (one per bucket per round)
    compiles: int = 0         # distinct padded shapes traced this run
    parts: int = 0            # NS parts processed
    max_part_edges: int = 0   # largest NS working set seen (budget check)
    real_edges: int = 0       # Σ real edge slots across all batches
    padded_slots: int = 0     # Σ materialized lane slots across all batches
    tri_total: int = 0        # triangles enumerated across partition rounds
    tri_assigned: int = 0     # of those, captured inside some part
    ns_sweeps: int = 0        # whole-graph NS edge-list sweeps (1 per batch)
    overlapped: int = 0       # rounds whose device peel overlapped the
    #                           host build of the NEXT round (pipeline depth)
    stage2_overlapped: int = 0  # stage-2 levels whose candidate extraction
    #                           + compaction was pre-built on the host while
    #                           the previous level's peel still ran on the
    #                           device (DESIGN.md §11)
    tri_est: int = 0          # wedge-based triangle estimates summed over
    #                           partition rounds (the cost model's
    #                           prediction; compare tri_total)
    tri_rescans_avoided: int = 0  # rounds whose triangle list was filtered
    #                           from the previous round's instead of
    #                           re-enumerated (the O(m^1.5) scan replaced
    #                           by an O(T) filter; at most rounds - 1)
    devices: int = 1          # mesh devices the sharded dispatch spans
    sharded_rounds: int = 0   # device dispatches (stage-1 partition rounds
    #                           + per-k candidate peels) routed through
    #                           shard_map across the mesh (DESIGN.md §10)
    retries: int = 0          # failed dispatches re-driven by the retry
    #                           ladder (lane splits + degraded re-runs)
    degraded: int = 0         # engine degradations taken: mesh drops +
    #                           working-set budget halvings (DESIGN.md §12)
    checkpoints: int = 0      # journal snapshots written this run
    resumed_round: int = -1   # round/level index of the snapshot this run
    #                           resumed from (-1: started fresh)
    chunk_reads: int = 0      # graph-store chunks read back (DESIGN.md §15)
    chunk_writes: int = 0     # graph-store chunks written (spilled)
    bytes_spilled: int = 0    # bytes written to the chunked store; chunks
    #                           aliased by the chunk-wise remove_edges cost 0
    prefetch_hits: int = 0    # chunk requests served by the background
    #                           prefetch thread (scheduled before requested)
    prefetch_misses: int = 0  # chunk requests that fell back to a
    #                           synchronous disk read at request time
    tri_spill_rows: int = 0   # largest triangle list (rows) spilled to the
    #                           store across partition rounds
    tri_reload_peak_rows: int = 0  # peak triangle rows resident at once
    #                           while CONSUMING a spilled list (chunk-
    #                           streamed: must stay far below
    #                           tri_spill_rows, DESIGN.md §16)
    edits_applied: int = 0    # maintenance edits applied (maintain.py)
    maintain_levels: int = 0  # per-level region peels run by maintenance
    affected_edges: int = 0   # Σ candidate edges over maintenance levels

    @property
    def prefetch_hit_rate(self) -> float:
        """Fraction of chunk requests the prefetcher hid the latency of —
        the overlap quality metric the ooc-disk smoke gates on (≥ 0.5)."""
        total = self.prefetch_hits + self.prefetch_misses
        return self.prefetch_hits / total if total else 1.0

    @property
    def tri_routes(self) -> int:
        """Whole-graph triangle enumerations routed to parts — an alias:
        ``build_partition_batch`` does exactly one triangle routing per NS
        sweep, so the two whole-graph scan counters move in lockstep."""
        return self.ns_sweeps

    @property
    def padding_waste(self) -> float:
        if not self.padded_slots:
            return 0.0
        return 1.0 - self.real_edges / self.padded_slots

    @property
    def tri_locality(self) -> float:
        """Fraction of enumerated triangles captured inside a part — the
        objective the locality-aware partitioner maximizes (DESIGN.md §9)."""
        return self.tri_assigned / self.tri_total if self.tri_total else 1.0

    @property
    def tri_est_error(self) -> float:
        """Relative error of the partitioner's wedge-based triangle-volume
        estimate vs the actual per-round enumerations (DESIGN.md §11).
        The cost model only steers locality — a wildly wrong estimate can
        cost rounds, never correctness — but the error is surfaced so the
        estimator's drift on new graph shapes is visible in benchmarks.
        The denominator floors at 1 so triangle-free runs still expose an
        over-predicting estimator instead of reporting it as exact."""
        return abs(self.tri_est - self.tri_total) / max(self.tri_total, 1)

    def absorb_batch(self, batch: "plib.PartitionBatch") -> None:
        self.parts += batch.n_parts
        self.scans += batch.n_parts
        self.batches += len(batch.buckets)
        self.real_edges += batch.real_edges
        self.padded_slots += batch.padded_slots
        self.max_part_edges = max(self.max_part_edges, batch.max_part_edges)
        self.tri_total += batch.tri_total
        self.tri_assigned += batch.tri_assigned
        self.tri_est += batch.tri_est
        self.ns_sweeps += 1        # build_partition_batch does exactly one
        #                            whole-graph NS sweep + triangle routing

    def as_dict(self) -> Dict[str, int]:
        """JSON-safe counter snapshot (the journal's metadata form)."""
        return {f.name: int(getattr(self, f.name))
                for f in dataclasses.fields(self)}

    @classmethod
    def from_dict(cls, d: Dict[str, int]) -> "OocStats":
        """Rebuild from :meth:`as_dict` output; unknown keys (snapshots
        written by a newer layout) are ignored."""
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: int(v) for k, v in d.items() if k in known})


@dataclasses.dataclass
class LowerBoundResult:
    edges: np.ndarray        # canonical edge list of the original graph
    phi: np.ndarray          # trussness; filled with 2 for the exact Phi_2
    lb: np.ndarray           # lower bound phi(e) for G_new edges (>=2)
    in_gnew: np.ndarray      # bool mask: edge still undecided (in G_new)
    rounds: int              # partition rounds (the paper's O(m/M) iterations)
    scans: int               # NS extractions (I/O-scan analogue)
    max_part_edges: int      # largest NS working set seen (budget check)
    stats: Optional[OocStats] = None


def _run_key(driver: str, n: int, edges: np.ndarray, budget,
             partitioner, partitioner_seed: int, **extras) -> str:
    """Digest binding a journal to one run configuration (DESIGN.md §12).

    Covers the driver, the canonical edge bytes and every parameter that
    changes the decomposition's trajectory, so ``resume=True`` can never
    silently continue a snapshot from a different graph or configuration.
    Callable partitioners hash by name — the best identity available short
    of bytecode hashing.
    """
    pname = (partitioner if isinstance(partitioner, str)
             else getattr(partitioner, "__name__", "custom"))
    h = hashlib.sha256()
    desc = "|".join(
        [driver, f"n={n}", f"budget={budget}", f"part={pname}",
         f"seed={partitioner_seed}"]
        + [f"{k}={v}" for k, v in sorted(extras.items())])
    h.update(desc.encode())
    h.update(np.ascontiguousarray(edges, dtype=np.int64).tobytes())
    return h.hexdigest()[:16]


def _parse_every(every: Union[int, str]) -> Tuple[str, float]:
    """Normalize a ``checkpoint_every`` knob to ``(mode, value)``.

    Integers are the historical event-count gate (``("events", k)``, floored
    at 1).  Strings are wall-clock budgets — ``"30s"``, ``"500ms"``,
    ``"5m"``, ``"1h"`` — yielding ``("time", seconds)``: long decompositions
    bound *time at risk* rather than rounds, since round durations vary by
    orders of magnitude across the shrink (DESIGN.md §12).
    """
    if isinstance(every, str):
        match = re.fullmatch(r"\s*(\d+(?:\.\d+)?)\s*(ms|s|m|h)\s*", every)
        if match is None:
            raise ValueError(
                f"checkpoint_every={every!r}: expected an event count or a "
                f"duration like '30s', '500ms', '5m', '1h'")
        secs = float(match.group(1)) * {"ms": 1e-3, "s": 1.0, "m": 60.0,
                                        "h": 3600.0}[match.group(2)]
        if secs <= 0:
            raise ValueError(
                f"checkpoint_every={every!r}: duration must be positive")
        return "time", secs
    return "events", float(max(1, int(every)))


class RoundJournal:
    """Round-granular snapshot journal over ``checkpoint.manager`` (§12).

    One journal serves one decomposition run.  Each snapshot is a flat
    ``{name: array}`` tree of host-side round state plus metadata
    ``{stage, index, run_key, stats, **extra}``; writes go through
    :func:`checkpoint.manager.save`'s atomic tmp+rename path, so a crash
    mid-write can never corrupt the newest intact snapshot.  Steps form a
    monotone sequence continued across resumes (the constructor seeds the
    counter from the directory), and ``run_key`` is verified at load so a
    ``checkpoint_dir`` can never silently resume a different run.

    ``every`` gates writes by event count (int) or wall clock (a duration
    string, :func:`_parse_every`); ``clock`` injects the monotonic time
    source so time-gated tests stay deterministic.  ``store`` ties the
    journal to the run's graph store: each snapshot first absorbs the
    store's I/O counters into ``stats`` (so a resumed run's counters
    include pre-crash I/O), and the snapshot payload is reserved against
    the store's :class:`~repro.core.store.IoAccount` while it serializes —
    checkpoint I/O and chunk I/O share one budget (DESIGN.md §15).
    """

    def __init__(self, ckpt_dir: str, run_key: str, *,
                 every: Union[int, str] = 1, keep: int = 3,
                 clock: Callable[[], float] = time.monotonic,
                 store: Optional[GraphStore] = None):
        self.ckpt_dir = ckpt_dir
        self.run_key = run_key
        self.mode, self.every = _parse_every(every)
        self.keep = keep
        self.store = store
        self._clock = clock
        self._last_write = clock()
        self.seq = int(ckpt.latest_step(ckpt_dir) or 0)
        self._events = 0

    def _due(self) -> bool:
        if self.mode == "time":
            return self._clock() - self._last_write >= self.every
        return self._events % int(self.every) == 0

    def record(self, stage: str, index: int, arrays: Dict[str, np.ndarray],
               stats: OocStats, **extra) -> bool:
        """Journal one completed unit of work (a partition round or class
        level); writes when the ``every`` gate (events or wall clock) is
        due.  Returns whether a snapshot was written.  The write is
        synchronous — the device pipeline is already overlapped with host
        work, and an async journal would leave a window where "completed"
        rounds are lost on crash."""
        self._events += 1
        if not self._due():
            return False
        self.seq += 1
        stats.checkpoints += 1
        if self.store is not None:
            self.store.absorb_into(stats)
        meta = {"stage": stage, "index": int(index),
                "run_key": self.run_key, "stats": stats.as_dict(), **extra}
        # narrow i64 -> i32 on the way out (phi/lb/sup are all < 2^31; the
        # restore paths cast back), halving the dominant snapshot cost
        arrays = {k: (np.asarray(v).astype(np.int32)
                      if np.asarray(v).dtype == np.int64 else np.asarray(v))
                  for k, v in arrays.items()}
        account = getattr(self.store, "io_account", None)
        payload = sum(int(a.nbytes) for a in arrays.values())
        if account is not None:
            with account.hold(payload, "checkpoint"):
                ckpt.save(self.ckpt_dir, self.seq, dict(arrays),
                          metadata=meta, keep=self.keep)
        else:
            ckpt.save(self.ckpt_dir, self.seq, dict(arrays), metadata=meta,
                      keep=self.keep)
        if self.mode == "time":
            self._last_write = self._clock()
        return True

    def load_latest(self):
        """``(arrays, meta)`` of the newest intact snapshot, or ``None``
        when the directory holds no usable one (empty, or every snapshot
        corrupt — the run then starts fresh, with a warning in the corrupt
        case).  A ``run_key`` mismatch raises: resuming a different run's
        journal is a caller error, not a recoverable state."""
        try:
            tree, meta = ckpt.restore(self.ckpt_dir)
        except FileNotFoundError:
            return None
        except ckpt.CheckpointCorruptionError as e:
            warnings.warn(
                f"no intact snapshot under {self.ckpt_dir!r} ({e}); "
                f"starting the run from scratch", stacklevel=2)
            return None
        if meta.get("run_key") != self.run_key:
            raise ValueError(
                f"checkpoint_dir {self.ckpt_dir!r} holds a journal for a "
                f"different run (run_key {meta.get('run_key')!r} != "
                f"{self.run_key!r}); refusing to resume")
        return tree, meta


def _local_truss(sub_edges: np.ndarray, n: int) -> np.ndarray:
    """Trussness of every edge of the subgraph (seed per-part local peel).

    One ``build_graph`` over the FULL vertex space, one host triangle
    enumeration and one dynamically-shaped device peel per call — the
    per-part cost model the batched engine replaces; kept as the benchmark
    baseline and as a second implementation for the batch-padding tests.
    """
    g = glib.build_graph(n, sub_edges)
    if g.m == 0:
        return np.zeros(0, np.int64)
    tris = list_triangles_np(g)
    sup = support_from_triangle_list(tris, g.m).astype(np.int32)
    if len(tris) == 0:
        tris = np.full((1, 3), g.m, np.int32)
    phi, _ = peel_classes(jnp.asarray(sup), jnp.asarray(tris), jnp.ones(g.m, bool))
    return np.asarray(phi).astype(np.int64)


def lower_bounding(
    n: int,
    edges: np.ndarray,
    budget: int,
    partitioner: str | Callable = "sequential",
    engine: str = "batched",
    *,
    partitioner_seed: int = 0,
    mesh=None,
    mesh_axis="data",
    kernel: str = "auto",
    journal: Optional[RoundJournal] = None,
    restored=None,
    max_retries: int = 2,
    engine_state: Optional[_Engine] = None,
    store: Optional[GraphStore] = None,
) -> LowerBoundResult:
    """Algorithm 3: per-edge lower bounds + exact round-1 Phi_2.

    With a ``mesh``, every round's bucket peels span the mesh axis
    (DESIGN.md §10); requires the batched engine.  ``mesh_axis`` may be a
    single axis name or a ``(lane, tri)`` tuple for multi-axis meshes
    (DESIGN.md §13); ``kernel`` routes each lane's peel engine
    (``"pallas" | "xla" | "auto"``, forwarded to
    ``peel.peel_classes_batched``).

    ``journal`` / ``restored`` / ``max_retries`` are the resilience hooks
    (DESIGN.md §12): a :class:`RoundJournal` snapshots the host-side fold
    state after each completed round, ``restored`` (an ``(arrays, meta)``
    pair from :meth:`RoundJournal.load_latest` at stage ``"lb"``) resumes
    from it, and ``max_retries`` bounds the lane-split retries a failed
    dispatch gets before the engine degrades.  ``engine_state`` shares one
    mutable :class:`_Engine` with the caller so a mesh drop here carries
    into stage 2.  Both engines compute identical bounds, but only the
    batched engine journals — its per-round state lives in flat host
    arrays; the per-part seed path is the benchmark baseline.

    ``store`` (batched engine only) routes the round loop's working graph
    through a :class:`~repro.core.store.GraphStore` — with a
    ``ChunkedDiskStore`` the graph lives on disk between rounds and the
    store's prefetch thread overlaps the chunk reads with the device peel
    (DESIGN.md §15); φ is bit-identical either way.
    """
    part_fn = _resolve_partitioner(partitioner, seed=partitioner_seed)
    edges = glib.canonical_edges(edges, n)
    if engine == "perpart":
        if mesh is not None:
            raise ValueError("mesh= requires the batched engine")
        if journal is not None or restored is not None:
            raise ValueError(
                "checkpointing requires the batched engine "
                "(engine='perpart' is the uninstrumented seed baseline)")
        if store is not None:
            raise ValueError(
                "store= requires the batched engine "
                "(engine='perpart' is the uninstrumented seed baseline)")
        return _lower_bounding_perpart(n, edges, budget, part_fn)
    if engine != "batched":
        raise ValueError(f"unknown engine {engine!r}")
    return _lower_bounding_batched(n, edges, budget, part_fn,
                                   mesh=mesh, mesh_axis=mesh_axis,
                                   kernel=kernel,
                                   journal=journal, restored=restored,
                                   max_retries=max_retries,
                                   engine_state=engine_state, store=store)


def _partition_rounds(
    n: int, edges: np.ndarray, budget: int, part_fn, stats: OocStats,
    *, with_incidence: bool = True, lane_multiple: int = 1,
    start_ids: Optional[np.ndarray] = None,
    store: Optional[GraphStore] = None,
) -> Iterator[Tuple[int, "plib.PartitionBatch", np.ndarray, int]]:
    """Producer side of the double-buffered round pipeline (DESIGN.md §9).

    Yields ``(round_idx, batch, cur_ids, cur_budget, zone_state)`` per
    partition round, with ``cur_ids`` mapping the batch's current-graph
    edge ids to original edge ids, ``cur_budget`` the working-set budget
    the round was built at (the value a resumed run must restart from,
    since the stall fallback below mutates it), and ``zone_state`` the
    locality partitioner's adaptive state as of this round's feedback
    (``None`` for stateless partitioners).  Which edges a round removes is known at batch-build
    time (a round's internal edges leave the working graph regardless of
    their peel results), so the generator applies ``Graph.remove_edges``
    and repartitions immediately — the consumer can keep the device busy
    with round r while this code builds round r + 1 on the host.

    ``start_ids`` restarts the generator from a working graph that is a
    subset of ``edges`` (the resume and budget-degrade paths, DESIGN.md
    §12); the default is the full edge list.  Round numbering continues
    from ``stats.rounds``, which a resumed run restores first.

    A round in which no edge became internal (a deterministic-partitioner
    stall; the paper's remedy is the randomized re-partition) doubles the
    working-set budget and yields nothing: with no internal edges a peel
    could not contribute any bound.

    Triangle lists are **incremental** across rounds: the full working
    graph is enumerated once (round 1), and every later round filters the
    previous list against the surviving edges — a triangle of the shrunken
    graph is exactly a triangle of the previous graph with all three edges
    alive — and remaps edge ids to the compacted numbering
    ``Graph.remove_edges`` produces.  The O(m^1.5) wedge enumeration per
    round becomes an O(T) mask (``OocStats.tri_rescans_avoided``); zoned
    covers pay one full scan up front instead of one zone scan per round,
    and ``build_partition_batch`` re-scopes the passed list so
    ``tri_total`` / ``tri_locality`` semantics are unchanged.

    With a ``store``, the working graph and the incremental triangle list
    are **spilled between rounds** (DESIGN.md §15): after each
    ``remove_edges`` the successor graph spills chunk-wise (untouched
    chunks alias the predecessor's files), the predecessor's chunks are
    released, and the next round's arrays are prefetched before the yield
    — so the background reads overlap the consumer's device peel exactly
    like the batch pipeline overlaps the host build.
    """
    if start_ids is None:
        g = glib.build_graph(n, edges, store=store)
        cur_ids = np.arange(g.m, dtype=np.int64)
    else:
        cur_ids = np.asarray(start_ids, dtype=np.int64)
        g = glib.build_graph(n, edges[cur_ids], store=store)
    if store is not None:
        g.spill()
        g.prefetch()
    cur_budget = budget
    tris_cur = None      # full triangle list of g, g-local edge ids
    tris_key = None      # store key the spilled triangle list lives under
    # shape ladder (sharded packing only, DESIGN.md §13): the shapes this
    # run has already compiled the shard_map peel for; a round that fits
    # an entry reuses it verbatim (compile-cache hit), one that doesn't
    # packs naturally and contributes its shape — on a mesh every
    # recompile is a pod-wide stall, and the dead padding a reused entry
    # adds costs each shard only 1/n_dev of its slots
    ladder: list = []
    while g.m:
        stats.rounds += 1
        # the host-side "between rounds" fault site: the natural place for
        # the crash/kill injections the resume tests drive (DESIGN.md §12)
        faults.check(faults.PARTITIONER, stage=1, round=stats.rounds,
                     budget=cur_budget)
        parts = part_fn(g, cur_budget, stats.rounds)
        if not parts:
            break
        spilled_round = tris_cur is None and tris_key is not None
        if spilled_round:
            # chunk-stream the spilled list through the batch builder
            # (DESIGN.md §16): the builder retains only the rows assigned
            # into some part, so the host's peak triangle working set is
            # the round's bucket payload plus one store chunk — never the
            # whole 3·T list the old whole-array reload materialized
            stats.tri_rescans_avoided += 1
            tris_in = sup_lib.iter_triangle_chunks(store, tris_key)
        elif tris_cur is None:
            tris_cur = np.asarray(list_triangles(g), np.int64).reshape(-1, 3)
            tris_in = tris_cur
        else:
            stats.tri_rescans_avoided += 1
            tris_in = tris_cur
        batch = plib.build_partition_batch(
            g, parts, with_incidence=with_incidence,
            lane_multiple=lane_multiple, tris=tris_in,
            shape_ladder=ladder if lane_multiple > 1 else None)
        if spilled_round:
            stats.tri_reload_peak_rows = max(stats.tri_reload_peak_rows,
                                             batch.tri_peak_rows)
        if lane_multiple > 1:
            for b in batch.buckets:
                shape = (b.cap_e, b.cap_t, b.n_lanes)
                if shape not in ladder:
                    ladder.append(shape)
        stats.absorb_batch(batch)
        observe = getattr(part_fn, "observe", None)
        if observe is not None:
            observe(batch)     # adaptive zone sizing feedback (§11)
        removed = np.zeros(g.m, dtype=bool)
        for bucket in batch.buckets:
            removed[bucket.edge_ids[bucket.internal]] = True
        if not removed.any():
            # the batch is discarded un-launched; keep ``batches`` meaning
            # "device launches"
            stats.batches -= len(batch.buckets)
            cur_budget *= 2
            continue
        ids_snapshot = cur_ids
        cur_ids = cur_ids[~removed]
        g_prev, g = g, g.remove_edges(removed)
        remap = np.cumsum(~removed) - 1          # old id -> compacted id
        if tris_cur is not None and len(tris_cur):
            keep = ~removed[tris_cur].any(axis=1)
            tris_cur = remap[tris_cur[keep]]
        if store is not None:
            # spill the successor BEFORE releasing the predecessor: the
            # chunk-wise filter aliases untouched chunk files, and the
            # refcounts must see them registered before the old graph's
            # release decrements them
            g.spill()
            g_prev.release()
            if spilled_round:
                # stream-filter the old spilled list into a fresh key: one
                # chunk resident at a time, and the writer must not clobber
                # the key it is still reading from, so the key alternates
                # per round and the predecessor is released after close
                new_key = store.graph_key() + "/tris"
                with sup_lib.stream_spill_triangles(store, new_key) as w:
                    for chunk in sup_lib.iter_triangle_chunks(store,
                                                              tris_key):
                        stats.tri_reload_peak_rows = max(
                            stats.tri_reload_peak_rows, int(len(chunk)))
                        keep = ~removed[chunk].any(axis=1)
                        w.append(remap[chunk[keep]])
                    spilled_rows = w.rows
                if new_key != tris_key:
                    store.release(tris_key)
                tris_key = new_key
            else:
                if tris_key is None:
                    tris_key = store.graph_key() + "/tris"
                sup_lib.spill_triangles(store, tris_key, tris_cur)
                spilled_rows = len(tris_cur)
            stats.tri_spill_rows = max(stats.tri_spill_rows,
                                       int(spilled_rows))
            tris_cur = None
            # warm the next round's reads while the consumer peels this one
            g.prefetch()
            store.prefetch([tris_key])
        # zone state as of THIS round's observe — the value the next
        # round's planning reads, hence the one a resume from this round's
        # snapshot must restore.  Captured here because the double-buffered
        # consumer journals one round late, by which time the producer has
        # already observed the following round's batch.
        yield (stats.rounds, batch, ids_snapshot, cur_budget,
               _zone_state(part_fn))


def _retry_stage1_round(eng: _Engine, stats: OocStats, shape_cache,
                        round_idx: int, batch, ids, fold_bucket, exc,
                        cur_budget: int, max_retries: int) -> None:
    """Blocking retry ladder for a failed stage-1 round (DESIGN.md §12).

    The failed dispatch's donated device buffers are gone — a poisoned
    :class:`~repro.core.peel.PendingPeel` can never be re-finalized — but
    the :class:`~repro.core.partition.PartBucket` host arrays survive the
    donation, so the round is rebuilt by re-dispatching them.  The ladder,
    engaged only for retryable failures (:func:`faults.is_retryable`):

    1. lane-split retries — re-dispatch each bucket as
       ``split_bucket_lanes`` sub-buckets (split 2, then 4, … up to
       ``max_retries`` doublings), halving the device-resident footprint
       per launch each time;
    2. mesh drop — retire the sharded dispatch for the rest of the run
       (``eng.mesh = None``; per-shard overheads are gone and the smallest
       single-device launch is strictly smaller than a shard's slice);
    3. budget halving — raise :class:`_RestartRounds` so the driver
       restarts the round loop from the journaled host state with half the
       working-set budget (smaller parts => smaller buckets), down to
       ``_MIN_ROUND_BUDGET``; below the floor the failure propagates.

    Folds re-applied by a retry are idempotent (``lb`` is a running max,
    ``phi``/``in_gnew``/``alive`` are set-to-constant scatters), so a retry
    that failed halfway through folding simply re-folds everything.
    """
    split = 1
    while True:
        if not faults.is_retryable(exc):
            raise exc
        stats.retries += 1
        if split < (1 << max_retries):
            split *= 2
        elif eng.mesh is not None:
            eng.mesh = None
            stats.degraded += 1
        else:
            if cur_budget <= _MIN_ROUND_BUDGET:
                raise exc
            stats.degraded += 1
            raise _RestartRounds(max(cur_budget // 2, _MIN_ROUND_BUDGET))
        try:
            for bi, bucket in enumerate(batch.buckets):
                for si, sub in enumerate(
                        plib.split_bucket_lanes(bucket, split)):
                    # a sub-bucket whose lane count no longer divides the
                    # mesh axis runs single-device (the point is a smaller
                    # footprint, not preserving the routing)
                    mesh = (eng.mesh if eng.mesh is not None
                            and sub.n_lanes % eng.n_dev == 0 else None)
                    h = peel_classes_batched(
                        sub.sup, sub.tris, sub.indptr, sub.tids, sub.alive,
                        shape_cache=shape_cache, blocking=False,
                        mesh=mesh, mesh_axis=eng.mesh_axis,
                        kernel=eng.kernel,
                        fault_ctx={"stage": 1, "round": round_idx,
                                   "bucket": bi, "sub": si, "retry": split})
                    stats.compiles += int(h.new_compile)
                    stats.batches += 1
                    phi_b, _ = h.result()
                    fold_bucket(round_idx, sub, ids, np.asarray(phi_b))
            return
        except Exception as e:
            exc = e


def _lower_bounding_batched(n, edges, budget, part_fn, mesh=None,
                            mesh_axis="data", kernel: str = "auto",
                            journal: Optional[RoundJournal] = None,
                            restored=None, max_retries: int = 2,
                            engine_state: Optional[_Engine] = None,
                            store: Optional[GraphStore] = None,
                            ) -> LowerBoundResult:
    m = len(edges)
    phi = np.zeros(m, dtype=np.int64)
    lb = np.full(m, 2, dtype=np.int64)
    in_gnew = np.zeros(m, dtype=bool)
    alive = np.ones(m, dtype=bool)        # still in the working graph
    stats = OocStats()
    eng = engine_state if engine_state is not None else _Engine(
        mesh=mesh, mesh_axis=mesh_axis, kernel=kernel)
    stats.devices = eng.devices
    start_budget = budget
    if restored is not None:
        # resume from a journaled "lb" snapshot: the fold state is four
        # flat arrays over original edge ids; the working graph is
        # edges[alive] (fresh ranks are fine — phi is exact under any
        # partition sequence, DESIGN.md §12)
        tree, meta = restored
        phi = tree["phi"].astype(np.int64)
        lb = tree["lb"].astype(np.int64)
        in_gnew = tree["in_gnew"].astype(bool)
        alive = tree["alive"].astype(bool)
        stats = OocStats.from_dict(meta["stats"])
        stats.resumed_round = int(meta["index"])
        stats.devices = eng.devices
        start_budget = int(meta.get("cur_budget", budget))
        _restore_zone_state(part_fn, meta.get("zone_state"))
    shape_cache: set = set()

    def fold_bucket(round_idx, bucket, ids, phi_b):
        """Fold one bucket's peel results into lb/phi/in_gnew/alive.

        Internal edges live in exactly one part, so the flat scatters are
        collision-free; every scatter is idempotent (lb is a max, the rest
        set constants), which is what lets the retry ladder re-fold."""
        int_mask = bucket.internal
        ids_int = bucket.edge_ids[int_mask]          # current-graph ids
        phi_int = phi_b[int_mask].astype(np.int64)
        glob = ids[ids_int]
        np.maximum.at(lb, glob, phi_int)
        if round_idx == 1:
            # Exact Phi_2: internal support == global support in G here.
            is2 = phi_int == 2
            phi[glob[is2]] = 2
            in_gnew[glob[~is2]] = True
        else:
            in_gnew[glob] = True
        alive[glob] = False

    def consume(pending):
        """Blocking half: land one round's folds, retrying on failure,
        then journal the completed round."""
        round_idx, batch, ids, handles, cur_b, zs = pending
        try:
            for bucket, handle in zip(batch.buckets, handles):
                phi_b, _ = handle.result()
                fold_bucket(round_idx, bucket, ids, np.asarray(phi_b))
        except Exception as exc:
            _retry_stage1_round(eng, stats, shape_cache, round_idx, batch,
                                ids, fold_bucket, exc, cur_b, max_retries)
        if journal is not None:
            journal.record("lb", round_idx,
                           {"phi": phi, "lb": lb, "in_gnew": in_gnew,
                            "alive": alive},
                           stats, cur_budget=int(cur_b), zone_state=zs)

    # Double-buffered rounds: dispatch round r non-blocking, then let the
    # generator build round r + 1 (NS sweep, triangle routing, lane packing)
    # while the device peels r; consume r's results one round late.  With a
    # mesh the same pipeline holds pod-wide: the handles are shard_map
    # dispatches whose lanes span the mesh axis (DESIGN.md §10).
    #
    # The outer loop is the budget-degrade restart (DESIGN.md §12): when
    # the retry ladder exhausts lane splits and the mesh drop, it raises
    # _RestartRounds and the round generator is rebuilt from the fold
    # state's alive mask at the smaller budget.  ``alive`` only changes in
    # fold_bucket, so an un-folded round's edges are all still present —
    # the restart re-partitions (and re-peels) exactly the unfinished work.
    while True:
        start_ids = np.nonzero(alive)[0]
        if not len(start_ids):
            break
        pending = None
        try:
            for round_idx, batch, ids, cur_b, zs in _partition_rounds(
                    n, edges, start_budget, part_fn, stats,
                    lane_multiple=eng.n_dev, start_ids=start_ids,
                    store=store):
                try:
                    handles = []
                    for bi, bucket in enumerate(batch.buckets):
                        h = peel_classes_batched(
                            bucket.sup, bucket.tris, bucket.indptr,
                            bucket.tids, bucket.alive,
                            shape_cache=shape_cache, blocking=False,
                            mesh=eng.mesh, mesh_axis=eng.mesh_axis,
                            kernel=eng.kernel,
                            fault_ctx={"stage": 1, "round": round_idx,
                                       "bucket": bi, "retry": 0})
                        stats.compiles += int(h.new_compile)
                        handles.append(h)
                    stats.sharded_rounds += int(
                        any(h.sharded for h in handles))
                except Exception as exc:
                    # the failed dispatch is dead, but the PREVIOUS round's
                    # handles are fine: land those folds first so a budget
                    # restart below cannot lose a completed round
                    if pending is not None:
                        consume(pending)
                        pending = None
                    _retry_stage1_round(eng, stats, shape_cache, round_idx,
                                        batch, ids, fold_bucket, exc,
                                        cur_b, max_retries)
                    if journal is not None:
                        journal.record("lb", round_idx,
                                       {"phi": phi, "lb": lb,
                                        "in_gnew": in_gnew, "alive": alive},
                                       stats, cur_budget=int(cur_b),
                                       zone_state=zs)
                    continue
                if pending is not None:
                    stats.overlapped += 1
                    consume(pending)
                pending = (round_idx, batch, ids, handles, cur_b, zs)
            if pending is not None:
                consume(pending)
            break
        except _RestartRounds as r:
            start_budget = r.budget

    if store is not None:
        store.absorb_into(stats)
    return LowerBoundResult(
        edges=edges, phi=phi, lb=lb, in_gnew=in_gnew, rounds=stats.rounds,
        scans=stats.scans, max_part_edges=stats.max_part_edges, stats=stats,
    )


def _lower_bounding_perpart(n, edges, budget, part_fn) -> LowerBoundResult:
    """Seed path: per-round rebuild, per-part NS scan + dynamic-shape peel."""
    m = len(edges)
    phi = np.zeros(m, dtype=np.int64)
    lb = np.full(m, 2, dtype=np.int64)
    alive = np.ones(m, dtype=bool)          # still in the working graph
    in_gnew = np.zeros(m, dtype=bool)       # emitted to G_new
    stats = OocStats()
    cur_budget = budget

    while alive.any():
        stats.rounds += 1
        cur_ids = np.nonzero(alive)[0]
        g = glib.build_graph(n, edges[cur_ids])
        parts = part_fn(g, cur_budget, stats.rounds)
        if not parts:
            break
        round_removed = np.zeros(len(cur_ids), dtype=bool)
        for P in parts:
            stats.scans += 1
            stats.parts += 1
            stats.batches += 1
            sub_ids, sub_edges, internal = glib.neighborhood_subgraph(g, P)
            if len(sub_ids) == 0:
                continue
            stats.max_part_edges = max(stats.max_part_edges, len(sub_ids))
            stats.real_edges += len(sub_ids)
            stats.padded_slots += len(sub_ids)
            phi_local = _local_truss(sub_edges, n)
            int_ids = sub_ids[internal]               # ids in current graph
            glob_ids = cur_ids[int_ids]               # ids in original graph
            lb[glob_ids] = np.maximum(lb[glob_ids], phi_local[internal])
            if stats.rounds == 1:
                is_phi2 = phi_local[internal] == 2
                phi[glob_ids[is_phi2]] = 2
                in_gnew[glob_ids[~is_phi2]] = True
            else:
                in_gnew[glob_ids] = True
            round_removed[int_ids] = True
        if not round_removed.any():
            cur_budget *= 2
            continue
        alive[cur_ids[round_removed]] = False

    return LowerBoundResult(
        edges=edges, phi=phi, lb=lb, in_gnew=in_gnew, rounds=stats.rounds,
        scans=stats.scans, max_part_edges=stats.max_part_edges, stats=stats,
    )


@dataclasses.dataclass
class BottomUpResult:
    edges: np.ndarray
    phi: np.ndarray
    kmax: int
    rounds: int
    scans: int
    candidate_sizes: List[int]   # |H| per k (I/O + working-set accounting)
    stats: Optional[OocStats] = None


def _retry_candidate_peel(eng: _Engine, stats: OocStats, exc, dispatch,
                          max_retries: int = 2):
    """Blocking retry ladder for a failed stage-2 / top-down candidate peel
    (DESIGN.md §12).  The candidate's host arrays survive the donation, so
    a retry is a plain re-dispatch of the same level (``dispatch(retry,
    eng)`` must dispatch blocking and return the folded result).  After
    ``max_retries`` failures the mesh is dropped — single-device is the
    memory floor for a candidate peel, whose size is set by the k-class
    structure rather than the round budget — and the retry budget resets
    once on the degraded engine; then the failure propagates.
    """
    attempt = 0
    while True:
        if not faults.is_retryable(exc):
            raise exc
        stats.retries += 1
        attempt += 1
        if attempt > max_retries:
            if eng.mesh is None:
                raise exc
            eng.mesh = None
            stats.degraded += 1
            attempt = 0
        try:
            return dispatch(attempt, eng)
        except Exception as e:
            exc = e


def bottom_up_decompose(
    n: int,
    edges: np.ndarray,
    budget: int,
    partitioner: str | Callable = "sequential",
    engine: str = "batched",
    *,
    partitioner_seed: int = 0,
    mesh=None,
    mesh_axis="data",
    kernel: str = "auto",
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: Union[int, str] = 1,
    resume: bool = False,
    checkpoint_keep: int = 3,
    max_retries: int = 2,
    store: Optional[GraphStore] = None,
) -> BottomUpResult:
    """Algorithm 4: full decomposition under a working-set budget.

    With a ``mesh`` (batched engine only), stage-1 rounds split their
    bucket lanes over ``mesh_axis`` and stage-2 candidate peels run
    triangle-sharded — one partition round spans the pod (DESIGN.md §10);
    ``OocStats.devices`` / ``sharded_rounds`` record the routing.  A
    ``(lane, tri)`` tuple ``mesh_axis`` additionally shards each lane's
    triangle sweep over the second axis (DESIGN.md §13).  ``kernel``
    routes the per-lane peel engine (``"pallas" | "xla" | "auto"``);
    it never changes φ or the round trajectory, so it is not part of
    the checkpoint run key.
    ``partitioner_seed`` offsets the randomized partitioner's per-round
    reseed (ignored by the deterministic splitters).

    ``checkpoint_dir`` enables the round journal (DESIGN.md §12): every
    ``checkpoint_every``-th completed stage-1 round ("lb" snapshots) and
    stage-2 level ("s2" snapshots) is written through the atomic
    checkpoint path, keeping the newest ``checkpoint_keep``
    (``checkpoint_every`` also takes a duration string — ``"30s"`` — to
    gate snapshots by wall clock instead of event count); with
    ``resume=True`` the newest intact snapshot whose run_key matches this
    configuration is restored and the run continues — φ is bit-identical
    to an uninterrupted run.  ``max_retries`` bounds the lane-split
    retries a retryable dispatch failure gets before the engine degrades
    (mesh drop, then budget halving); ``OocStats.retries / degraded /
    checkpoints / resumed_round`` record all of it.

    ``store`` (batched engine only) runs stage 1's working graph through a
    :class:`~repro.core.store.GraphStore` (DESIGN.md §15); the store's I/O
    counters land in ``OocStats``.  Neither the store nor
    ``checkpoint_every``'s gating mode enters the run key — they change
    I/O behavior, never φ or the round trajectory, so a crashed disk-backed
    run may resume in-memory and vice versa.
    """
    if store is not None and engine != "batched":
        raise ValueError(
            "store= requires the batched engine "
            "(engine='perpart' is the uninstrumented seed baseline)")
    journal = None
    snap = None
    if checkpoint_dir is not None:
        if engine != "batched":
            raise ValueError(
                "checkpointing requires the batched engine "
                "(engine='perpart' is the uninstrumented seed baseline)")
        edges = glib.canonical_edges(edges, n)
        key = _run_key("bottom_up", n, edges, budget, partitioner,
                       partitioner_seed,
                       devices=_mesh_devices(mesh, mesh_axis))
        journal = RoundJournal(checkpoint_dir, key, every=checkpoint_every,
                               keep=checkpoint_keep, store=store)
        if resume:
            snap = journal.load_latest()

    eng = _Engine(mesh=mesh, mesh_axis=mesh_axis, kernel=kernel)
    if snap is not None and snap[1]["stage"] == "s2":
        # stage 1 is complete in the snapshot; rebuild the stage-2 state
        # directly and skip the partition rounds entirely
        tree, meta = snap
        edges = glib.canonical_edges(edges, n)
        phi = tree["phi"].astype(np.int64)
        lb = tree["lb"].astype(np.int64)
        remaining = tree["remaining"].astype(bool)
        stats = OocStats.from_dict(meta["stats"])
        stats.resumed_round = int(meta["index"])
        stats.devices = eng.devices
        k0 = int(meta["index"]) + 1     # the journaled level is complete
        lbres = None
    else:
        k0 = 2
        lbres = lower_bounding(
            n, edges, budget, partitioner, engine=engine,
            partitioner_seed=partitioner_seed, mesh=mesh,
            mesh_axis=mesh_axis, journal=journal,
            restored=snap if snap is not None
            and snap[1]["stage"] == "lb" else None,
            max_retries=max_retries, engine_state=eng, store=store)
        edges = lbres.edges
        phi = lbres.phi.copy()
        lb = lbres.lb
        remaining = lbres.in_gnew.copy()
        stats = lbres.stats
    cand_sizes: List[int] = []
    shape_cache: set = set()

    def candidate_masks(k_b: int):
        """U_k and NS(U_k) from the CURRENT ``remaining`` mask — the one
        extraction both engines share.  Returns ``(h_ids, internal)`` or
        None when no remaining edge admits class k_b."""
        elig = remaining & (lb <= k_b)
        if not elig.any():
            return None
        u_k = np.zeros(n, dtype=bool)
        eg = edges[elig]
        u_k[eg[:, 0]] = True
        u_k[eg[:, 1]] = True
        # H = NS(U_k) within G_new: every remaining edge with >=1 endpoint
        # in U_k.
        u_in = u_k[edges[:, 0]]
        v_in = u_k[edges[:, 1]]
        in_h = remaining & (u_in | v_in)
        internal = remaining & u_in & v_in
        return np.nonzero(in_h)[0], internal

    def build_candidate(k_b: int):
        """Host half of one batched stage-2 level: NS(U_k) extracted,
        compacted and triangle-enumerated.

        Called one level ahead while the device still peels level k
        (DESIGN.md §11): the ``remaining`` it reads then still contains the
        edges level k is about to remove, so its U is a *superset* of the
        true U_{k+1} — which is sound: every Φ_{k+1} edge has both endpoints
        in U_{k+1} ⊆ U', so it stays removable, and a removable edge's
        triangles all lie inside NS(U') (its endpoints are in U'), so its
        support never under-counts; over-included removable edges with
        trussness > k+1 keep support >= k through their own T_{k+2}
        triangles, whose partner edges are again inside NS(U').  The edges
        the pending peel then removes are killed at use time via the
        ``alive0`` mask of ``local_threshold_peel``.  Returns None when no
        remaining edge admits class k_b (the consumer re-checks after the
        pending removal lands and jumps k past empty classes).
        """
        masks = candidate_masks(k_b)
        if masks is None:
            return None
        h_ids, internal = masks
        local_edges, verts = glib.compact_edge_list(edges[h_ids])
        sub = glib.build_graph(len(verts), local_edges)
        tris = np.asarray(list_triangles(sub), np.int32).reshape(-1, 3)
        return k_b, h_ids, tris, internal

    k = k0
    pre = None          # candidate pre-built while the previous level peeled
    while remaining.any():
        # Skip empty classes: no remaining edge admits class < min lb, so
        # jump k straight there instead of probing one k at a time.
        k = max(k, int(lb[remaining].min()))
        stats.scans += 1
        if engine == "perpart":
            # seed path: blocking per-level extraction + full-shape peel
            # (non-empty by the k-jump above)
            h_ids, internal = candidate_masks(k)
            cand_sizes.append(len(h_ids))
            sub = glib.build_graph(n, edges[h_ids])
            tris = list_triangles_np(sub)
            sup = support_from_triangle_list(tris, sub.m).astype(np.int32)
            if len(tris) == 0:
                tris = np.full((1, 3), sub.m, np.int32)
            # Map internal mask to subgraph ids (canonical order preserved).
            removable = jnp.asarray(internal[h_ids])
            _, _, removed = peel_threshold(
                jnp.asarray(sup), jnp.asarray(tris),
                jnp.ones(sub.m, bool), removable, jnp.int32(k - 2),
            )
            removed = np.asarray(removed)
        else:
            if pre is not None and pre[0] == k:
                cand = pre           # built while level k-1 was peeling
                stats.stage2_overlapped += 1
            else:
                cand = build_candidate(k)
            pre = None
            _, h_ids, tris, internal = cand
            cand_sizes.append(len(h_ids))
            # kill the edges the previous level removed after this
            # candidate was built; supports count fully-alive triangles
            alive_h = remaining[h_ids]
            if len(tris):
                t_alive = (alive_h[tris[:, 0]] & alive_h[tris[:, 1]]
                           & alive_h[tris[:, 2]])
                sup = support_from_triangle_list(
                    tris[t_alive], len(h_ids)).astype(np.int32)
            else:
                sup = np.zeros(len(h_ids), np.int32)
            handle = dispatch_exc = None
            try:
                handle = local_threshold_peel(
                    sup, tris, internal[h_ids], k - 2, alive0=alive_h,
                    shape_cache=shape_cache, blocking=False, mesh=eng.mesh,
                    mesh_axis=eng.mesh_axis, kernel=eng.kernel,
                    fault_ctx={"stage": 2, "k": int(k), "retry": 0})
                stats.compiles += int(handle.new_compile)
                stats.batches += 1
                stats.sharded_rounds += int(handle.sharded)
            except Exception as exc:
                dispatch_exc = exc      # enters the retry ladder below
            # pipeline: extract + compact level k+1's candidate on the host
            # while the device peels level k (DESIGN.md §11)
            pre = build_candidate(k + 1)
            try:
                if dispatch_exc is not None:
                    raise dispatch_exc
                _, removed = handle.result()
            except Exception as exc:
                # the level's host inputs survive the donation: re-dispatch
                # through the retry ladder (DESIGN.md §12)
                def redispatch(retry, e, _k=k, _sup=sup, _tris=tris,
                               _rm=internal[h_ids], _alive=alive_h):
                    h = local_threshold_peel(
                        _sup, _tris, _rm, _k - 2, alive0=_alive,
                        shape_cache=shape_cache, blocking=False,
                        mesh=e.mesh, mesh_axis=e.mesh_axis, kernel=e.kernel,
                        fault_ctx={"stage": 2, "k": int(_k),
                                   "retry": retry})
                    stats.compiles += int(h.new_compile)
                    stats.batches += 1
                    _, rem = h.result()
                    return rem

                removed = _retry_candidate_peel(eng, stats, exc, redispatch,
                                                max_retries)
        rm_glob = h_ids[removed]
        phi[rm_glob] = k
        remaining[rm_glob] = False
        if journal is not None:
            journal.record("s2", k,
                           {"phi": phi, "lb": lb, "remaining": remaining},
                           stats)
        k += 1

    kmax = int(phi.max()) if len(phi) else 2
    if store is not None:
        store.absorb_into(stats)    # delta-based: journal absorbs mid-run
    return BottomUpResult(
        edges=edges, phi=phi, kmax=kmax, rounds=stats.rounds,
        scans=stats.scans, candidate_sizes=cand_sizes, stats=stats,
    )


def _support_credit_triples(bucket, round_idx: int, bi: int, sub_idx: int,
                            retry: int, *,
                            chunk_rows: int = 1 << 16) -> np.ndarray:
    """Flat parent-edge-id triples of one bucket's captured triangles —
    the compute half of a ``partitioned_support`` round, kept PURE (no
    scatter into the global ``sup``).

    Unlike the stage-1 folds, triangle credits (``np.add.at``) are **not**
    idempotent, so the retry ladder must be able to recompute a failed
    bucket from its host arrays and fold exactly once afterwards; the
    ``"support"`` fault site fires here, before any credit exists.

    The lane-wise gather walks ``bucket.tris`` in slabs of ``chunk_rows``
    triangle slots so the padded ``(B, cap_t, 3)`` parent intermediate is
    never materialized whole — its peak is ``B * chunk_rows * 3`` — while
    the returned array still holds only the real (unpadded) triples.
    """
    faults.check(faults.SUPPORT, stage=1, round=round_idx, bucket=bi,
                 sub=sub_idx, retry=retry)
    B = bucket.n_lanes
    # local triangle ids -> parent edge ids, lane-wise; the drop slot
    # cap_e maps to -1, so padding rows vanish with the mask
    eid_pad = np.concatenate(
        [bucket.edge_ids, np.full((B, 1), -1, np.int64)], axis=1)
    lane = np.arange(B)[:, None, None]
    cap_t = bucket.tris.shape[1]
    step = max(1, int(chunk_rows))
    out: List[np.ndarray] = []
    for lo in range(0, cap_t, step):
        parent = eid_pad[lane, bucket.tris[:, lo:lo + step]]
        real = parent[:, :, 0] >= 0
        out.append(parent[real].reshape(-1))
    return np.concatenate(out) if out else np.zeros(0, np.int64)


def _retry_support_round(eng: _Engine, stats: OocStats, round_idx: int,
                         batch, exc, cur_budget: int,
                         max_retries: int) -> List[np.ndarray]:
    """Retry ladder for a failed triangle-credit round — the
    ``partitioned_support`` sibling of :func:`_retry_stage1_round`
    (DESIGN.md §12), engaged only for retryable failures:

    1. lane-split retries — recompute each bucket as
       ``split_bucket_lanes`` sub-buckets (split 2, then 4, … up to
       ``max_retries`` doublings; every triangle lives in exactly one lane
       of one bucket, so the union of sub-bucket triples is exactly the
       whole batch's);
    2. mesh drop — ``eng.mesh = None`` for the rest of the run (the
       credit scatters are host-side, but the shared engine state carries
       the degrade into any later device stage the caller runs);
    3. budget halving — raise :class:`_RestartRounds`; the un-credited
       round's internal edges are all still alive, so the restarted rounds
       re-credit exactly the unfinished triangles (the exactly-once
       invariant is per-working-graph).

    Returns the per-(sub-)bucket triple arrays; the caller folds them
    once, after the whole round has been recomputed successfully.
    """
    split = 1
    while True:
        if not faults.is_retryable(exc):
            raise exc
        stats.retries += 1
        if split < (1 << max_retries):
            split *= 2
        elif eng.mesh is not None:
            eng.mesh = None
            stats.degraded += 1
        else:
            if cur_budget <= _MIN_ROUND_BUDGET:
                raise exc
            stats.degraded += 1
            raise _RestartRounds(max(cur_budget // 2, _MIN_ROUND_BUDGET))
        try:
            trips = []
            for bi, bucket in enumerate(batch.buckets):
                for si, sub in enumerate(
                        plib.split_bucket_lanes(bucket, split)):
                    trips.append(
                        _support_credit_triples(sub, round_idx, bi, si,
                                                split))
            return trips
        except Exception as e:
            exc = e


def partitioned_support(
    n: int,
    edges: np.ndarray,
    budget: int,
    partitioner: str | Callable = "sequential",
    engine: str = "batched",
    with_stats: bool = False,
    *,
    partitioner_seed: int = 0,
    mesh=None,
    mesh_axis="data",
    journal: Optional[RoundJournal] = None,
    restored=None,
    max_retries: int = 2,
    store: Optional[GraphStore] = None,
):
    """Exact sup(e) w.r.t. the FULL graph, computed under a working-set
    budget (triangle-credit variant of Algorithm 3 used by the top-down
    algorithm; see DESIGN.md §7).

    Invariant: every triangle of G is credited exactly once — in the first
    round in which one of its edges becomes internal (all internal edges of a
    triangle lie in the same part, two disjoint parts cannot both hold two of
    a triangle's three vertices, and a triangle loses an edge from the
    working graph the moment it is first credited).

    The batched engine lists each NS(P)'s triangles through the compacted,
    skew-aware machinery and credits them in one vectorized scatter per
    bucket; no peeling is involved, so the batch is built without incidence
    and a ``mesh`` only records ``OocStats.devices`` for the caller
    (top-down threads it here so one stats object describes both stages —
    the credit scatters themselves are host-side and never span the mesh).

    ``journal`` / ``restored`` (batched engine only) snapshot the credit
    state after each completed round as ``"sup"``-stage snapshots and
    resume from one (DESIGN.md §12): the exactly-once crediting invariant
    is per-working-graph, so restarting the rounds from the journaled
    ``alive`` mask re-credits nothing — rounds after the snapshot were
    never folded into the journaled ``sup``.

    A failed round (the ``"support"`` fault site) drives the same
    degradation ladder as stage 1 — lane splits, mesh drop, budget-halving
    restart (:func:`_retry_support_round`); because the credits are not
    idempotent, a round's triples are all computed before any is folded,
    so a mid-round failure never half-credits.  ``max_retries`` bounds the
    lane-split rungs; ``store`` routes the working graph through a
    :class:`~repro.core.store.GraphStore` (batched engine only).
    """
    part_fn = _resolve_partitioner(partitioner, seed=partitioner_seed)
    edges = glib.canonical_edges(edges, n)
    m = len(edges)
    sup = np.zeros(m, dtype=np.int64)
    alive = np.ones(m, dtype=bool)
    stats = OocStats()
    if mesh is not None:
        if engine == "perpart":
            raise ValueError("mesh= requires the batched engine")
        stats.devices = _mesh_devices(mesh, mesh_axis)
    if store is not None and engine == "perpart":
        raise ValueError(
            "store= requires the batched engine "
            "(engine='perpart' is the uninstrumented seed baseline)")
    cur_budget = budget
    if restored is not None:
        if engine == "perpart":
            raise ValueError(
                "checkpointing requires the batched engine "
                "(engine='perpart' is the uninstrumented seed baseline)")
        tree, meta = restored
        sup = tree["sup"].astype(np.int64)
        alive = tree["alive"].astype(bool)
        dev = stats.devices
        stats = OocStats.from_dict(meta["stats"])
        stats.resumed_round = int(meta["index"])
        stats.devices = dev
        cur_budget = int(meta.get("cur_budget", budget))
        _restore_zone_state(part_fn, meta.get("zone_state"))

    if engine == "perpart":
        alive = np.ones(m, dtype=bool)
        while alive.any():
            stats.rounds += 1
            cur_ids = np.nonzero(alive)[0]
            g = glib.build_graph(n, edges[cur_ids])
            parts = part_fn(g, cur_budget, stats.rounds)
            if not parts:
                break
            round_removed = np.zeros(len(cur_ids), dtype=bool)
            for P in parts:
                stats.scans += 1
                sub_ids, sub_edges, internal = glib.neighborhood_subgraph(g, P)
                if len(sub_ids) == 0:
                    continue
                sub = glib.build_graph(n, sub_edges)
                tris = list_triangles_np(sub)
                if len(tris):
                    # subgraph edge id -> current-graph id -> original id
                    to_glob = cur_ids[sub_ids]
                    np.add.at(sup, to_glob[tris.reshape(-1)], 1)
                round_removed[sub_ids[internal]] = True
            if not round_removed.any():
                cur_budget *= 2   # stall fallback (see lower_bounding)
                continue
            alive[cur_ids[round_removed]] = False
        return (sup, stats) if with_stats else sup

    if engine != "batched":
        raise ValueError(f"unknown engine {engine!r}")

    # The triangle-credit counter is all host-side scatters (no device
    # peel), so the shared round generator is consumed directly — same
    # incremental maintenance and stall fallback as the peeling driver.
    # The outer loop is the budget-degrade restart (DESIGN.md §12): the
    # ladder raises _RestartRounds and the generator is rebuilt from the
    # credit state's alive mask at the smaller budget — un-credited rounds'
    # internal edges are all still alive, so nothing double-credits.
    eng = _Engine(mesh=mesh, mesh_axis=mesh_axis)
    while True:
        start_ids = np.nonzero(alive)[0]
        if not len(start_ids):
            break
        try:
            for round_idx, batch, ids, cur_b, zs in _partition_rounds(
                    n, edges, cur_budget, part_fn, stats,
                    with_incidence=False, start_ids=start_ids, store=store):
                try:
                    trips = [
                        _support_credit_triples(bucket, round_idx, bi, 0, 0)
                        for bi, bucket in enumerate(batch.buckets)]
                except Exception as exc:
                    trips = _retry_support_round(eng, stats, round_idx,
                                                 batch, exc, cur_b,
                                                 max_retries)
                # fold only after EVERY bucket's triples exist: the credits
                # are not idempotent, so a failed round must never be
                # partially folded (the ladder recomputes it whole)
                for trip in trips:
                    if len(trip):
                        np.add.at(sup, ids[trip], 1)
                for bucket in batch.buckets:
                    alive[ids[bucket.edge_ids[bucket.internal]]] = False
                if journal is not None:
                    journal.record("sup", round_idx,
                                   {"sup": sup, "alive": alive}, stats,
                                   cur_budget=int(cur_b), zone_state=zs)
            break
        except _RestartRounds as r:
            cur_budget = r.budget

    if store is not None:
        store.absorb_into(stats)
    return (sup, stats) if with_stats else sup
