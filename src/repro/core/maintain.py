"""Incremental truss maintenance for evolving graphs (DESIGN.md §16).

Every engine in this repo recomputes φ from scratch; the massive networks
the paper targets arrive as *edge streams*.  Zhou et al., "Efficient Truss
Maintenance in Evolving Networks" (arxiv 1402.2807) observe that a single
edge edit changes any trussness by at most 1, and only inside a small
triangle-connected region around the edited edge.  :func:`truss_maintain`
applies a batch of edits one at a time, computes each edit's affected
region on the host, and re-peels only that region with the existing
:func:`~repro.core.peel.local_threshold_peel` machinery — the padded-shape
device peel the out-of-core engines already use, honoring the same
``kernel=`` / ``mesh=`` / ``store=`` knobs.

Why sequential single edits: the ±1 bound that makes per-level processing
*exact* holds per edit, not per batch (two inserts can raise a trussness
by 2, which no single-level pass reproduces).  Each edit is O(m) host work
(id splice + one undirected CSR) plus peels over regions usually orders of
magnitude smaller than the graph — the recompute it replaces is the full
O(m^1.5) enumeration plus a full peel (``table5maint`` measures the gap).

Per-edit algorithm (both directions share the region machinery):

* **Deletion** of ``e0``: each destroyed triangle ``(e0, f, f')`` seeds
  ``f`` at level ``k = φ(f)`` iff ``min(φ(e0), φ(f')) >= k`` (the triangle
  counted toward f's level-k support).  Per level k ≥ 3 — levels are
  independent, a k→k−1 drop never changes another level's counts — the
  candidates are the triangle-connected closure of the seeds over φ=k
  edges through triangles whose other two edges have φ_old ≥ k; partners
  with φ_old > k are *frozen* (they keep φ′ ≥ k: a single delete drops
  them at most to k).  Peeling the region at threshold k−3 (an edge stays
  in the k-truss with ≥ k−2 surviving triangles) demotes exactly the
  candidates whose support structure collapsed: ``φ′ = k−1``.

* **Insertion** of ``e0``: φ′(e0) is bounded by the largest k with
  ``|{triangles of e0 : min φ_old(partners) ≥ k−1}| ≥ k−2`` (a partner
  supporting level k needs φ′ ≥ k, hence φ_old ≥ k−1).  Per level
  k in 3..k2, candidates are e0 plus the closure of φ_old = k−1 edges
  reachable from e0 through triangles whose partners have φ_old ≥ k−1
  (e0 qualifying at every level); frozen partners are φ_old ≥ k edges
  (insertion never lowers φ).  Candidates surviving the k−3 peel are
  promoted to k; φ′(e0) is the largest level it survived (≥ 2).  An edge
  not triangle-connected to e0 gains no triangle, so the closure is the
  complete affected set (the maximality argument of Zhou et al.).

Crash safety rides the PR-7 :class:`~repro.core.bottom_up.RoundJournal`:
each committed edit snapshots ``(edges, φ)`` under the ``"maint"`` stage,
and ``resume=True`` rebuilds the working graph from the newest intact
snapshot and replays only the remaining edits.  The ``"maintain"`` fault
site fires between edits (DESIGN.md §12), so the kill-9 smoke can die
mid-batch and the differential tests can pin resumed φ to the oracle.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterable, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import faults
from repro.core import graph as glib
from repro.core.bottom_up import OocStats, RoundJournal, _run_key
from repro.core.graph import Graph, build_graph, edge_id_lookup, undirected_csr

# a qualification value larger than any real trussness (m bounds φ)
_PHI_INF = np.int64(1) << 40


@dataclasses.dataclass(frozen=True)
class EditBatch:
    """One batch of edge edits; deletions apply before insertions.

    Each array is an (k, 2) vertex-pair list.  Order inside a batch does
    not affect the final φ — every edit is applied exactly, so the result
    always equals a full recompute on the final edge set — but
    delete-first keeps the working graph smallest.
    """

    inserts: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0, 2), np.int64))
    deletes: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0, 2), np.int64))


@dataclasses.dataclass
class MaintainResult:
    graph: Graph             # the maintained graph (edits applied)
    phi: np.ndarray          # trussness per edge of ``graph.edges``
    stats: OocStats


def _normalize_edits(edits) -> list:
    """Flatten ``edits`` to an ordered [(op, u, v), ...] list.

    Accepts an :class:`EditBatch` (deletes first) or any sequence of
    ``(op, u, v)`` tuples with op in {"insert", "delete"}.
    """
    steps = []
    if isinstance(edits, EditBatch):
        for u, v in np.asarray(edits.deletes, np.int64).reshape(-1, 2):
            steps.append(("delete", int(u), int(v)))
        for u, v in np.asarray(edits.inserts, np.int64).reshape(-1, 2):
            steps.append(("insert", int(u), int(v)))
        return steps
    for step in edits:
        op, u, v = step
        if op not in ("insert", "delete"):
            raise ValueError(
                f"edit op must be 'insert' or 'delete', got {op!r}")
        steps.append((op, int(u), int(v)))
    return steps


def _edits_digest(steps: Sequence[Tuple[str, int, int]]) -> str:
    h = hashlib.sha256()
    for op, u, v in steps:
        h.update(f"{op}:{u}:{v};".encode())
    return h.hexdigest()[:16]


def _tri_partners(g: Graph, indptr: np.ndarray, nbrs: np.ndarray,
                  eid: int) -> Tuple[np.ndarray, np.ndarray]:
    """Edge ids (e_aw, e_bw) of the two partner edges of every triangle
    containing edge ``eid``, via common-neighbor intersection on the
    undirected CSR (one binary-merge per query edge)."""
    a, b = (int(x) for x in g.edges[eid])
    wa = nbrs[indptr[a]:indptr[a + 1]]
    wb = nbrs[indptr[b]:indptr[b + 1]]
    w = np.intersect1d(wa, wb, assume_unique=True)
    if not len(w):
        z = np.zeros(0, np.int64)
        return z, z
    ea = edge_id_lookup(g, np.full(len(w), a, np.int64), w).astype(np.int64)
    eb = edge_id_lookup(g, np.full(len(w), b, np.int64), w).astype(np.int64)
    return ea, eb


def _grow_region(g: Graph, indptr: np.ndarray, nbrs: np.ndarray,
                 phi_q: np.ndarray, q: int, cand_mask: np.ndarray,
                 seeds: Iterable[int]):
    """Triangle-connected closure of candidate edges from ``seeds``.

    A triangle ``(e, a, b)`` of a candidate ``e`` *qualifies* when both
    partners have ``phi_q >= q``; qualifying partners that satisfy
    ``cand_mask`` join the closure, the rest are frozen (their φ′ is
    guaranteed ≥ the level under maintenance, so the peel may count but
    never remove them).  Returns ``(cand_ids, frozen_ids, tris)`` with
    ``tris`` a set of sorted edge-id triples — every qualifying triangle
    of every candidate, each exactly once.
    """
    in_c = np.zeros(g.m, dtype=bool)
    stack = []
    for s in seeds:
        s = int(s)
        if cand_mask[s] and not in_c[s]:
            in_c[s] = True
            stack.append(s)
    frozen = set()
    tris = set()
    while stack:
        e = stack.pop()
        ea, eb = _tri_partners(g, indptr, nbrs, e)
        if not len(ea):
            continue
        qual = (phi_q[ea] >= q) & (phi_q[eb] >= q)
        for a, b in zip(ea[qual], eb[qual]):
            a, b = int(a), int(b)
            tris.add(tuple(sorted((e, a, b))))
            for p in (a, b):
                if cand_mask[p]:
                    if not in_c[p]:
                        in_c[p] = True
                        stack.append(p)
                else:
                    frozen.add(p)
    cand_ids = np.nonzero(in_c)[0].astype(np.int64)
    frozen_ids = np.fromiter(sorted(frozen), np.int64, len(frozen))
    return cand_ids, frozen_ids, tris


def _peel_region(cand_ids: np.ndarray, frozen_ids: np.ndarray, tris,
                 thresh: int, peel_kwargs: dict,
                 fault_ctx: Optional[dict]) -> np.ndarray:
    """Peel one level's region; returns the candidate edge ids removed
    (deletion: demoted; insertion: NOT promoted)."""
    from repro.core.peel import local_threshold_peel

    lids = np.concatenate([cand_ids, frozen_ids])
    loc = np.zeros(int(lids.max()) + 1 if len(lids) else 1, np.int64)
    loc[lids] = np.arange(len(lids), dtype=np.int64)
    if tris:
        tris_local = loc[np.asarray(sorted(tris), np.int64)].astype(np.int32)
    else:
        tris_local = np.zeros((0, 3), np.int32)
    sup = np.bincount(tris_local.reshape(-1),
                      minlength=len(lids)).astype(np.int64)
    removable = np.zeros(len(lids), dtype=bool)
    removable[:len(cand_ids)] = True
    _, removed, _ = local_threshold_peel(
        sup, tris_local, removable, thresh, fault_ctx=fault_ctx,
        **peel_kwargs)
    return cand_ids[removed[:len(cand_ids)]]


def _apply_delete(g: Graph, phi: np.ndarray, u: int, v: int,
                  peel_kwargs: dict, stats: OocStats, edit_idx: int):
    """One exact single-edge deletion; returns (graph', phi', applied)."""
    e0 = int(edge_id_lookup(g, np.asarray([u], np.int64),
                            np.asarray([v], np.int64))[0])
    if e0 < 0:
        return g, phi, False   # edge absent: no-op
    indptr, nbrs = undirected_csr(g)
    ea, eb = _tri_partners(g, indptr, nbrs, e0)   # destroyed triangles
    k0 = int(phi[e0])
    seeds: dict = {}
    for f, other in ((ea, eb), (eb, ea)):
        if not len(f):
            continue
        kf = phi[f]
        hit = (np.minimum(k0, phi[other]) >= kf) & (kf >= 3)
        for i in np.nonzero(hit)[0]:
            seeds.setdefault(int(kf[i]), set()).add(int(f[i]))
    rm = np.zeros(g.m, dtype=bool)
    rm[e0] = True
    g1 = g.remove_edges(rm)
    new_id = np.cumsum(~rm) - 1            # old -> new ids (survivors)
    phi_old = phi[~rm]                     # levels read φ as of before
    phi_new = phi_old.copy()
    indptr1, nbrs1 = undirected_csr(g1)
    for k in sorted(seeds):
        sd = [int(new_id[e]) for e in seeds[k]]
        cand, frozen, tris = _grow_region(
            g1, indptr1, nbrs1, phi_old, k, phi_old == k, sd)
        if not len(cand):
            continue
        demoted = _peel_region(
            cand, frozen, tris, k - 3, peel_kwargs,
            {"stage": "maint", "edit": edit_idx, "k": int(k), "retry": 0})
        phi_new[demoted] = k - 1
        stats.maintain_levels += 1
        stats.affected_edges += int(len(cand))
    return g1, phi_new, True


def _apply_insert(g: Graph, phi: np.ndarray, u: int, v: int,
                  peel_kwargs: dict, stats: OocStats, edit_idx: int):
    """One exact single-edge insertion; returns (graph', phi', applied)."""
    pair = np.asarray([[u, v]], np.int64)
    g1 = g.add_edges(pair)
    if g1 is g:
        return g, phi, False   # present / self-loop: no-op
    e0 = int(edge_id_lookup(g1, np.asarray([u], np.int64),
                            np.asarray([v], np.int64))[0])
    phi_old = np.insert(phi, e0, 2)
    phi_new = phi_old.copy()
    phi_q = phi_old.copy()
    phi_q[e0] = _PHI_INF     # e0 qualifies as a partner at every level
    indptr1, nbrs1 = undirected_csr(g1)
    ea, eb = _tri_partners(g1, indptr1, nbrs1, e0)  # the created triangles
    phi_e0 = 2
    if len(ea):
        tmin = np.sort(np.minimum(phi_old[ea], phi_old[eb]))[::-1]
        # k2: largest k with >= k-2 triangles whose partners allow level k
        k2 = 2
        for j in range(len(tmin)):   # j+1 triangles have tmin >= tmin[j]
            k2 = max(k2, min(int(tmin[j]) + 1, j + 3))
        for k in range(3, k2 + 1):
            cand_mask = phi_old == k - 1
            cand_mask[e0] = True
            cand, frozen, tris = _grow_region(
                g1, indptr1, nbrs1, phi_q, k - 1, cand_mask, [e0])
            not_promoted = _peel_region(
                cand, frozen, tris, k - 3, peel_kwargs,
                {"stage": "maint", "edit": edit_idx, "k": int(k),
                 "retry": 0})
            keep = np.ones(len(cand), dtype=bool)
            keep[np.searchsorted(cand, not_promoted)] = False
            promoted = cand[keep]
            if e0 in promoted:
                phi_e0 = max(phi_e0, k)
            others = promoted[promoted != e0]
            phi_new[others] = k
            stats.maintain_levels += 1
            stats.affected_edges += int(len(cand))
    phi_new[e0] = phi_e0
    return g1, phi_new, True


def truss_maintain(graph: Union[Graph, Tuple[int, np.ndarray]],
                   phi: np.ndarray, edits, *, kernel: str = "auto",
                   mesh=None, mesh_axis="data", store=None,
                   checkpoint_dir: Optional[str] = None,
                   checkpoint_every: Union[int, str] = 1,
                   resume: bool = False) -> MaintainResult:
    """Maintain a truss decomposition under a batch of edge edits.

    Args:
      graph: the current :class:`Graph` (or an ``(n, edges)`` pair), whose
        decomposition ``phi`` is being maintained.
      phi: (m,) trussness per edge of ``graph.edges`` — the output of any
        of the repo's decomposers on the pre-edit graph.
      edits: an :class:`EditBatch` or an ordered sequence of
        ``(op, u, v)`` tuples, op in {"insert", "delete"}.  No-op edits
        (deleting an absent edge, inserting a present one) are skipped.
      kernel / mesh / mesh_axis: forwarded to every region peel
        (:func:`~repro.core.peel.local_threshold_peel`), so maintenance
        runs on the same engine the full decomposition would.
      store: optional :class:`~repro.core.store.GraphStore`; the working
        graph spills through it between edits (chunk-wise: the splice /
        filter plans alias untouched chunks), keeping maintenance
        out-of-core capable.  A graph already carrying a store keeps it.
      checkpoint_dir / checkpoint_every / resume: the
        :class:`~repro.core.bottom_up.RoundJournal` knobs — each committed
        edit journals ``(edges, φ)`` and ``resume=True`` replays only the
        edits after the newest intact snapshot.

    Returns a :class:`MaintainResult`; ``result.phi`` is bit-identical to
    a full recompute on ``result.graph.edges`` (the differential suite
    pins this across the conformance corpus).
    """
    if isinstance(graph, Graph):
        g = graph
        if store is None:
            store = g.store
    else:
        n0, edges0 = graph
        g = build_graph(int(n0), np.asarray(edges0), store=store)
    if store is not None and g.store is None:
        g = build_graph(g.n, g.edges, store=store)
    phi = np.asarray(phi, dtype=np.int64).copy()
    if len(phi) != g.m:
        raise ValueError(
            f"phi has {len(phi)} entries but the graph has {g.m} edges")
    steps = _normalize_edits(edits)
    stats = OocStats()
    shape_cache: set = set()
    peel_kwargs = dict(shape_cache=shape_cache, kernel=kernel, mesh=mesh,
                       mesh_axis=mesh_axis)

    journal = None
    start = 0
    if checkpoint_dir is not None:
        run_key = _run_key("maintain", g.n, g.edges, budget=0,
                           partitioner="none", partitioner_seed=0,
                           edits=_edits_digest(steps))
        journal = RoundJournal(checkpoint_dir, run_key,
                               every=checkpoint_every, store=store)
        if resume:
            snap = journal.load_latest()
            if snap is not None:
                tree, meta = snap
                if meta.get("stage") != "maint":
                    raise ValueError(
                        f"checkpoint_dir {checkpoint_dir!r} holds a "
                        f"{meta.get('stage')!r} journal, not a maintenance "
                        f"one; refusing to resume")
                edges1 = np.asarray(tree["edges"], np.int64)
                released = g
                g = build_graph(g.n, edges1, store=store)
                if store is not None and released.store is store:
                    # the journaled graph supersedes the caller's spill
                    released.unload()
                phi = np.asarray(tree["phi"], np.int64)
                start = int(meta["index"]) + 1
                stats = OocStats.from_dict(meta.get("stats", {}))
                stats.resumed_round = int(meta["index"])

    first = g   # the caller's graph: never released here
    if store is not None:
        g.spill()
    for i in range(start, len(steps)):
        op, u, v = steps[i]
        faults.check(faults.MAINTAIN, edit=i, op=op, u=int(u), v=int(v))
        prev = g
        if op == "delete":
            g, phi, applied = _apply_delete(g, phi, u, v, peel_kwargs,
                                            stats, i)
        else:
            g, phi, applied = _apply_insert(g, phi, u, v, peel_kwargs,
                                            stats, i)
        if applied:
            stats.edits_applied += 1
            stats.rounds += 1
            if store is not None:
                g.spill()                   # spill successor first: its
                if prev is not first:       # plan aliases prev's chunks
                    prev.release()
        if journal is not None:
            journal.record("maint", i, {"phi": phi, "edges": g.edges},
                           stats)
    if store is not None:
        store.absorb_into(stats)
    return MaintainResult(graph=g, phi=phi, stats=stats)
