"""Paper-faithful serial truss decomposition (numpy/python oracles).

``alg1_truss`` is Cohen's original algorithm (paper Algorithm 1, "TD-inmem"):
on each edge removal it intersects the *full* neighborhoods of both endpoints,
O(sum_v deg(v)^2) total.

``alg2_truss`` is the paper's improved algorithm (Algorithm 2, "TD-inmem+"):
edges are kept in a bin-sorted array by support; on removal of e=(u,v) only
the neighbors of the lower-degree endpoint are enumerated, with O(1) hash
membership tests — O(m^1.5) total (Theorem 1).

Both return the trussness phi(e) per canonical edge id and serve as the
correctness oracle for every vectorized/distributed path in this framework.
"""

from __future__ import annotations

import numpy as np

from repro.core import graph as glib


class _EdgeBins:
    """Bin-sorted edge array with O(1) decrement, as in Batagelj–Zaversnik.

    Mirrors the paper's "sorted edge array A" + position table: edges sorted
    ascending by support; ``remove_min``/``decrement`` are O(1).
    """

    def __init__(self, sup: np.ndarray):
        self.m = len(sup)
        self.sup = sup.astype(np.int64).copy()
        max_s = int(self.sup.max()) if self.m else 0
        order = np.argsort(self.sup, kind="stable")
        self.arr = order.astype(np.int64)  # edge ids sorted by support
        self.pos = np.empty(self.m, dtype=np.int64)
        self.pos[self.arr] = np.arange(self.m)
        # bin_start[s] = first index in arr with support >= s
        counts = np.bincount(self.sup, minlength=max_s + 2)
        self.bin_start = np.zeros(max_s + 2, dtype=np.int64)
        self.bin_start[1:] = np.cumsum(counts)[:-1]
        self.head = 0  # everything left of head is removed

    def min_support(self) -> int:
        return int(self.sup[self.arr[self.head]])

    def empty(self) -> bool:
        return self.head >= self.m

    def pop_min(self) -> int:
        e = int(self.arr[self.head])
        self.head += 1
        return e

    def decrement(self, e: int) -> None:
        """sup[e] -= 1, keeping the array bin-sorted (O(1))."""
        s = int(self.sup[e])
        p = int(self.pos[e])
        start = max(int(self.bin_start[s]), self.head)
        # swap e with the first edge of its bin
        q = start
        o = int(self.arr[q])
        self.arr[p], self.arr[q] = o, e
        self.pos[o], self.pos[e] = p, q
        self.bin_start[s] = start + 1
        self.sup[e] = s - 1


def _adjacency(n: int, edges: np.ndarray) -> list[dict[int, int]]:
    adj: list[dict[int, int]] = [dict() for _ in range(n)]
    for eid, (u, v) in enumerate(edges):
        adj[u][v] = eid
        adj[v][u] = eid
    return adj


def initial_support(n: int, edges: np.ndarray) -> np.ndarray:
    """sup(e) for every canonical edge, via degree-oriented wedge counting."""
    g = glib.build_graph(n, edges)
    from repro.core.support import edge_support_np

    return edge_support_np(g)


def alg2_truss(n: int, edges: np.ndarray, sup: np.ndarray | None = None) -> np.ndarray:
    """Paper Algorithm 2 ("TD-inmem+"). Returns phi per canonical edge id."""
    edges = glib.canonical_edges(edges, n)
    m = len(edges)
    phi = np.zeros(m, dtype=np.int64)
    if m == 0:
        return phi
    if sup is None:
        sup = initial_support(n, edges)
    bins = _EdgeBins(np.asarray(sup))
    adj = _adjacency(n, edges)
    removed = np.zeros(m, dtype=bool)
    k = 2
    while not bins.empty():
        if bins.min_support() > k - 2:
            k += 1
            continue
        e = bins.pop_min()
        removed[e] = True
        u, v = int(edges[e, 0]), int(edges[e, 1])
        # Theorem-1 trick: enumerate the lower-degree endpoint only.
        if len(adj[u]) > len(adj[v]):
            u, v = v, u
        av = adj[v]
        for w, euw in list(adj[u].items()):
            evw = av.get(w)
            if evw is None:
                continue
            if not removed[euw]:
                bins.decrement(euw)
            if not removed[evw]:
                bins.decrement(evw)
        del adj[u][v], adj[v][u]
        phi[e] = k
    return phi


def alg1_truss(n: int, edges: np.ndarray, sup: np.ndarray | None = None) -> np.ndarray:
    """Cohen's Algorithm 1 ("TD-inmem"): full neighborhood intersection on
    every removal (the O(sum deg^2) baseline the paper improves on)."""
    edges = glib.canonical_edges(edges, n)
    m = len(edges)
    phi = np.zeros(m, dtype=np.int64)
    if m == 0:
        return phi
    if sup is None:
        sup = initial_support(n, edges)
    bins = _EdgeBins(np.asarray(sup))
    adj = _adjacency(n, edges)
    removed = np.zeros(m, dtype=bool)
    k = 3  # Algorithm 1 starts at k=3; its threshold is STRICT (sup < k-2),
    # so an edge removed at level k has trussness k-1 (it survives T_{k-1}).
    while not bins.empty():
        if bins.min_support() >= k - 2:
            k += 1
            continue
        e = bins.pop_min()
        removed[e] = True
        u, v = int(edges[e, 0]), int(edges[e, 1])
        # Full intersection, no degree ordering (Algorithm 1 Steps 5-6).
        common = set(adj[u].keys()) & set(adj[v].keys())
        for w in common:
            euw, evw = adj[u][w], adj[v][w]
            if not removed[euw]:
                bins.decrement(euw)
            if not removed[evw]:
                bins.decrement(evw)
        del adj[u][v], adj[v][u]
        phi[e] = k - 1
    return phi


def truss_from_phi(edges: np.ndarray, phi: np.ndarray, k: int) -> np.ndarray:
    """Edge set of the k-truss: union of classes >= k (paper Section 2)."""
    return edges[phi >= k]


def verify_truss(n: int, edges: np.ndarray, phi: np.ndarray) -> bool:
    """Definition-level check: for each k, every edge of T_k has support
    >= k-2 inside T_k, and T_{k+1}-excluded edges fail inside T_k + {e}."""
    edges = glib.canonical_edges(edges, n)
    if len(edges) == 0:
        return True
    for k in range(2, int(phi.max()) + 1):
        tk = truss_from_phi(edges, phi, k)
        if len(tk) == 0:
            continue
        g = glib.build_graph(n, tk)
        from repro.core.support import edge_support_np

        sup = edge_support_np(g)
        if (sup < k - 2).any():
            return False
    return True
