"""Truss-based graph utilities exposed to the training framework.

This is where the paper's technique becomes a first-class feature of the
GNN/recsys pipelines (DESIGN.md §4):

* ``truss_filter``       — keep only edges of the k-truss (cohesive-core
                           training graph; the paper's visualization /
                           fingerprinting use case as a data-prep op);
* ``trussness_features`` — per-edge trussness as an input feature;
* ``sampling_weights``   — trussness-proportional neighbor-sampling weights
                           for the minibatch GNN sampler (strong ties first);
* ``clique_upper_bound`` — k_max bound on the maximum clique (Section 7.4).
"""

from __future__ import annotations

import numpy as np

from repro.core import graph as glib
from repro.core.peel import truss_decompose


def truss_filter(n: int, edges: np.ndarray, k: int) -> np.ndarray:
    """Edge list of the k-truss T_k."""
    edges = glib.canonical_edges(edges, n)
    phi = truss_decompose(n, edges)
    return edges[phi >= k]


def trussness_features(n: int, edges: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(canonical edges, normalized trussness in [0, 1]) per edge."""
    edges = glib.canonical_edges(edges, n)
    phi = truss_decompose(n, edges).astype(np.float32)
    kmax = max(phi.max(), 3.0)
    return edges, (phi - 2.0) / (kmax - 2.0)


def sampling_weights(n: int, edges: np.ndarray, alpha: float = 1.0) -> np.ndarray:
    """Per-edge neighbor-sampling weight ∝ (trussness - 1) ** alpha."""
    edges = glib.canonical_edges(edges, n)
    phi = truss_decompose(n, edges).astype(np.float64)
    w = np.maximum(phi - 1.0, 1.0) ** alpha
    return (w / w.sum()).astype(np.float32)


def clique_upper_bound(n: int, edges: np.ndarray) -> int:
    """Max-clique size is at most k_max (tighter than c_max + 1; §7.4)."""
    phi = truss_decompose(n, edges)
    return int(phi.max()) if len(phi) else 2
