"""Top-down truss decomposition for the top-t classes (paper Section 6).

``upper_bounds`` implements Procedure 6 / Lemma 2: for e = (u, v),
``psi(e) = min(sup(e), x_u, x_v) + 2`` where ``x_w`` is the largest x such
that x edges incident to w (excluding e) have support >= x — an h-index over
incident supports, computed vectorized for all edges at once.

``top_down_decompose`` implements Algorithm 7: classes are extracted from
k = max(psi) downward.  Per k it extracts the candidate H = NS(U_k) with
``U_k = {v : exists unclassified alive e at v with psi(e) >= k}`` and peels it
at threshold (k-3) (i.e. removes sup < k-2, Procedure 8); the surviving
internal unclassified edges are Phi_k.  Classified edges that no longer share
any triangle with an undecided edge are pruned from the working graph
(Algorithm 7 Steps 7-9).

The per-k candidate peel runs on the batch-engine machinery (DESIGN.md §8):
H is compacted to candidate-local edge ids, its triangle list filtered from
the one static G_new list, and the peel executes on pow4-padded shapes
(``peel.local_threshold_peel``) so consecutive k values reuse one compiled
kernel — the seed path instead recomputed an m-wide support scatter and ran
an m-sized peel per k.  The peel is dispatched non-blocking (DESIGN.md §9,
§11): while the device works, the host pre-builds the NEXT level's
candidate from the pre-result masks (a superset — provably sound: newly
classified edges flip to support-only externals and pruned edges die via
the peel's ``alive0`` mask at use time; ``OocStats.stage2_overlapped``)
and runs the O(T) alive-triangle sweep the Steps-7-9 pruning needs.  With
a ``budget``, stage-1 supports come from the
batched ``partitioned_support`` (whose partition rounds share the
double-buffered producer of ``bottom_up._partition_rounds``).
``TopDownResult.stats`` carries the ``OocStats`` counters of both stages.

Deviation from the paper (DESIGN.md §7): Procedure 8 counts support
contributed by *external unclassified* edges of H — edges whose own upper
bound rules them out of T_k (psi < k at every vertex outside U_k) — which can
keep a non-T_k internal edge alive and over-report Phi_k.  We exclude
external unclassified edges from the candidate peel, which makes the result
provably exact: survivors S satisfy "every edge of S ∪ T_k has support
>= k-2 within S ∪ T_k", so S ⊆ T_k by maximality, and S ⊇ Phi_k because a
T_k edge's triangles inside T_k use only classified or Phi_k (internal)
co-edges, all present.  ``faithful_proc8=True`` restores the paper's literal
procedure for comparison.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core import graph as glib
from repro.core.bottom_up import (OocStats, RoundJournal, _Engine,
                                  _retry_candidate_peel, _run_key,
                                  partitioned_support)
from repro.core.peel import local_threshold_peel
from repro.core.support import (edge_support_auto, list_triangles,
                                support_from_triangle_list)


def upper_bounds(n: int, edges: np.ndarray, sup: np.ndarray) -> np.ndarray:
    """Procedure 6: psi(e) upper bound on trussness, vectorized."""
    m = len(edges)
    if m == 0:
        return np.zeros(0, np.int64)
    sup = np.asarray(sup, dtype=np.int64)
    inc_v = np.concatenate([edges[:, 0], edges[:, 1]]).astype(np.int64)
    inc_e = np.concatenate([np.arange(m), np.arange(m)]).astype(np.int64)
    inc_s = sup[inc_e]
    order = np.lexsort((-inc_s, inc_v))
    v_sorted = inc_v[order]
    s_sorted = inc_s[order]
    # segment starts per vertex
    seg_start = np.zeros(n + 1, dtype=np.int64)
    np.add.at(seg_start, v_sorted + 1, 1)
    seg_start = np.cumsum(seg_start)
    r = np.arange(len(v_sorted), dtype=np.int64) - seg_start[v_sorted] + 1
    # h0(v) = #{r : s_r >= r}; s_r - r strictly decreasing within a segment.
    cond = (s_sorted >= r).astype(np.int64)
    h0 = np.zeros(n, dtype=np.int64)
    np.add.at(h0, v_sorted, cond)
    # s_{h0+1}(v): the (h0+1)-th largest incident support (0 if none).
    deg = seg_start[1:] - seg_start[:-1]
    idx = seg_start[:-1] + h0  # position of rank h0+1
    has_next = h0 < deg
    s_next = np.where(has_next, s_sorted[np.minimum(idx, len(s_sorted) - 1)], 0)
    # x_v(e): exclude e from v's h-index.
    def x_at(vcol):
        v = edges[:, vcol].astype(np.int64)
        h = h0[v]
        drop = (sup >= h) & ~(s_next[v] >= np.maximum(h, 1))
        # if sup(e) < h0: exclusion doesn't affect counts at threshold h0;
        # x >= 0 always (the empty set satisfies x = 0).
        x = np.where(sup < h, h, np.where(drop, h - 1, h))
        return np.maximum(x, 0)

    x_u = x_at(0)
    x_v = x_at(1)
    return np.minimum(sup, np.minimum(x_u, x_v)) + 2


@dataclasses.dataclass
class TopDownResult:
    edges: np.ndarray
    phi: np.ndarray          # 0 = undecided (beyond the requested top-t)
    classes: List[int]       # the k values emitted, descending
    kmax: int
    candidate_sizes: List[int]
    pruned: int              # edges pruned by Steps 7-9
    stats: Optional[OocStats] = None


def top_down_decompose(
    n: int,
    edges: np.ndarray,
    t: Optional[int] = None,
    budget: Optional[int] = None,
    partitioner: str = "sequential",
    faithful_proc8: bool = False,
    *,
    partitioner_seed: int = 0,
    mesh=None,
    mesh_axis="data",
    kernel: str = "auto",
    checkpoint_dir=None,
    checkpoint_every: "int | str" = 1,
    resume: bool = False,
    checkpoint_keep: int = 3,
    max_retries: int = 2,
    store=None,
) -> TopDownResult:
    """Algorithm 7: top-t k-classes (all classes if t is None).

    With a ``mesh``, every per-k candidate peel runs with its triangle
    list sharded over ``mesh_axis`` (DESIGN.md §10); ``OocStats.devices``
    / ``sharded_rounds`` record the routing.  A ``(lane, tri)`` tuple
    ``mesh_axis`` shards the triangle sweep over the flattened product of
    both axes (DESIGN.md §13).  ``kernel`` routes the candidate peel
    engine (``"pallas" | "xla" | "auto"``, forwarded to
    ``peel.local_threshold_peel``); it never changes φ, so it is not part
    of the checkpoint run key.  ``partitioner_seed`` offsets the
    randomized partitioner's per-round reseed in stage 1.

    With a ``checkpoint_dir`` the run journals round state (DESIGN.md §12):
    stage-1 partition rounds as ``"sup"`` snapshots and each completed class
    level as a ``"td"`` snapshot; ``resume=True`` restores the newest intact
    one and continues to a phi bit-identical to an uninterrupted run.  The
    derived level structure (psi, G_new, its triangle list) is recomputed
    deterministically from the journaled supports rather than stored.
    Failed candidate peels walk the retry ladder of
    ``bottom_up._retry_candidate_peel``; failed stage-1 credit rounds walk
    ``bottom_up._retry_support_round`` (``max_retries`` bounds both).
    ``checkpoint_every`` also accepts a duration string (``"30s"``).

    ``store`` routes stage 1's working graph through a
    :class:`~repro.core.store.GraphStore` (requires a ``budget`` — the
    unbudgeted whole-graph support path is in-memory by construction);
    the per-k class walk operates on G_new, which the top-down algorithm
    assumes host-resident (DESIGN.md §15).
    """
    edges = glib.canonical_edges(edges, n)
    m = len(edges)
    phi = np.zeros(m, dtype=np.int64)
    stats = OocStats()
    eng = _Engine(mesh=mesh, mesh_axis=mesh_axis, kernel=kernel)
    if mesh is not None:
        stats.devices = eng.devices
    if store is not None and budget is None:
        raise ValueError(
            "store= requires a working-set budget (the unbudgeted support "
            "path computes over the whole resident graph)")
    if m == 0:
        return TopDownResult(edges, phi, [], 2, [], 0, stats)

    journal = snap = None
    if checkpoint_dir is not None:
        key = _run_key("top_down", n, edges, budget, partitioner,
                       partitioner_seed, t=t, faithful=bool(faithful_proc8),
                       devices=eng.devices)
        journal = RoundJournal(checkpoint_dir, key, every=checkpoint_every,
                               keep=checkpoint_keep, store=store)
        if resume:
            snap = journal.load_latest()
    td_snap = snap if snap is not None and snap[1].get("stage") == "td" else None

    # Stage 1 (Alg 3 variant): exact supports; Phi_2 = zero-support edges.
    # edge_support_auto routes dense cores to the matmul/Pallas path and
    # sparse graphs to the bucketed wedge scan (DESIGN.md §2); with a budget
    # the batched triangle-credit counter runs under the working-set cap.
    # A "td" snapshot carries the finished supports, so stage 1 is skipped.
    if td_snap is not None:
        sup = np.asarray(td_snap[0]["sup"], dtype=np.int64)
        stats = OocStats.from_dict(td_snap[1]["stats"])
        stats.resumed_round = int(td_snap[1]["index"])
        if mesh is not None:
            stats.devices = eng.devices
    elif budget is None:
        g = glib.build_graph(n, edges)
        sup = edge_support_auto(g)
    else:
        sup, stats = partitioned_support(
            n, edges, budget,
            partitioner=partitioner,
            partitioner_seed=partitioner_seed,
            mesh=mesh, mesh_axis=mesh_axis,
            with_stats=True, journal=journal,
            restored=snap if snap is not None
            and snap[1].get("stage") == "sup" else None,
            max_retries=max_retries, store=store)
    phi[sup == 0] = 2
    alive = sup > 0                      # G_new
    psi = upper_bounds(n, edges, sup)

    # One static triangle list over G_new (skew-aware enumeration); every
    # per-k candidate filters it instead of re-enumerating wedges.
    gnew = glib.build_graph(n, edges[alive])
    gnew_ids = np.nonzero(alive)[0]
    tris_l = np.asarray(list_triangles(gnew), dtype=np.int64).reshape(-1, 3)
    shape_cache: set = set()
    # masks below are in G_new-local edge ids
    alive_l = np.ones(gnew.m, dtype=bool)
    classified_l = np.zeros(gnew.m, dtype=bool)
    psi_l = psi[gnew_ids]
    edges_l = edges[gnew_ids]

    classes: List[int] = []
    cand_sizes: List[int] = []
    pruned_total = 0
    k = int(psi_l.max()) if gnew.m else 2
    if td_snap is not None:
        # Continue below the journaled level: the snapshot's masks are the
        # state AFTER level ``index`` completed, so the next level is
        # ``index - 1``.  phi already holds every emitted class.
        tree, meta = td_snap
        phi = np.asarray(tree["phi"], dtype=np.int64)
        alive_l = np.asarray(tree["alive_l"], dtype=bool)
        classified_l = np.asarray(tree["classified_l"], dtype=bool)
        classes = [int(c) for c in meta.get("classes", [])]
        cand_sizes = [int(c) for c in meta.get("cand_sizes", [])]
        pruned_total = int(meta.get("pruned", 0))
        k = int(meta["index"]) - 1

    def build_candidate(k_b: int):
        """Host half of one top-down level: U_k from the CURRENT alive /
        classified masks, the candidate compacted and its triangles
        filtered from the static G_new list.

        Called one level ahead while the device still peels level k
        (DESIGN.md §11), when ``classified_l`` / ``alive_l`` miss the
        pending level's classifications and prunes — which only makes U
        and the candidate *supersets* of the true ones, and that is sound:
        a Φ_{k-1} edge is undecided and alive with psi >= k-1 now and
        after the pending level (classification only touches survivors of
        level k, pruning only classified edges off every undecided
        triangle), so it stays tentative with its T_{k-1} triangles
        present; extra tentative edges can only peel away or survive into
        S, and the S ∪ T_k maximality argument of the module docstring
        never assumed U was minimal.  At use time the masks are re-read:
        newly classified edges flip from removable to support-only,
        pruned edges die via the ``alive0`` mask of
        ``local_threshold_peel``.  Returns None when no undecided alive
        edge has psi >= k_b.
        """
        undecided_b = alive_l & ~classified_l
        elig = undecided_b & (psi_l >= k_b)
        if not elig.any():
            return None
        u_k = np.zeros(n, dtype=bool)
        eg = edges_l[elig]
        u_k[eg[:, 0]] = True
        u_k[eg[:, 1]] = True
        u_in = u_k[edges_l[:, 0]]
        v_in = u_k[edges_l[:, 1]]
        in_h = alive_l & (u_in | v_in)
        internal = u_in & v_in           # re-masked by alive at use time
        if faithful_proc8:
            cand_set = in_h
        else:
            # exclude external unclassified support (see module docstring)
            cand_set = ((internal & alive_l & ~classified_l)
                        | (classified_l & in_h))
        # Compact the candidate to local edge ids and filter its triangles
        # (part-local compaction shared with the partition-batch engine).
        h_l = np.nonzero(cand_set)[0]
        tmask = (cand_set[tris_l[:, 0]] & cand_set[tris_l[:, 1]]
                 & cand_set[tris_l[:, 2]])
        tris_loc = glib.compact_index(h_l, tris_l[tmask])
        return k_b, h_l, tris_loc, internal, int(in_h.sum())

    pre = None          # candidate pre-built while the previous level peeled
    while k >= 3 and (t is None or len(classes) < t):
        undecided = alive_l & ~classified_l
        if not undecided.any():
            break
        elig = undecided & (psi_l >= k)
        if not elig.any():
            k = int(psi_l[undecided].max())
            continue
        if pre is not None and pre[0] == k and not faithful_proc8:
            cand = pre               # built while level k+1 was peeling
            stats.stage2_overlapped += 1
        else:
            cand = build_candidate(k)
        pre = None
        _, h_l, tris_loc, internal, in_h_size = cand
        tentative = internal & alive_l & ~classified_l
        cand_sizes.append(in_h_size)
        stats.scans += 1
        # kill candidate edges pruned after a pre-build; supports count
        # fully-alive triangles (newly classified edges stay as
        # support-only externals — they were tentative at build time)
        alive_h = alive_l[h_l]
        if len(tris_loc):
            t_alive = (alive_h[tris_loc[:, 0]] & alive_h[tris_loc[:, 1]]
                       & alive_h[tris_loc[:, 2]])
            sup0 = support_from_triangle_list(
                tris_loc[t_alive], len(h_l)).astype(np.int32)
        else:
            sup0 = np.zeros(len(h_l), np.int32)
        # Double-buffered candidate peel (DESIGN.md §9, §11): dispatch
        # without blocking, then build the NEXT level's candidate and do
        # the O(T) alive-triangle sweep the prune step needs while the
        # device peels — both depend only on masks the peel result cannot
        # change before it is consumed.
        handle = dispatch_exc = None
        try:
            handle = local_threshold_peel(
                sup0, tris_loc, tentative[h_l], k - 3, alive0=alive_h,
                shape_cache=shape_cache, blocking=False, mesh=eng.mesh,
                mesh_axis=eng.mesh_axis, kernel=eng.kernel,
                fault_ctx={"stage": "td", "k": int(k), "retry": 0})
            stats.compiles += int(handle.new_compile)
            stats.batches += 1
            stats.sharded_rounds += int(handle.sharded)
        except Exception as exc:
            dispatch_exc = exc          # enters the retry ladder below
        if not faithful_proc8:
            pre = build_candidate(k - 1)
        ta = (alive_l[tris_l[:, 0]] & alive_l[tris_l[:, 1]]
              & alive_l[tris_l[:, 2]])
        try:
            if dispatch_exc is not None:
                raise dispatch_exc
            surv_l, _ = handle.result()
        except Exception as exc:
            # Candidate host arrays survive the donation, so a retry is a
            # plain re-dispatch of the same level (DESIGN.md §12).
            def redispatch(retry, e, _sup=sup0, _tris=tris_loc,
                           _rm=tentative[h_l], _k=k, _alive=alive_h):
                h = local_threshold_peel(
                    _sup, _tris, _rm, _k - 3, alive0=_alive,
                    shape_cache=shape_cache, blocking=False, mesh=e.mesh,
                    mesh_axis=e.mesh_axis, kernel=e.kernel,
                    fault_ctx={"stage": "td", "k": int(_k), "retry": retry})
                stats.compiles += int(h.new_compile)
                stats.batches += 1
                stats.sharded_rounds += int(h.sharded)
                s, _ = h.result()
                return s
            surv_l = _retry_candidate_peel(eng, stats, exc, redispatch,
                                           max_retries)
        phi_k = np.zeros(gnew.m, dtype=bool)
        phi_k[h_l[surv_l]] = True
        phi_k &= tentative
        if phi_k.any():
            classes.append(k)
            classified_l |= phi_k
            phi[gnew_ids[phi_k]] = k
            # Steps 7-9: prune classified edges with no undecided triangle.
            und = alive_l & ~classified_l
            tri_needs = ta & (und[tris_l[:, 0]] | und[tris_l[:, 1]]
                              | und[tris_l[:, 2]])
            needs = np.zeros(gnew.m, dtype=np.int64)
            np.add.at(needs, tris_l.reshape(-1), np.repeat(tri_needs, 3))
            prunable = alive_l & classified_l & (needs == 0)
            pruned_total += int(prunable.sum())
            alive_l &= ~prunable
        if journal is not None:
            journal.record(
                "td", k,
                {"phi": phi, "sup": sup, "alive_l": alive_l,
                 "classified_l": classified_l},
                stats,
                classes=[int(c) for c in classes],
                cand_sizes=[int(c) for c in cand_sizes],
                pruned=int(pruned_total))
        k -= 1

    kmax = classes[0] if classes else 2
    return TopDownResult(
        edges=edges, phi=phi, classes=classes, kmax=kmax,
        candidate_sizes=cand_sizes, pruned=pruned_total, stats=stats,
    )
