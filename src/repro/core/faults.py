"""Deterministic fault injection for the out-of-core engines (DESIGN.md §12).

Long out-of-core decompositions fail in a handful of well-defined places:
a device peel OOMs at dispatch, a :class:`~repro.core.peel.PendingPeel`
finalize surfaces an ``XlaRuntimeError`` one round late, a checkpoint write
is torn by a crash, or the process dies outright between rounds.  Testing
the recovery paths by monkeypatching each call site separately sprawls and
drifts; this module instead names the injection sites once —

* ``"dispatch"``      — entry of a device peel (``peel_classes_batched`` /
  ``local_threshold_peel``), before any device work is enqueued;
* ``"finalize"``      — inside ``PendingPeel.result()``, before the blocking
  device readback (a failure here poisons the handle exactly like a real
  asynchronous device error surfacing at block time);
* ``"checkpoint-write"`` — inside ``checkpoint.manager.save`` after the
  array payload is on disk but before the manifest/rename commit point;
* ``"partitioner"``   — start of each partition round, before the
  partitioner runs (the natural host-side "crash between rounds" site)
* ``"support"``       — entry of a triangle-credit fold in
  ``partitioned_support`` (per bucket, before any credit is scattered into
  the global ``sup`` — the credits are NOT idempotent, so the retry ladder
  must recompute a failed bucket from scratch rather than re-fold);
* ``"chunk-read"``    — inside ``store.ChunkedDiskStore._read_chunk``,
  before a graph chunk is read back from disk;
* ``"chunk-write"``   — inside ``store.ChunkedDiskStore._write_chunk``,
  before a graph chunk spill commits (tmp+rename, same atomicity contract
  as the checkpoint writer — a ``kill`` here is the crash-mid-spill case)
* ``"maintain"``      — start of each single-edit step inside
  ``maintain.truss_maintain``, after the previous edit's φ committed to the
  journal but before the next edit mutates the working graph (the
  crash-mid-maintenance site of DESIGN.md §16)

— and lets a test describe failures declaratively as a :class:`FaultPlan`:
*at the 2nd stage-1 dispatch of round 3, raise a device OOM, twice*.  Rules
match on the site name plus any subset of the context keys the site reports
(stage, round, level, retry, step, ...), fire deterministically, and record
what fired in ``plan.log`` so tests assert on the injection itself, not
just its fallout.

Fault kinds:

* ``"oom"``      — raise an ``XlaRuntimeError`` whose message carries
  ``RESOURCE_EXHAUSTED`` (exactly what a real device OOM surfaces);
  classified retryable by :func:`is_retryable`, so the drivers' rebuild /
  lane-split / degrade ladder engages.
* ``"error"``    — raise :class:`InjectedFault` (NOT retryable): models a
  poisoned computation / host bug; drivers must propagate it.
* ``"truncate"`` — at the checkpoint-write site only: truncate the array
  payload on disk and return, so the snapshot *commits corrupted* — the
  manifest checksum must catch it at restore time and fall back.
* ``"crash"``    — raise ``OSError`` at the site: at the checkpoint-write
  site this dies before the rename, leaving only a ``.tmp`` directory (the
  atomicity contract's crash-mid-write case).
* ``"kill"``     — ``SIGKILL`` the current process: the crash-and-resume
  subprocess smoke (no atexit, no finally blocks — the real thing).

The active plan is process-global and installed with the :func:`active`
context manager (tests) or :func:`install` (subprocess drivers).  With no
plan installed every ``check`` is a no-op costing one attribute load, so
production runs pay nothing.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import signal
from typing import Any, Dict, List, Optional

try:  # the real device-error type, so retry classification matches production
    from jaxlib.xla_extension import XlaRuntimeError
except Exception:  # pragma: no cover - jaxlib always present in this image
    class XlaRuntimeError(RuntimeError):
        """Stand-in when jaxlib is unavailable."""

# site names (any string is accepted; these are the ones the engines report)
DISPATCH = "dispatch"
FINALIZE = "finalize"
CHECKPOINT_WRITE = "checkpoint-write"
PARTITIONER = "partitioner"
SUPPORT = "support"
CHUNK_READ = "chunk-read"
CHUNK_WRITE = "chunk-write"
MAINTAIN = "maintain"

_RETRYABLE_MARKERS = ("RESOURCE_EXHAUSTED", "OUT_OF_MEMORY", "out of memory",
                      "Out of memory")


class InjectedFault(RuntimeError):
    """A deliberately injected non-retryable failure (kind="error")."""


def make_oom(site: str, ctx: Dict[str, Any]) -> BaseException:
    """An ``XlaRuntimeError`` indistinguishable (to the retry classifier)
    from a real device allocation failure."""
    msg = (f"RESOURCE_EXHAUSTED: injected device OOM at site={site!r} "
           f"ctx={ctx!r}")
    try:
        return XlaRuntimeError(msg)
    except Exception:  # pragma: no cover - XlaRuntimeError takes a message
        return RuntimeError(msg)


def is_retryable(exc: BaseException) -> bool:
    """Whether a failure is worth a rebuild-and-retry (DESIGN.md §12).

    Retryable: device resource exhaustion — an ``XlaRuntimeError`` (or any
    ``RuntimeError``) whose message carries a RESOURCE_EXHAUSTED / OOM
    marker.  Shrinking the dispatch (lane split, mesh drop, smaller rounds)
    can genuinely fix these.  Everything else — :class:`InjectedFault`,
    shape errors, poisoned ``PendingPeel`` handles — signals a logic error
    where a retry would only mask the bug, so drivers propagate it.
    """
    if isinstance(exc, InjectedFault):
        return False
    if not isinstance(exc, RuntimeError):
        return False
    text = str(exc)
    return any(marker in text for marker in _RETRYABLE_MARKERS)


@dataclasses.dataclass
class FaultRule:
    """One deterministic failure: fire ``times`` times starting at the
    ``nth`` call that matches ``site`` + ``where``.

    ``where`` is a subset match against the context keys the site reports
    (e.g. ``{"stage": 1, "round": 3}``); an empty ``where`` matches every
    call at the site.  Sites report a ``retry`` key on re-dispatches, so a
    rule with ``times > 1`` and no ``where`` constraint on ``retry`` keeps
    failing retries too — that is how tests drive the drivers down the
    whole degradation ladder.
    """

    site: str
    kind: str = "oom"               # oom | error | truncate | crash | kill
    where: Dict[str, Any] = dataclasses.field(default_factory=dict)
    nth: int = 1                    # 1-based index of the first firing match
    times: int = 1                  # how many matching calls to fail
    seen: int = 0                   # matching calls observed (internal)
    fired: int = 0                  # failures delivered (internal)

    def matches(self, site: str, ctx: Dict[str, Any]) -> bool:
        if site != self.site:
            return False
        return all(k in ctx and ctx[k] == v for k, v in self.where.items())


@dataclasses.dataclass
class FaultPlan:
    """An ordered set of :class:`FaultRule`; ``log`` records every firing
    as ``(site, kind, ctx)`` for test assertions."""

    rules: List[FaultRule]
    log: List[Dict[str, Any]] = dataclasses.field(default_factory=list)

    def check(self, site: str, ctx: Dict[str, Any]) -> None:
        for rule in self.rules:
            if not rule.matches(site, ctx):
                continue
            rule.seen += 1
            if rule.seen < rule.nth or rule.fired >= rule.times:
                continue
            rule.fired += 1
            self.log.append({"site": site, "kind": rule.kind, "ctx": dict(ctx)})
            self._deliver(rule, site, ctx)
            return  # at most one failure per call

    def _deliver(self, rule: FaultRule, site: str, ctx: Dict[str, Any]):
        if rule.kind == "oom":
            raise make_oom(site, ctx)
        if rule.kind == "error":
            raise InjectedFault(
                f"injected non-retryable fault at site={site!r} ctx={ctx!r}")
        if rule.kind == "crash":
            raise OSError(f"injected crash at site={site!r} ctx={ctx!r}")
        if rule.kind == "kill":
            os.kill(os.getpid(), signal.SIGKILL)  # no cleanup, by design
        if rule.kind == "truncate":
            path = ctx.get("path")
            if path and os.path.exists(path):
                size = os.path.getsize(path)
                with open(path, "r+b") as f:
                    f.truncate(max(size // 2, 1))
            return  # torn write: the save commits a corrupted payload
        if rule.kind not in ("oom", "error", "crash", "kill", "truncate"):
            raise ValueError(f"unknown fault kind {rule.kind!r}")


_ACTIVE: Optional[FaultPlan] = None


def install(plan: Optional[FaultPlan]) -> None:
    """Install ``plan`` process-wide (None uninstalls).  Subprocess drivers
    use this; tests prefer the :func:`active` context manager."""
    global _ACTIVE
    _ACTIVE = plan


@contextlib.contextmanager
def active(plan: FaultPlan):
    """Scoped installation: the plan is active inside the with-block only."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = prev


def check(site: str, **ctx: Any) -> None:
    """The injection site hook: no-op unless a plan is installed."""
    plan = _ACTIVE
    if plan is not None:
        plan.check(site, ctx)
