"""Chunked graph storage behind the :class:`~repro.core.graph.Graph` arrays.

The paper's headline claim is I/O-efficient decomposition of graphs that do
NOT fit in main memory (DESIGN.md §15).  The partition engines already
stream *batches* to the device; this module makes the working graph itself
non-resident: a :class:`GraphStore` maps flat keys (``"g3/edges"``,
``"g3/nbrs"``, ``"g7/tris"``) to arrays, and the packed ``Graph`` routes
every array attribute through it.  Two implementations:

* :class:`InMemoryStore` — a dict; ``get`` returns the registered array
  zero-copy, so the in-memory engines keep their exact current behavior
  and cost.  The conformance matrix runs the same drivers over both
  stores to pin φ bit-identical.
* :class:`ChunkedDiskStore` — arrays split into fixed-byte row chunks
  spilled to a directory; a background prefetch thread loads chunks ahead
  of the consumer, and a ``host_memory_budget`` (bytes) caps what the
  store keeps resident at any moment.  Chunk files are immutable and
  refcounted, so :meth:`put_filtered` — the spill side of
  ``Graph.remove_edges`` — rewrites only chunks that actually lost rows
  and *aliases* untouched ones (the chunk-wise filter of DESIGN.md §15,
  preserving the PR-2 rank-reuse discipline: a reused ``rank`` costs zero
  write I/O).  Every chunk flush rides the checkpoint writer's atomic
  tmp+rename primitive (``checkpoint.manager.atomic_file_write``) behind
  the ``"chunk-write"`` fault site, so a SIGKILL mid-spill never tears a
  committed chunk and the round journal resumes cleanly.

Residency contract: the budget bounds bytes the STORE retains (prefetched
/ cached chunks, shared with checkpoint writes through one
:class:`IoAccount`); a consumer materializing an array holds a transient
working copy sized by the round's working-set budget, exactly like a
device batch.  Chunks stream read-once: a consumed chunk leaves the cache
immediately, so the resident window is the prefetch lookahead, not the
graph.

Prefetch accounting (the counters the benchmark's ``table4disk`` row and
``OocStats`` carry): a chunk request served by a previously scheduled
asynchronous load (completed or still in flight — either way the consumer
issued no disk read) is a ``prefetch_hit``; a request that falls back to
a synchronous read at request time is a ``prefetch_miss``.
``bytes_spilled`` counts bytes actually written — aliased chunks are
free, which is what makes the chunk-wise ``remove_edges`` visible in the
benchmark row.
"""

from __future__ import annotations

import concurrent.futures
import contextlib
import dataclasses
import os
import threading
import uuid
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import faults

# counters a store folds into an OocStats (names shared with bottom_up)
_ABSORB_KEYS = ("chunk_reads", "chunk_writes", "bytes_spilled",
                "prefetch_hits", "prefetch_misses")


class StoreError(RuntimeError):
    """A graph-store invariant violation (unknown key, torn chunk, size
    mismatch between a filter mask and its source manifest)."""


@dataclasses.dataclass
class StoreStats:
    """I/O counters of one store (absorbed into ``OocStats`` per run)."""

    chunk_reads: int = 0          # chunk payloads read back from disk
    chunk_writes: int = 0         # chunk payloads written (spilled)
    bytes_spilled: int = 0        # bytes written; aliased chunks cost 0
    prefetch_hits: int = 0        # requests served by a scheduled load
    prefetch_misses: int = 0      # requests that read synchronously
    peak_resident_bytes: int = 0  # high-water mark of retained chunk bytes

    @property
    def prefetch_hit_rate(self) -> float:
        total = self.prefetch_hits + self.prefetch_misses
        return self.prefetch_hits / total if total else 1.0

    def as_dict(self) -> Dict[str, int]:
        return {f.name: int(getattr(self, f.name))
                for f in dataclasses.fields(self)}


@dataclasses.dataclass
class IoAccount:
    """One budget account shared by graph-chunk I/O and checkpoint I/O
    (DESIGN.md §15).

    ``budget_bytes`` caps concurrently *reserved* host bytes: the chunked
    store reserves a chunk's bytes while it is scheduled/retained, and the
    round journal reserves a snapshot's payload while it serializes — so a
    checkpoint in flight transparently throttles chunk prefetch instead of
    stacking on top of it.  ``None`` means unaccounted (no cap).
    Reservations made with :meth:`hold` may overshoot the budget (a
    checkpoint must always be writable); only the store's *admission*
    check (:meth:`fits`) hard-gates.
    """

    budget_bytes: Optional[int] = None
    reserved: int = 0
    peak: int = 0
    chunk_bytes_total: int = 0        # cumulative chunk reservations
    checkpoint_bytes_total: int = 0   # cumulative checkpoint reservations
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False)

    def fits(self, nbytes: int) -> bool:
        if self.budget_bytes is None:
            return True
        with self._lock:
            return self.reserved + nbytes <= self.budget_bytes

    def reserve(self, nbytes: int, kind: str = "chunk") -> None:
        with self._lock:
            self.reserved += nbytes
            self.peak = max(self.peak, self.reserved)
            if kind == "checkpoint":
                self.checkpoint_bytes_total += nbytes
            else:
                self.chunk_bytes_total += nbytes

    def release(self, nbytes: int) -> None:
        with self._lock:
            self.reserved = max(0, self.reserved - nbytes)

    @contextlib.contextmanager
    def hold(self, nbytes: int, kind: str = "checkpoint"):
        """Reserve for the duration of a block (the journal's write path)."""
        self.reserve(nbytes, kind)
        try:
            yield
        finally:
            self.release(nbytes)


class GraphStore:
    """Key -> array mapping the packed ``Graph`` spills to and reads from.

    Keys are flat strings namespaced by :meth:`graph_key`
    (``"g<N>/<array>"``); :meth:`release` drops a whole namespace.  The
    base class provides the counter plumbing and degenerate defaults
    (``put_filtered`` / ``alias`` fall back to a plain ``put``) so a
    subclass only has to implement ``put`` / ``get`` / ``release``.
    """

    def __init__(self):
        self.stats = StoreStats()
        self.io_account: Optional[IoAccount] = None
        self._graph_seq = 0
        self._absorbed: Dict[str, int] = {}

    # -- required interface -------------------------------------------------
    def put(self, key: str, arr: np.ndarray) -> None:
        raise NotImplementedError

    def get(self, key: str) -> np.ndarray:
        raise NotImplementedError

    def release(self, key: str) -> None:
        """Drop ``key`` and every key under ``key + "/"``."""
        raise NotImplementedError

    # -- optional hooks ------------------------------------------------------
    def prefetch(self, keys: Sequence[str]) -> None:
        """Hint that ``keys`` will be read soon (no-op by default)."""

    def put_filtered(self, dst: str, src: str, keep: np.ndarray,
                     arr: np.ndarray) -> None:
        """Register ``arr == get(src)[keep]`` under ``dst``; a chunked
        store reuses source chunks whose rows are all kept."""
        self.put(dst, arr)

    def alias(self, dst: str, src: str, arr: np.ndarray) -> None:
        """Register ``arr == get(src)`` under ``dst`` without a rewrite
        when the backend supports it (``rank`` reuse across rounds)."""
        self.put(dst, arr)

    def put_inserted(self, dst: str, src: str, is_new: np.ndarray,
                     arr: np.ndarray) -> None:
        """Register ``arr`` under ``dst`` where ``arr[~is_new] == get(src)``
        (the spill side of ``Graph.add_edges``); a chunked store aliases
        source chunks with no interior insertion point."""
        self.put(dst, arr)

    def get_chunks(self, key: str):
        """Yield ``get(key)`` piecewise so a consumer can bound its peak
        working set to one chunk; the base store yields the whole array."""
        arr = self.get(key)
        if len(arr):
            yield arr

    def stream_put(self, key: str, dtype, trail: Tuple[int, ...] = ()):
        """An appendable writer registering ``key`` at ``close()``; the
        base store buffers and concatenates, a chunked store flushes
        incrementally at chunk granularity (so a streaming producer never
        holds the full array)."""
        return _BufferedStreamWriter(self, key, dtype, trail)

    def close(self) -> None:
        """Release backend resources (threads, files)."""

    # -- shared plumbing -----------------------------------------------------
    def graph_key(self) -> str:
        """A fresh ``"g<N>"`` namespace for one working graph."""
        self._graph_seq += 1
        return f"g{self._graph_seq}"

    def absorb_into(self, ooc_stats) -> None:
        """Fold the counter DELTA since the last absorb into an
        ``OocStats`` — callable repeatedly (journal snapshots mid-run, the
        driver once more at the end) without double counting."""
        for name in _ABSORB_KEYS:
            cur = int(getattr(self.stats, name))
            prev = self._absorbed.get(name, 0)
            if hasattr(ooc_stats, name):
                setattr(ooc_stats, name,
                        getattr(ooc_stats, name) + (cur - prev))
            self._absorbed[name] = cur

    def __enter__(self) -> "GraphStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class InMemoryStore(GraphStore):
    """Current behavior: arrays stay host-resident, ``get`` is zero-copy.

    Exists so the store interface can be driven through the whole matrix
    (store × engine × partitioner) with no behavioral delta against the
    storeless path; every counter stays 0.
    """

    def __init__(self):
        super().__init__()
        self._data: Dict[str, np.ndarray] = {}

    def put(self, key: str, arr: np.ndarray) -> None:
        self._data[key] = np.asarray(arr)

    def get(self, key: str) -> np.ndarray:
        try:
            return self._data[key]
        except KeyError:
            raise StoreError(f"unknown store key {key!r}") from None

    def release(self, key: str) -> None:
        prefix = key + "/"
        for k in [k for k in self._data
                  if k == key or k.startswith(prefix)]:
            del self._data[k]


class _BufferedStreamWriter:
    """Base-store ``stream_put`` writer: buffer chunks, ``put`` on close."""

    def __init__(self, store: GraphStore, key: str, dtype,
                 trail: Tuple[int, ...]):
        self._store = store
        self._key = key
        self._dtype = np.dtype(dtype)
        self._trail = tuple(int(d) for d in trail)
        self._parts: List[np.ndarray] = []
        self._closed = False

    @property
    def rows(self) -> int:
        return sum(len(p) for p in self._parts)

    def append(self, arr: np.ndarray) -> None:
        part = np.asarray(arr, self._dtype).reshape((-1,) + self._trail)
        if len(part):
            self._parts.append(part)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._parts:
            arr = (self._parts[0] if len(self._parts) == 1
                   else np.concatenate(self._parts))
        else:
            arr = np.empty((0,) + self._trail, dtype=self._dtype)
        self._parts = []
        self._store.put(self._key, arr)

    def __enter__(self) -> "_BufferedStreamWriter":
        return self

    def __exit__(self, *exc) -> None:
        if exc[0] is None:
            self.close()


@dataclasses.dataclass
class _Chunk:
    """One immutable row-range of a stored array, on disk."""

    path: str
    key: str                 # owning store key (fault-injection context)
    index: int               # chunk position within the key
    rows: int
    nbytes: int


@dataclasses.dataclass
class _Manifest:
    dtype: str
    trail: Tuple[int, ...]   # trailing dims (rows, *trail)
    rows: int
    chunks: List[_Chunk]


# worker marker for a load skipped at execution time (budget full)
_SKIPPED = object()


class ChunkedDiskStore(GraphStore):
    """Edge/CSR/triangle chunks spilled to ``directory`` under a host
    residency budget, with background prefetch (DESIGN.md §15).

    ``host_memory_budget`` (bytes) caps concurrently retained chunk bytes
    through the shared :class:`IoAccount`; ``None`` removes the cap.
    ``chunk_bytes`` sizes the row chunks, ``lookahead`` is how many chunks
    the streaming reader schedules ahead of the one it is copying out.

    The directory is a scratch cache owned by this store: manifests live
    in memory, so ``__init__`` sweeps spill files (``*.bin`` / ``*.tmp``)
    left behind by a previous — possibly SIGKILLed — process.  Crash
    durability belongs to the checkpoint journal, not the store; a resumed
    run re-spills its working graph from the journaled host state.
    """

    def __init__(self, directory: str,
                 host_memory_budget: Optional[int] = None, *,
                 chunk_bytes: int = 1 << 20, lookahead: int = 4,
                 io_account: Optional[IoAccount] = None):
        super().__init__()
        if host_memory_budget is not None and host_memory_budget <= 0:
            raise ValueError(
                f"host_memory_budget must be a positive byte count, got "
                f"{host_memory_budget!r}")
        if chunk_bytes <= 0:
            raise ValueError(
                f"chunk_bytes must be a positive byte count, got "
                f"{chunk_bytes!r}")
        if lookahead <= 0:
            raise ValueError(
                f"lookahead must be a positive chunk count, got "
                f"{lookahead!r}")
        self.directory = directory
        self.chunk_bytes = int(chunk_bytes)
        self.lookahead = int(lookahead)
        self.io_account = (io_account if io_account is not None
                           else IoAccount(budget_bytes=host_memory_budget))
        os.makedirs(directory, exist_ok=True)
        for name in os.listdir(directory):
            if name.endswith(".bin") or name.endswith(".tmp"):
                with contextlib.suppress(OSError):
                    os.remove(os.path.join(directory, name))
        self._nonce = uuid.uuid4().hex[:8]
        self._file_seq = 0
        self._lock = threading.Lock()
        self._manifests: Dict[str, _Manifest] = {}
        self._file_refs: Dict[str, int] = {}
        self._futures: Dict[str, concurrent.futures.Future] = {}
        self._resident = 0       # bytes reserved for scheduled/retained chunks
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="graphstore-prefetch")

    # -- chunk I/O primitives (the registered fault sites) -------------------
    def _write_chunk(self, path: str, payload: bytes, *, key: str,
                     index: int) -> None:
        """Commit one chunk via the checkpoint writer's tmp+rename path."""
        faults.check(faults.CHUNK_WRITE, key=key, chunk=index, path=path)
        from repro.checkpoint import manager as _ckpt
        _ckpt.atomic_file_write(path, payload)
        with self._lock:
            self.stats.chunk_writes += 1
            self.stats.bytes_spilled += len(payload)

    def _read_chunk(self, chunk: _Chunk) -> bytes:
        faults.check(faults.CHUNK_READ, key=chunk.key, chunk=chunk.index,
                     path=chunk.path)
        try:
            with open(chunk.path, "rb") as f:
                data = f.read()
        except OSError as e:
            raise StoreError(
                f"chunk {chunk.index} of {chunk.key!r} unreadable "
                f"({e})") from e
        if len(data) != chunk.nbytes:
            raise StoreError(
                f"chunk {chunk.index} of {chunk.key!r} is torn: expected "
                f"{chunk.nbytes} bytes, found {len(data)}")
        with self._lock:
            self.stats.chunk_reads += 1
        return data

    # -- write side ----------------------------------------------------------
    def _next_path(self) -> str:
        self._file_seq += 1
        return os.path.join(self.directory,
                            f"{self._nonce}-{self._file_seq:08d}.bin")

    def put(self, key: str, arr: np.ndarray) -> None:
        arr = np.ascontiguousarray(arr)
        self.release(key)
        trail = tuple(int(d) for d in arr.shape[1:])
        row_bytes = int(arr.itemsize * int(np.prod(trail, dtype=np.int64)))
        rows_per = max(1, self.chunk_bytes // max(row_bytes, 1))
        chunks: List[_Chunk] = []
        for i, start in enumerate(range(0, len(arr), rows_per)):
            part = arr[start:start + rows_per]
            payload = part.tobytes()
            with self._lock:
                path = self._next_path()
            self._write_chunk(path, payload, key=key, index=i)
            chunks.append(_Chunk(path=path, key=key, index=i,
                                 rows=len(part), nbytes=len(payload)))
        with self._lock:
            for c in chunks:
                self._file_refs[c.path] = 1
            self._manifests[key] = _Manifest(
                dtype=str(arr.dtype), trail=trail, rows=len(arr),
                chunks=chunks)

    def put_filtered(self, dst: str, src: str, keep: np.ndarray,
                     arr: np.ndarray) -> None:
        """Chunk-wise filter: ``arr == get(src)[keep]``, but chunks whose
        rows are all kept become manifest aliases of the source files —
        zero write I/O for untouched row ranges (DESIGN.md §15)."""
        with self._lock:
            src_man = self._manifests.get(src)
        keep = np.asarray(keep, dtype=bool)
        if src_man is None or len(keep) != src_man.rows:
            self.put(dst, arr)
            return
        arr = np.ascontiguousarray(arr)
        kept_prefix = np.concatenate(
            [np.zeros(1, np.int64), np.cumsum(keep, dtype=np.int64)])
        if int(kept_prefix[-1]) != len(arr):
            raise StoreError(
                f"put_filtered({dst!r}): mask keeps {int(kept_prefix[-1])} "
                f"rows of {src!r} but the filtered array has {len(arr)}")
        self.release(dst)
        chunks: List[_Chunk] = []
        new_refs: List[str] = []
        off_old = 0
        off_new = 0
        idx = 0
        for c in src_man.chunks:
            kept = int(kept_prefix[off_old + c.rows] - kept_prefix[off_old])
            if kept == c.rows:
                chunks.append(_Chunk(path=c.path, key=dst, index=idx,
                                     rows=c.rows, nbytes=c.nbytes))
                new_refs.append(c.path)
                idx += 1
            elif kept > 0:
                part = arr[off_new:off_new + kept]
                payload = part.tobytes()
                with self._lock:
                    path = self._next_path()
                self._write_chunk(path, payload, key=dst, index=idx)
                chunks.append(_Chunk(path=path, key=dst, index=idx,
                                     rows=kept, nbytes=len(payload)))
                idx += 1
            off_old += c.rows
            off_new += kept
        with self._lock:
            for path in new_refs:
                self._file_refs[path] = self._file_refs.get(path, 0) + 1
            for c in chunks:
                self._file_refs.setdefault(c.path, 1)
            self._manifests[dst] = _Manifest(
                dtype=str(arr.dtype), trail=src_man.trail, rows=len(arr),
                chunks=chunks)

    def alias(self, dst: str, src: str, arr: np.ndarray) -> None:
        """Register ``dst`` as a zero-I/O view of ``src``'s chunks (the
        reused ``rank`` across ``remove_edges`` rounds)."""
        with self._lock:
            src_man = self._manifests.get(src)
        if src_man is None:
            self.put(dst, arr)
            return
        self.release(dst)
        with self._lock:
            chunks = [_Chunk(path=c.path, key=dst, index=i, rows=c.rows,
                             nbytes=c.nbytes)
                      for i, c in enumerate(src_man.chunks)]
            for c in chunks:
                self._file_refs[c.path] = self._file_refs.get(c.path, 0) + 1
            self._manifests[dst] = _Manifest(
                dtype=src_man.dtype, trail=src_man.trail, rows=src_man.rows,
                chunks=chunks)

    def put_inserted(self, dst: str, src: str, is_new: np.ndarray,
                     arr: np.ndarray) -> None:
        """Chunk-wise splice: ``arr[~is_new] == get(src)`` with new rows at
        the ``is_new`` positions.  Source chunks with no interior insertion
        are aliased (zero write I/O); inserted runs and chunks straddling a
        splice point are written fresh — the insertion mirror of
        :meth:`put_filtered` (DESIGN.md §16)."""
        with self._lock:
            src_man = self._manifests.get(src)
        is_new = np.asarray(is_new, dtype=bool)
        arr = np.ascontiguousarray(arr)
        trail = tuple(int(d) for d in arr.shape[1:])
        if (src_man is None or len(is_new) != len(arr)
                or int((~is_new).sum()) != src_man.rows
                or str(arr.dtype) != src_man.dtype
                or trail != src_man.trail):
            self.put(dst, arr)
            return
        old_pos = np.nonzero(~is_new)[0]
        row_bytes = int(arr.itemsize * int(np.prod(trail, dtype=np.int64)))
        rows_per = max(1, self.chunk_bytes // max(row_bytes, 1))
        self.release(dst)
        chunks: List[_Chunk] = []
        new_refs: List[str] = []
        state = {"idx": 0}

        def write_fresh(part: np.ndarray) -> None:
            for start in range(0, len(part), rows_per):
                piece = part[start:start + rows_per]
                payload = piece.tobytes()
                with self._lock:
                    path = self._next_path()
                self._write_chunk(path, payload, key=dst,
                                  index=state["idx"])
                chunks.append(_Chunk(path=path, key=dst,
                                     index=state["idx"], rows=len(piece),
                                     nbytes=len(payload)))
                state["idx"] += 1

        cursor = 0    # next unemitted row of arr
        off_old = 0   # rows of src consumed so far
        for c in src_man.chunks:
            lo = int(old_pos[off_old])
            hi = int(old_pos[off_old + c.rows - 1]) + 1
            if lo > cursor:
                # insertions falling strictly before this source chunk
                write_fresh(arr[cursor:lo])
            if hi - lo == c.rows:
                chunks.append(_Chunk(path=c.path, key=dst,
                                     index=state["idx"], rows=c.rows,
                                     nbytes=c.nbytes))
                new_refs.append(c.path)
                state["idx"] += 1
            else:
                write_fresh(arr[lo:hi])
            cursor = hi
            off_old += c.rows
        if cursor < len(arr):
            write_fresh(arr[cursor:])
        with self._lock:
            for path in new_refs:
                self._file_refs[path] = self._file_refs.get(path, 0) + 1
            for c in chunks:
                self._file_refs.setdefault(c.path, 1)
            self._manifests[dst] = _Manifest(
                dtype=src_man.dtype, trail=trail, rows=len(arr),
                chunks=chunks)

    # -- read side -----------------------------------------------------------
    def _schedule(self, chunks: Iterable[_Chunk]) -> None:
        """Queue background loads for chunks not yet scheduled, admitting
        only what the shared budget has room for (a skipped chunk gets
        re-offered by the streaming window once space frees)."""
        for c in chunks:
            with self._lock:
                if c.path in self._futures:
                    continue
                if not self.io_account.fits(c.nbytes):
                    continue
                self.io_account.reserve(c.nbytes, "chunk")
                self._resident += c.nbytes
                self.stats.peak_resident_bytes = max(
                    self.stats.peak_resident_bytes, self._resident)
                fut = self._pool.submit(self._load_task, c)
                self._futures[c.path] = fut

    def _load_task(self, chunk: _Chunk):
        # re-check the budget at execution time: a checkpoint hold that
        # landed after admission shrinks the window instead of overshooting
        if not self.io_account.fits(0):
            return _SKIPPED
        return self._read_chunk(chunk)

    def _acquire(self, chunk: _Chunk) -> Tuple[bytes, bool]:
        """One chunk's payload plus whether a scheduled load served it."""
        with self._lock:
            fut = self._futures.pop(chunk.path, None)
        if fut is None:
            with self._lock:
                self.stats.prefetch_misses += 1
            return self._read_chunk(chunk), False
        try:
            data = fut.result()
        finally:
            with self._lock:
                self._resident -= chunk.nbytes
            self.io_account.release(chunk.nbytes)
        if data is _SKIPPED:
            with self._lock:
                self.stats.prefetch_misses += 1
            return self._read_chunk(chunk), False
        with self._lock:
            self.stats.prefetch_hits += 1
        return data, True

    def get(self, key: str) -> np.ndarray:
        with self._lock:
            man = self._manifests.get(key)
        if man is None:
            raise StoreError(f"unknown store key {key!r}")
        dtype = np.dtype(man.dtype)
        out = np.empty((man.rows,) + man.trail, dtype=dtype)
        off = 0
        for i, c in enumerate(man.chunks):
            # streaming window: schedule the next chunks while copying this
            # one out (the background thread overlaps the disk reads)
            self._schedule(man.chunks[i + 1:i + 1 + self.lookahead])
            data, _ = self._acquire(c)
            out[off:off + c.rows] = np.frombuffer(
                data, dtype=dtype).reshape((c.rows,) + man.trail)
            off += c.rows
        return out

    def get_chunks(self, key: str):
        """The ``get`` loop, yielded per chunk: a consumer's peak working
        set is one chunk (plus the prefetch window), never the key.  The
        yielded arrays are read-only views over the chunk payloads."""
        with self._lock:
            man = self._manifests.get(key)
        if man is None:
            raise StoreError(f"unknown store key {key!r}")
        dtype = np.dtype(man.dtype)
        for i, c in enumerate(man.chunks):
            self._schedule(man.chunks[i + 1:i + 1 + self.lookahead])
            data, _ = self._acquire(c)
            yield np.frombuffer(data, dtype=dtype).reshape(
                (c.rows,) + man.trail)

    def stream_put(self, key: str, dtype, trail: Tuple[int, ...] = ()):
        """An appendable writer that flushes chunk files incrementally at
        ``chunk_bytes`` granularity, so a producer filtering one stream
        into another never holds either side whole."""
        return _ChunkStreamWriter(self, key, dtype, trail)

    def prefetch(self, keys: Sequence[str]) -> None:
        """Warm the head of each key so the next round's first reads hit
        (the rest streams through the per-``get`` lookahead window)."""
        for key in keys:
            with self._lock:
                man = self._manifests.get(key)
            if man is not None:
                self._schedule(man.chunks[:self.lookahead])

    # -- lifecycle -----------------------------------------------------------
    def release(self, key: str) -> None:
        prefix = key + "/"
        dead: List[str] = []
        with self._lock:
            names = [k for k in self._manifests
                     if k == key or k.startswith(prefix)]
            for name in names:
                man = self._manifests.pop(name)
                for c in man.chunks:
                    fut = self._futures.pop(c.path, None)
                    if fut is not None:
                        fut.cancel()
                        self._resident -= c.nbytes
                        self.io_account.release(c.nbytes)
                    self._file_refs[c.path] = \
                        self._file_refs.get(c.path, 1) - 1
                    if self._file_refs[c.path] <= 0:
                        del self._file_refs[c.path]
                        dead.append(c.path)
        for path in dead:
            with contextlib.suppress(OSError):
                os.remove(path)

    @property
    def resident_bytes(self) -> int:
        return self._resident

    def close(self) -> None:
        self._pool.shutdown(wait=True, cancel_futures=True)
        with self._lock:
            self._futures.clear()
            self._resident = 0


class _ChunkStreamWriter:
    """Chunked-store ``stream_put`` writer: appended rows are cut into
    chunk files as soon as a full chunk accumulates, and the manifest is
    registered atomically at ``close()`` — until then the key keeps its
    previous contents, so a round can stream-filter a key into its
    successor while the predecessor is still being read."""

    def __init__(self, store: ChunkedDiskStore, key: str, dtype,
                 trail: Tuple[int, ...]):
        self._store = store
        self._key = key
        self._dtype = np.dtype(dtype)
        self._trail = tuple(int(d) for d in trail)
        row_bytes = int(self._dtype.itemsize
                        * int(np.prod(self._trail, dtype=np.int64)))
        self._rows_per = max(1, store.chunk_bytes // max(row_bytes, 1))
        self._pending: List[np.ndarray] = []
        self._pending_rows = 0
        self._chunks: List[_Chunk] = []
        self._rows = 0
        self._closed = False

    @property
    def rows(self) -> int:
        return self._rows + self._pending_rows

    def append(self, arr: np.ndarray) -> None:
        part = np.ascontiguousarray(
            np.asarray(arr, self._dtype).reshape((-1,) + self._trail))
        if not len(part):
            return
        self._pending.append(part)
        self._pending_rows += len(part)
        while self._pending_rows >= self._rows_per:
            self._flush(self._rows_per)

    def _flush(self, rows: int) -> None:
        buf = (self._pending[0] if len(self._pending) == 1
               else np.concatenate(self._pending))
        part, rest = buf[:rows], buf[rows:]
        self._pending = [rest] if len(rest) else []
        self._pending_rows = len(rest)
        payload = np.ascontiguousarray(part).tobytes()
        store = self._store
        with store._lock:
            path = store._next_path()
        store._write_chunk(path, payload, key=self._key,
                           index=len(self._chunks))
        self._chunks.append(_Chunk(path=path, key=self._key,
                                   index=len(self._chunks), rows=len(part),
                                   nbytes=len(payload)))
        self._rows += len(part)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._pending_rows:
            self._flush(self._pending_rows)
        store = self._store
        store.release(self._key)
        with store._lock:
            for c in self._chunks:
                store._file_refs[c.path] = 1
            store._manifests[self._key] = _Manifest(
                dtype=str(self._dtype), trail=self._trail, rows=self._rows,
                chunks=self._chunks)

    def __enter__(self) -> "_ChunkStreamWriter":
        return self

    def __exit__(self, *exc) -> None:
        if exc[0] is None:
            self.close()
