"""Graph representation for truss decomposition.

Host-side (numpy) preprocessing produces static-shape arrays consumed by the
JAX algorithms:

* canonical edge list ``edges`` — (m, 2) int32, ``u < v``, lexicographically
  sorted, deduplicated, self-loop free.  The row index of an edge is its
  *edge id*, stable across the whole decomposition.
* degree-ordered orientation (the paper's Theorem-1 trick): rank vertices by
  ``(deg, id)``; orient every edge from its lower-rank endpoint ``a`` to the
  higher-rank endpoint ``b``.  Out-degrees are then bounded by ``O(sqrt(m))``
  for any graph, which is what gives wedge enumeration its ``O(m^1.5)`` total
  work bound — the vectorized analogue of "iterate over the lower-degree
  endpoint's neighbors".
* CSR of the oriented out-neighborhoods with rows sorted by neighbor id, so
  membership tests are vectorized binary searches instead of hash lookups
  (sorted arrays are the TPU-idiomatic replacement for the paper's hashtable).
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

Int = np.int32


def canonical_edges(edges: np.ndarray, n: Optional[int] = None) -> np.ndarray:
    """Canonicalize an edge list: undirected, simple, u < v, lex-sorted.

    Vertex ids are validated: negatives always raise, and with an explicit
    ``n`` any id >= n raises — the ``u * n + v`` dedup key below is
    injective only for ids in [0, n), so an out-of-range id would silently
    fold distinct edges together (and decode to garbage) instead of
    failing loudly.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if edges.size == 0:
        return np.zeros((0, 2), dtype=Int)
    if int(edges.min()) < 0:
        raise ValueError(
            f"edge list contains negative vertex id {int(edges.min())}")
    u = np.minimum(edges[:, 0], edges[:, 1])
    v = np.maximum(edges[:, 0], edges[:, 1])
    keep = u != v  # drop self loops
    u, v = u[keep], v[keep]
    if n is None:
        n = int(v.max()) + 1 if v.size else 0
    elif v.size and int(v.max()) >= n:
        raise ValueError(
            f"edge list references vertex id {int(v.max())} but n={n}; "
            f"vertex ids must lie in [0, n)")
    key = u * np.int64(n) + v
    key = np.unique(key)
    out = np.stack([key // n, key % n], axis=1)
    return out.astype(Int)


def degrees(n: int, edges: np.ndarray) -> np.ndarray:
    deg = np.zeros(n, dtype=Int)
    if len(edges):
        np.add.at(deg, edges[:, 0], 1)
        np.add.at(deg, edges[:, 1], 1)
    return deg


class Graph:
    """Static-shape packed graph (all arrays numpy; moved to device lazily).

    Attributes:
      n: number of vertices.
      edges: (m, 2) canonical edge list (edge id == row index).
      deg: (n,) degrees in the undirected graph.
      rank: (n,) degree-order rank of each vertex (position in (deg, id) order).
      src, dst: (m,) oriented endpoints per edge id: rank[src] < rank[dst].
      indptr: (n+1,) CSR row pointers of oriented out-adjacency.
      nbrs: (m,) concatenated out-neighbor lists, each row sorted by vertex id.
      nbr_eid: (m,) edge id of each (row_vertex, nbrs[i]) entry.
      max_out_deg: max oriented out-degree (static bound for wedge enumeration).

    With a :class:`~repro.core.store.GraphStore` attached (``store=``), the
    array attributes become *views through the store*: :meth:`spill` moves
    them out (to disk, for ``ChunkedDiskStore``) and each attribute access
    re-materializes lazily via ``store.get`` — the out-of-core round loop
    spills the working graph between rounds so the host never holds it
    whole (DESIGN.md §15).  ``store=None`` keeps today's behavior exactly:
    arrays are plain resident ndarrays and every store method is a no-op.
    """

    # the spillable payload, in spill order (scalars n/max_out_deg stay)
    _ARRAYS = ("edges", "deg", "rank", "src", "dst", "indptr", "nbrs",
               "nbr_eid")

    def __init__(self, *, n: int, edges: np.ndarray, deg: np.ndarray,
                 rank: np.ndarray, src: np.ndarray, dst: np.ndarray,
                 indptr: np.ndarray, nbrs: np.ndarray, nbr_eid: np.ndarray,
                 max_out_deg: int, store=None,
                 spill_plan: Optional[Dict[str, Tuple]] = None):
        self.n = int(n)
        self.max_out_deg = int(max_out_deg)
        self._m = len(edges)
        self._store = store
        self._key: Optional[str] = None
        self._spill_plan = spill_plan
        self._spilled: set = set()
        self._arrays: Dict[str, np.ndarray] = {
            "edges": edges, "deg": deg, "rank": rank, "src": src,
            "dst": dst, "indptr": indptr, "nbrs": nbrs, "nbr_eid": nbr_eid,
        }

    # -- store-routed array access ------------------------------------------
    def _fetch(self, name: str) -> np.ndarray:
        arr = self._arrays.get(name)
        if arr is None:
            if self._store is None or self._key is None:
                raise RuntimeError(
                    f"graph array {name!r} was dropped without a store to "
                    f"reload it from")
            arr = self._store.get(f"{self._key}/{name}")
            self._arrays[name] = arr
        return arr

    @property
    def edges(self) -> np.ndarray:
        return self._fetch("edges")

    @property
    def deg(self) -> np.ndarray:
        return self._fetch("deg")

    @property
    def rank(self) -> np.ndarray:
        return self._fetch("rank")

    @property
    def src(self) -> np.ndarray:
        return self._fetch("src")

    @property
    def dst(self) -> np.ndarray:
        return self._fetch("dst")

    @property
    def indptr(self) -> np.ndarray:
        return self._fetch("indptr")

    @property
    def nbrs(self) -> np.ndarray:
        return self._fetch("nbrs")

    @property
    def nbr_eid(self) -> np.ndarray:
        return self._fetch("nbr_eid")

    @property
    def m(self) -> int:
        return self._m

    @property
    def store(self):
        return self._store

    # -- spill lifecycle (all no-ops without a store) ------------------------
    def spill(self) -> None:
        """Move the packed arrays into the store and drop the host refs.

        A graph produced by :meth:`remove_edges` carries a *spill plan*:
        filtered arrays go through ``store.put_filtered`` (chunk-wise —
        source chunks whose rows are all kept are aliased, not rewritten)
        and the reused ``rank`` through ``store.alias`` (zero write I/O,
        the PR-2 rank-reuse discipline made visible on disk).  Arrays
        already spilled once are never rewritten — re-materialized copies
        are just dropped.
        """
        if self._store is None:
            return
        if self._key is None:
            self._key = self._store.graph_key()
        plan = self._spill_plan or {}
        for name in self._ARRAYS:
            if name in self._spilled:
                continue
            arr = self._arrays.get(name)
            if arr is None:
                continue
            dst_key = f"{self._key}/{name}"
            step = plan.get(name)
            if step is None:
                self._store.put(dst_key, arr)
            elif step[0] == "alias":
                self._store.alias(dst_key, step[1], arr)
            elif step[0] == "insert":  # ("insert", src_key, is_new_mask)
                self._store.put_inserted(dst_key, step[1], step[2], arr)
            else:  # ("filter", src_key, keep_mask)
                self._store.put_filtered(dst_key, step[1], step[2], arr)
            self._spilled.add(name)
        self._spill_plan = None
        self._arrays = {}

    def prefetch(self, names: Optional[Sequence[str]] = None) -> None:
        """Hint the store to warm this graph's arrays for the next round."""
        if self._store is None or self._key is None:
            return
        self._store.prefetch([f"{self._key}/{nm}"
                              for nm in (names or self._ARRAYS)
                              if nm in self._spilled])

    def unload(self) -> None:
        """Drop re-materialized host copies of already-spilled arrays."""
        if self._store is None:
            return
        for name in list(self._arrays):
            if name in self._spilled:
                del self._arrays[name]

    def release(self) -> None:
        """Drop this graph's chunks from the store (refcounted: chunk files
        aliased into a successor graph survive)."""
        if self._store is not None and self._key is not None:
            self._store.release(self._key)
        self._arrays = {}
        self._spilled = set()
        self._key = None

    # -- structural ops ------------------------------------------------------
    def subgraph(self, edge_mask: np.ndarray) -> "Graph":
        """Graph induced by the kept edges (vertex ids preserved)."""
        return build_graph(self.n, self.edges[edge_mask])

    def remove_edges(self, remove_mask: np.ndarray, *,
                     detach: bool = False) -> "Graph":
        """Incremental maintenance: drop the masked edges without a rebuild.

        ``build_graph`` pays a full lexsort (ranks) plus a lexsort of the
        oriented edge list (CSR) every call; the out-of-core drivers remove
        a batch of internal edges per round, so this filters instead:

        * ``rank`` is REUSED — it stays a total order, so the orientation of
          every surviving edge is unchanged and wedge enumeration remains
          correct (the forward algorithm only needs *some* fixed acyclic
          orientation).  The O(sqrt(m)) out-degree bound degrades gracefully
          as ranks go stale w.r.t. the shrunk degrees; correctness does not.
        * CSR rows are filtered in place — each row stays sorted by neighbor
          id, so membership binary searches keep working.

        Total cost O(n + m) with no sort.  Edge ids are renumbered densely;
        old id ``i`` maps to ``cumsum(keep)[i] - 1`` (order preserved, so the
        canonical lex order of ``edges`` is intact).

        Store-backed graphs hand the successor a *spill plan* (which mask
        filters which array, plus the ``rank`` alias) so its :meth:`spill`
        rewrites only the chunks the filter actually touched.
        ``detach=True`` produces a plain in-memory graph instead — for
        short-lived scoped graphs (the partition batch builder) that must
        never allocate store namespaces.
        """
        remove_mask = np.asarray(remove_mask, dtype=bool)
        if remove_mask.shape != (self.m,):
            raise ValueError(f"mask shape {remove_mask.shape} != ({self.m},)")
        keep = ~remove_mask
        new_edges = self.edges[keep]
        # old edge id -> new edge id (valid only where keep)
        new_id = np.cumsum(keep, dtype=np.int64) - 1
        deg = self.deg.copy()
        gone = self.edges[remove_mask]
        if len(gone):
            np.subtract.at(deg, gone[:, 0], 1)
            np.subtract.at(deg, gone[:, 1], 1)
        # filter CSR entries (row ownership from the old indptr)
        out_deg_old = (self.indptr[1:] - self.indptr[:-1]).astype(np.int64)
        rows = np.repeat(np.arange(self.n, dtype=np.int64), out_deg_old)
        keep_entry = keep[self.nbr_eid]
        counts = np.zeros(self.n + 1, dtype=np.int64)
        if keep_entry.any():
            np.add.at(counts, rows[keep_entry] + 1, 1)
        indptr = np.cumsum(counts).astype(Int)
        out_deg = indptr[1:] - indptr[:-1]
        store = None if detach else self._store
        plan = None
        if store is not None and self._key is not None:
            plan = {
                "edges": ("filter", f"{self._key}/edges", keep),
                "src": ("filter", f"{self._key}/src", keep),
                "dst": ("filter", f"{self._key}/dst", keep),
                "nbrs": ("filter", f"{self._key}/nbrs", keep_entry),
                "rank": ("alias", f"{self._key}/rank"),
                # deg / indptr / nbr_eid are recomputed, not filtered: they
                # take plain puts (no plan entry)
            }
        return Graph(
            n=self.n, edges=new_edges, deg=deg, rank=self.rank,
            src=self.src[keep], dst=self.dst[keep], indptr=indptr,
            nbrs=self.nbrs[keep_entry],
            nbr_eid=new_id[self.nbr_eid[keep_entry]].astype(Int),
            max_out_deg=int(out_deg.max()) if self.n and len(new_edges) else 0,
            store=store, spill_plan=plan,
        )

    def add_edges(self, new_edges: np.ndarray, *,
                  detach: bool = False) -> "Graph":
        """Incremental maintenance: splice new edges in without a rebuild.

        The mirror image of :meth:`remove_edges`, under the same
        rank-reuse / no-lexsort discipline (DESIGN.md §16):

        * ``rank`` is REUSED — it stays a total order over the fixed
          vertex set, so every existing edge keeps its orientation and the
          inserted edges are oriented by the same ranks (the forward
          algorithm only needs *some* fixed acyclic orientation).  Ranks
          go stale w.r.t. the grown degrees; the O(sqrt(m)) out-degree
          bound degrades gracefully, correctness does not.
        * the canonical lex order of ``edges`` is preserved by a
          searchsorted SPLICE: old edge id ``i`` maps to ``i + (#inserted
          keys < key_i)`` and the inserted edges take the gap ids — the m
          existing edges are never re-sorted.  Each CSR row absorbs its
          new entries the same way (a merge of two sorted runs keyed by
          ``row * n + nbr``); only the k inserted entries are ever sorted.

        Inserted pairs are canonicalized against ``self.n`` (self loops,
        duplicates and edges already present are dropped); when nothing
        remains, ``self`` is returned unchanged.  Total cost O(n + m +
        k log k) with no sort of existing data.

        Store-backed graphs hand the successor an *insertion-preserving*
        spill plan (``store.put_inserted``): source chunks with no
        interior splice point are aliased, so a small edit batch costs
        write I/O proportional to the chunks it touches, not the graph
        (the insertion side of the chunk-wise ``remove_edges`` filter).
        ``detach=True`` produces a plain in-memory graph instead.
        """
        ins = canonical_edges(new_edges, self.n)
        if len(ins):
            ins = ins[edge_id_lookup(self, ins[:, 0], ins[:, 1]) < 0]
        if len(ins) == 0:
            return self
        n, m, k = self.n, self.m, len(ins)
        old_keys = (self.edges[:, 0].astype(np.int64) * np.int64(n)
                    + self.edges[:, 1])
        ins_keys = ins[:, 0].astype(np.int64) * np.int64(n) + ins[:, 1]
        # splice position of each inserted edge within the OLD edge list;
        # old id i shifts by the number of inserted keys before it and
        # inserted edge j lands at pos[j] + j (keys are unique, pos sorted)
        pos = np.searchsorted(old_keys, ins_keys)
        shift = np.searchsorted(ins_keys, old_keys)
        new_id_old = np.arange(m, dtype=np.int64) + shift
        new_id_ins = pos.astype(np.int64) + np.arange(k, dtype=np.int64)
        edges = np.insert(self.edges, pos, ins, axis=0)
        is_new = np.zeros(m + k, dtype=bool)
        is_new[new_id_ins] = True
        deg = self.deg.copy()
        np.add.at(deg, ins[:, 0], 1)
        np.add.at(deg, ins[:, 1], 1)
        rank = self.rank
        u_first = rank[ins[:, 0]] < rank[ins[:, 1]]
        ins_src = np.where(u_first, ins[:, 0], ins[:, 1]).astype(Int)
        ins_dst = np.where(u_first, ins[:, 1], ins[:, 0]).astype(Int)
        src = np.insert(self.src, pos, ins_src)
        dst = np.insert(self.dst, pos, ins_dst)
        # CSR merge: the existing entries are already sorted by the
        # composite key row * n + nbr (rows ascending, each row sorted by
        # neighbor id); sort just the k new entries and splice them at
        # their searchsorted positions
        out_deg_old = (self.indptr[1:] - self.indptr[:-1]).astype(np.int64)
        rows_old = np.repeat(np.arange(n, dtype=np.int64), out_deg_old)
        key_old = rows_old * np.int64(n) + self.nbrs
        order = np.lexsort((ins_dst, ins_src))
        e_src, e_dst = ins_src[order], ins_dst[order]
        e_eid = new_id_ins[order]
        key_new = e_src.astype(np.int64) * np.int64(n) + e_dst
        cpos = np.searchsorted(key_old, key_new)
        nbrs = np.insert(self.nbrs, cpos, e_dst)
        nbr_eid = np.insert(new_id_old[self.nbr_eid], cpos,
                            e_eid).astype(Int)
        is_new_entry = np.zeros(len(nbrs), dtype=bool)
        is_new_entry[cpos + np.arange(k, dtype=np.int64)] = True
        counts = np.zeros(n + 1, dtype=np.int64)
        counts[1:] = out_deg_old + np.bincount(
            e_src.astype(np.int64), minlength=n)
        indptr = np.cumsum(counts).astype(Int)
        out_deg = indptr[1:] - indptr[:-1]
        store = None if detach else self._store
        plan = None
        if store is not None and self._key is not None:
            plan = {
                "edges": ("insert", f"{self._key}/edges", is_new),
                "src": ("insert", f"{self._key}/src", is_new),
                "dst": ("insert", f"{self._key}/dst", is_new),
                "nbrs": ("insert", f"{self._key}/nbrs", is_new_entry),
                "rank": ("alias", f"{self._key}/rank"),
                # deg / indptr / nbr_eid are recomputed, not spliced: they
                # take plain puts (no plan entry)
            }
        return Graph(
            n=n, edges=edges.astype(Int), deg=deg, rank=rank, src=src,
            dst=dst, indptr=indptr, nbrs=nbrs, nbr_eid=nbr_eid,
            max_out_deg=int(out_deg.max()) if n and len(edges) else 0,
            store=store, spill_plan=plan,
        )


def build_graph(n: int, edges: np.ndarray, store=None) -> Graph:
    """Build the oriented CSR package from a canonical edge list.

    ``store`` attaches a :class:`~repro.core.store.GraphStore`; the graph
    stays fully resident until its first :meth:`Graph.spill`.
    """
    edges = canonical_edges(edges, n)
    m = len(edges)
    deg = degrees(n, edges)
    # rank by (deg, id): stable and total.
    order = np.lexsort((np.arange(n), deg))  # vertices sorted by (deg, id)
    rank = np.empty(n, dtype=Int)
    rank[order] = np.arange(n, dtype=Int)
    if m == 0:
        return Graph(
            n=n, edges=edges, deg=deg, rank=rank,
            src=np.zeros(0, Int), dst=np.zeros(0, Int),
            indptr=np.zeros(n + 1, Int), nbrs=np.zeros(0, Int),
            nbr_eid=np.zeros(0, Int), max_out_deg=0, store=store,
        )
    u, v = edges[:, 0], edges[:, 1]
    u_first = rank[u] < rank[v]
    src = np.where(u_first, u, v).astype(Int)
    dst = np.where(u_first, v, u).astype(Int)
    # CSR over (src -> dst), rows sorted by dst id for binary search.
    order = np.lexsort((dst, src))
    rows = src[order]
    nbrs = dst[order]
    nbr_eid = np.arange(m, dtype=Int)[order]
    indptr = np.zeros(n + 1, dtype=Int)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr, dtype=np.int64).astype(Int)
    out_deg = indptr[1:] - indptr[:-1]
    return Graph(
        n=n, edges=edges, deg=deg, rank=rank, src=src, dst=dst,
        indptr=indptr, nbrs=nbrs, nbr_eid=nbr_eid,
        max_out_deg=int(out_deg.max()) if n else 0, store=store,
    )


def edge_id_lookup(graph: Graph, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Edge ids for vertex pairs (a, b); -1 if absent.  Host-side helper."""
    u = np.minimum(a, b).astype(np.int64)
    v = np.maximum(a, b).astype(np.int64)
    key = u * np.int64(graph.n) + v
    ekey = graph.edges[:, 0].astype(np.int64) * np.int64(graph.n) + graph.edges[:, 1]
    pos = np.searchsorted(ekey, key)
    pos = np.clip(pos, 0, len(ekey) - 1) if len(ekey) else np.zeros_like(pos)
    ok = len(ekey) > 0
    hit = ok & (ekey[pos] == key) if ok else np.zeros_like(key, dtype=bool)
    return np.where(hit, pos, -1).astype(Int)


def neighborhood_subgraph(
    graph: Graph, part_vertices: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Extract NS(P): all edges with >= 1 endpoint in P (paper Definition 4).

    Returns (edge_ids, edges, internal_mask) where ``internal_mask`` marks
    edges with *both* endpoints in P (the paper's internal edges).
    """
    in_part = np.zeros(graph.n, dtype=bool)
    in_part[part_vertices] = True
    u_in = in_part[graph.edges[:, 0]]
    v_in = in_part[graph.edges[:, 1]]
    keep = u_in | v_in
    edge_ids = np.nonzero(keep)[0].astype(Int)
    internal = (u_in & v_in)[edge_ids]
    return edge_ids, graph.edges[edge_ids], internal


def undirected_csr(graph: Graph) -> tuple[np.ndarray, np.ndarray]:
    """CSR of the full (undirected) adjacency: (indptr, nbrs).

    The packed :class:`Graph` stores only the oriented out-adjacency; BFS
    growth (the locality-aware partitioner) needs both directions.  Each
    edge contributes two entries.  Built once per partition round, so the
    grouping uses a single stable argsort on the row key — neighbor order
    within a row is unspecified (no caller relies on it).
    """
    n, m = graph.n, graph.m
    if m == 0:
        return np.zeros(n + 1, Int), np.zeros(0, Int)
    e = graph.edges
    rows = np.concatenate([e[:, 0], e[:, 1]])
    cols = np.concatenate([e[:, 1], e[:, 0]])
    cols = cols[np.argsort(rows, kind="stable")]
    indptr = np.zeros(n + 1, dtype=np.int64)
    indptr[1:] = np.cumsum(np.bincount(rows, minlength=n))
    return indptr, cols.astype(Int)


def wedge_weight(deg_a: np.ndarray, deg_b: np.ndarray) -> np.ndarray:
    """Per-pair closed-wedge weight ``max(min(deg_a, deg_b) - 1, 0)`` —
    the wedges through an (a, b) edge that could close into a triangle.
    The single formula behind the DESIGN.md §11 cost model: shared by
    :func:`closed_wedge_estimate` and the locality partitioner's
    admission gain so the accuracy counter (``OocStats.tri_est_error``)
    always validates the formula that actually steers part growth."""
    return np.maximum(np.minimum(deg_a, deg_b) - 1, 0)


def closed_wedge_estimate(graph: Graph) -> np.ndarray:
    """Per-vertex triangle-volume estimate from wedge counts, O(m).

    ``t(v) = (1/2) * Σ_{u ∈ N(v)} max(min(deg(u), deg(v)) - 1, 0)`` — each
    neighbor u contributes the wedges (v, u, ·) that *could* close into a
    triangle, capped by v's own degree (a triangle at v needs its third
    vertex adjacent to v too).  Exact on cliques (``t(v) = C(deg(v), 2)``,
    the incident triangle count) and an upper-bound-flavored estimate on
    sparse graphs; ``Σ_v t(v) / 3`` estimates the graph's triangle count.

    This is the cost model of the triangle-aware locality partitioner
    (DESIGN.md §11): the per-edge weight depends only on endpoint degrees,
    so two scatters over the edge list suffice — no CSR, no sort — which
    is what lets every partition round afford it.  Additive over vertex
    sets, so per-part triangle budgets compose; its per-run accuracy is
    measured against the actual enumeration (``OocStats.tri_est_error``).
    """
    if graph.m == 0:
        return np.zeros(graph.n, np.int64)
    deg = graph.deg.astype(np.int64)
    e = graph.edges.astype(np.int64)
    w = wedge_weight(deg[e[:, 0]], deg[e[:, 1]]).astype(np.float64)
    est = np.bincount(e[:, 0], weights=w, minlength=graph.n) \
        + np.bincount(e[:, 1], weights=w, minlength=graph.n)
    return est.astype(np.int64) // 2


def compact_index(sorted_ids: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Map global ids to part-local slots: position of ``values`` in the
    ascending ``sorted_ids``.

    Shared by the partition-batch triangle routing and the top-down
    candidate compaction — every value must be present in ``sorted_ids``
    (NS(P) contains every edge of a triangle assigned to P; a candidate
    contains every edge of a kept triangle).
    """
    return np.searchsorted(sorted_ids, values).astype(Int)


def compact_edge_list(edges: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Relabel an edge list's vertices to dense local ids.

    Returns ``(local_edges, verts)`` with ``verts[local_id]`` the original
    vertex id.  The relabeling is monotone, so a canonical (u < v,
    lex-sorted) input stays canonical and the row index of every edge is
    preserved — the property the partition-batch engine relies on to map
    local edge ids back to parent edge ids.
    """
    if len(edges) == 0:
        return np.zeros((0, 2), Int), np.zeros(0, Int)
    verts = np.unique(edges.reshape(-1))
    local = np.searchsorted(verts, edges)
    return local.astype(Int), verts.astype(Int)


def incident_vertices(edges: np.ndarray) -> np.ndarray:
    """Sorted unique vertices touched by an edge list."""
    if len(edges) == 0:
        return np.zeros(0, dtype=Int)
    return np.unique(edges.reshape(-1)).astype(Int)


# ---------------------------------------------------------------------------
# Reference statistics used by benchmarks (Table 6).
# ---------------------------------------------------------------------------

def clustering_coefficient(n: int, edges: np.ndarray) -> float:
    """Global clustering coefficient: 3 * #triangles / #wedges."""
    g = build_graph(n, edges)
    if g.m == 0:
        return 0.0
    from repro.core import support as _support  # lazy to avoid jax import here

    sup = np.asarray(_support.edge_support_np(g))
    tri3 = sup.sum()  # counts each triangle 3x
    d = g.deg.astype(np.int64)
    wedges = (d * (d - 1) // 2).sum()
    return float(tri3) / float(wedges) if wedges else 0.0
