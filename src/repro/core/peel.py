"""Bulk-synchronous truss peeling (the vectorized adaptation of Algorithm 2).

The paper's Algorithm 2 removes one minimum-support edge at a time.  On
vector hardware we peel in *rounds*: every round removes ALL alive edges with
``sup <= k-2`` simultaneously and repairs the supports of surviving edges via
triangle bookkeeping over a static triangle list (edge-id triples).  Rounds
iterate at the same k until a fixed point, then k jumps directly to
``min_alive_support + 2`` (bucket jump).  This computes exactly the same
k-classes as the serial algorithm: an edge is removed at level k iff its
support inside the current remaining subgraph is <= k-2, which is precisely
the definition of the k-class.

State is fixed-shape; the whole decomposition is one ``lax.while_loop`` —
jit-compatible and shard_map-compatible.

``peel_recompute`` is the *global-iterate* baseline standing in for the
MapReduce algorithm [16]: no incremental bookkeeping — every round recounts
all supports from scratch (the algorithmic reason TD-MR loses by orders of
magnitude in the paper's Table 4).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.core.support import edge_support_np, list_triangles_np

_BIG = jnp.int32(np.iinfo(np.int32).max // 2)


def _tri_alive(alive, tris):
    return alive[tris[:, 0]] & alive[tris[:, 1]] & alive[tris[:, 2]]


@partial(jax.jit, static_argnames=("max_k",))
def peel_classes(sup0, tris, edge_alive0, max_k=None):
    """Compute trussness phi(e) for every edge.

    Args:
      sup0: (m,) int32 initial supports (w.r.t. alive edges).
      tris: (T, 3) int32 triangle edge-id triples (may include triangles of
        dead edges; they are masked out).
      edge_alive0: (m,) bool — initial alive mask (padding / pre-removed edges
        are False).
      max_k: optional static cap: stop after classes <= max_k are emitted
        (used by the bottom-up per-k candidate peel).

    Returns:
      phi: (m,) int32 trussness; 0 for edges never alive.  If ``max_k`` is
        given, edges with trussness > max_k keep phi == 0 and stay alive in
        the returned mask.
      alive: (m,) bool — edges still alive (empty unless max_k given).
    """
    m = sup0.shape[0]
    phi0 = jnp.zeros(m, jnp.int32)
    k0 = jnp.int32(2)

    def cond(state):
        alive, sup, phi, k = state
        any_alive = jnp.any(alive)
        if max_k is None:
            return any_alive
        return any_alive & (k <= max_k)

    def body(state):
        alive, sup, phi, k = state
        rm = alive & (sup <= k - 2)
        has_rm = jnp.any(rm)

        def do_remove(_):
            alive2 = alive & ~rm
            phi2 = jnp.where(rm, k, phi)
            died = _tri_alive(alive, tris) & ~_tri_alive(alive2, tris)
            dec = jnp.zeros(m + 1, jnp.int32)
            for c in range(3):
                e = tris[:, c]
                contrib = (died & alive2[e]).astype(jnp.int32)
                dec = dec.at[e].add(contrib, mode="drop")
            return alive2, sup - dec[:m], phi2, k

        def do_jump(_):
            min_sup = jnp.min(jnp.where(alive, sup, _BIG))
            new_k = jnp.maximum(k + 1, min_sup + 2)
            return alive, sup, phi, new_k

        return jax.lax.cond(has_rm, do_remove, do_jump, operand=None)

    alive, sup, phi, k = jax.lax.while_loop(cond, body, (edge_alive0, sup0, phi0, k0))
    return phi, alive


@jax.jit
def peel_threshold(sup0, tris, alive0, removable, thresh):
    """Single-level peel: repeatedly remove removable alive edges with
    ``sup <= thresh`` (decrementing surviving supports) until fixed point.

    This is Procedure 5 (thresh = k-2, bottom-up: removed edges are the
    k-class) and Procedure 8 (thresh = k-3, top-down: SURVIVING internal
    edges are the k-class) in bulk-synchronous form.  ``removable`` masks the
    paper's internal edges — external edges are never deleted.

    Returns (alive, sup, removed_mask).
    """
    m = sup0.shape[0]

    def cond(state):
        alive, sup = state
        return jnp.any(alive & removable & (sup <= thresh))

    def body(state):
        alive, sup = state
        rm = alive & removable & (sup <= thresh)
        alive2 = alive & ~rm
        died = _tri_alive(alive, tris) & ~_tri_alive(alive2, tris)
        dec = jnp.zeros(m + 1, jnp.int32)
        for c in range(3):
            e = tris[:, c]
            contrib = (died & alive2[e]).astype(jnp.int32)
            dec = dec.at[e].add(contrib, mode="drop")
        return alive2, sup - dec[:m]

    alive, sup = jax.lax.while_loop(cond, body, (alive0, sup0))
    return alive, sup, alive0 & ~alive


@partial(jax.jit, static_argnames=("m",))
def support_from_triangles(tris, alive, m):
    """sup(e) = number of fully-alive triangles containing e."""
    ta = _tri_alive(alive, tris).astype(jnp.int32)
    sup = jnp.zeros(m + 1, jnp.int32)
    for c in range(3):
        sup = sup.at[tris[:, c]].add(ta, mode="drop")
    return sup[:m]


@jax.jit
def peel_recompute(tris, edge_alive0):
    """Global-iterate baseline (MapReduce [16] stand-in): each round recounts
    every support from scratch, removes all violating edges, repeats."""
    m = edge_alive0.shape[0]
    phi0 = jnp.zeros(m, jnp.int32)
    k0 = jnp.int32(2)

    def cond(state):
        alive, phi, k = state
        return jnp.any(alive)

    def body(state):
        alive, phi, k = state
        sup = support_from_triangles(tris, alive, m)
        rm = alive & (sup <= k - 2)
        has_rm = jnp.any(rm)
        min_sup = jnp.min(jnp.where(alive, sup, _BIG))
        new_k = jnp.where(has_rm, k, jnp.maximum(k + 1, min_sup + 2))
        phi = jnp.where(rm, k, phi)
        alive = alive & ~rm
        return alive, phi, new_k

    alive, phi, k = jax.lax.while_loop(cond, body, (edge_alive0, phi0, k0))
    return phi


def truss_decompose(n: int, edges: np.ndarray) -> np.ndarray:
    """End-to-end in-memory decomposition (host entry point).

    Preprocess on host (orientation, CSR, triangle list), peel on device.
    """
    from repro.core.graph import build_graph

    g = build_graph(n, edges)
    if g.m == 0:
        return np.zeros(0, np.int64)
    tris = list_triangles_np(g)
    sup = edge_support_np(g).astype(np.int32)
    if len(tris) == 0:
        tris = np.zeros((1, 3), np.int32)  # keep shapes non-empty
        tris[:] = g.m  # points at the drop slot
    phi, _ = peel_classes(
        jnp.asarray(sup), jnp.asarray(tris), jnp.ones(g.m, bool)
    )
    return np.asarray(phi).astype(np.int64)


def kmax_truss(n: int, edges: np.ndarray) -> tuple[int, np.ndarray]:
    """The k_max-truss (paper Section 7.4): returns (k_max, its edge list)."""
    phi = truss_decompose(n, edges)
    if len(phi) == 0:
        return 2, np.zeros((0, 2), np.int32)
    from repro.core.graph import canonical_edges

    edges = canonical_edges(edges, n)
    kmax = int(phi.max())
    return kmax, edges[phi == kmax]
