"""Bulk-synchronous truss peeling — frontier-compacted engine (DESIGN.md §3).

The paper's Algorithm 2 removes one minimum-support edge at a time.  On
vector hardware we peel in *rounds*: every round removes alive edges with
``sup <= k-2`` and repairs the supports of surviving edges via triangle
bookkeeping.  Rounds iterate at the same k until a fixed point, then k jumps
directly to ``min_alive_support + 2`` (bucket jump).  This computes exactly
the same k-classes as the serial algorithm.

The seed implementation (kept as ``peel_classes_dense`` / an O(T)-per-round
baseline) rescanned the full (T, 3) triangle list three times per round and
scattered into all m edge slots even when a round removed a handful of
edges.  The frontier engine instead:

  (a) compacts the removed-edge frontier into a fixed-capacity buffer via a
      ``cumsum``-based stream compaction (capacity ``cap_f``);
  (b) gathers ONLY the triangles incident to frontier edges through a
      precomputed edge→triangle incidence CSR (``triangle_incidence_np``);
  (c) applies support decrements with scatters sized to the gathered
      frontier (capacity ``cap_t``), not to T or m.

Large rounds are *chunked*: when a round's frontier exceeds the capacities,
only a prefix is removed and the loop re-enters at the same k — peeling is
confluent (removing any subset of sub-threshold edges and iterating reaches
the same fixed point), so the result is unchanged.  Over a whole
decomposition every incidence entry is gathered exactly once, so total
scatter work is Θ(3T) instead of Θ(rounds · 3T).  If a single edge's
incidence row overflows ``cap_t`` the kernel reports overflow and the host
wrapper doubles the capacity and resumes from the returned state (the
default ``cap_t`` already covers the largest row, so this is a safety
valve, not a steady-state path).

State is fixed-shape; each kernel invocation is one ``lax.while_loop`` —
jit-compatible, vmap-compatible (``distributed_local_truss``) and
shard_map-compatible (``peel_classes_sharded`` adds a ``pmin`` on the chunk
prefix and a ``psum`` on the decrements).

``peel_recompute`` is the *global-iterate* baseline standing in for the
MapReduce algorithm [16]: no incremental bookkeeping — every round recounts
all supports from scratch (the algorithmic reason TD-MR loses by orders of
magnitude in the paper's Table 4).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults
from repro.core.support import (_pow2_ceil, _pow4_ceil, list_triangles_np,
                                support_from_triangle_list,
                                triangle_incidence_np)

_BIG = jnp.int32(np.iinfo(np.int32).max // 2)

# stats vector layout (int32): sub-rounds, edges removed, incidence slots
# gathered, max frontier size seen in a single round
N_STATS = 4
_S_ROUNDS, _S_REMOVED, _S_GATHERED, _S_MAXF = range(N_STATS)


def _tri_alive(alive, tris):
    return alive[tris[:, 0]] & alive[tris[:, 1]] & alive[tris[:, 2]]


@dataclasses.dataclass
class PeelStats:
    """Work counters of one frontier-peel invocation (DESIGN.md §3).

    ``gathered`` is the total number of incidence slots touched by scatter/
    gather work across all rounds — for a full decomposition it equals the
    incidence size (3T): each (edge, triangle) pair is processed exactly once,
    in the round its edge is removed.  The dense engine's equivalent would be
    ``rounds * 3T``.
    """

    rounds: int          # sub-rounds executed (incl. frontier chunks)
    removed: int         # edges removed
    gathered: int        # incidence slots gathered (frontier-sized work)
    max_frontier: int    # largest single-round frontier
    cap_f: int           # frontier buffer capacity used
    cap_t: int           # triangle gather capacity used
    resumes: int         # host capacity-doubling fallbacks taken

    @classmethod
    def from_vec(cls, vec, cap_f, cap_t, resumes):
        vec = np.asarray(vec)
        return cls(int(vec[_S_ROUNDS]), int(vec[_S_REMOVED]),
                   int(vec[_S_GATHERED]), int(vec[_S_MAXF]),
                   cap_f, cap_t, resumes)


# ---------------------------------------------------------------------------
# the frontier round primitive
# ---------------------------------------------------------------------------

def _frontier_round(alive, sup, rm, tris, tri_indptr, tri_ids,
                    *, cap_f: int, cap_t: int, axis: Optional[str] = None):
    """One compacted removal step: remove a prefix of ``rm``, repair ``sup``.

    Returns (alive2, sup2, rm_sub, nf, j_take, total_t, overflow) where
    ``rm_sub`` is the subset of ``rm`` actually removed this step (a prefix
    of the frontier in edge-id order; confluence of peeling makes any subset
    valid), ``nf`` the full frontier size, ``j_take`` the number of edges
    taken, ``total_t`` the incidence slots gathered.  ``overflow`` is set
    when the frontier is non-empty but not even one edge's incidence row
    fits in ``cap_t``.

    ``axis``: inside shard_map, the mesh axis holding the triangle shards —
    the taken prefix is agreed via ``pmin`` and decrements merged via
    ``psum`` so replicated edge state stays consistent.
    """
    m = alive.shape[0]
    rm_i = rm.astype(jnp.int32)
    nf = jnp.sum(rm_i)
    idx = jnp.cumsum(rm_i) - 1               # frontier position per edge
    cand = rm & (idx < cap_f)
    tgt = jnp.where(cand, idx, cap_f)        # cap_f = dump slot
    f_ids = jnp.full(cap_f + 1, m, jnp.int32).at[tgt].set(
        jnp.arange(m, dtype=jnp.int32), mode="drop")[:cap_f]
    fc = jnp.minimum(f_ids, m - 1)
    lens = jnp.where(f_ids < m, tri_indptr[fc + 1] - tri_indptr[fc], 0)
    offs = jnp.cumsum(lens)                  # inclusive prefix sums
    fits = (offs <= cap_t) & (f_ids < m)     # prefix mask (lens >= 0)
    j_take = jnp.sum(fits.astype(jnp.int32))
    if axis is not None:
        j_take = jax.lax.pmin(j_take, axis)
    overflow = (nf > 0) & (j_take == 0)
    total_t = jnp.where(j_take > 0, offs[jnp.maximum(j_take - 1, 0)], 0)
    rm_sub = rm & (idx < j_take)
    alive2 = alive & ~rm_sub

    # gather the incident triangles of the taken prefix (ragged -> flat)
    s = jnp.arange(cap_t, dtype=jnp.int32)
    j = jnp.searchsorted(offs, s, side="right").astype(jnp.int32)
    jc = jnp.minimum(j, cap_f - 1)
    valid = s < total_t
    pos = s - (offs[jc] - lens[jc])
    f = f_ids[jc]                            # frontier edge owning this slot
    fcl = jnp.minimum(f, m - 1)
    slot = jnp.minimum(tri_indptr[fcl] + pos, max(tri_ids.shape[0] - 1, 0))
    tid = tri_ids[slot]
    e0 = jnp.minimum(tris[tid, 0], m - 1)
    e1 = jnp.minimum(tris[tid, 1], m - 1)
    e2 = jnp.minimum(tris[tid, 2], m - 1)
    died = alive[e0] & alive[e1] & alive[e2]
    # a triangle incident to several removed edges appears once per such
    # edge; charge it to the minimum removed edge id so it decrements its
    # survivors exactly once
    owner = jnp.minimum(
        jnp.where(rm_sub[e0], e0, _BIG),
        jnp.minimum(jnp.where(rm_sub[e1], e1, _BIG),
                    jnp.where(rm_sub[e2], e2, _BIG)))
    contribute = valid & died & (f == owner)
    dec = jnp.zeros(m + 1, jnp.int32)
    for e_c in (e0, e1, e2):
        tgt_c = jnp.where(contribute & alive2[e_c], e_c, m)
        dec = dec.at[tgt_c].add(jnp.int32(1), mode="drop")
    if axis is not None:
        dec = jax.lax.psum(dec, axis)
    return alive2, sup - dec[:m], rm_sub, nf, j_take, total_t, overflow


def _bump_stats(stats, nf, j_take, total_t):
    stats = stats.at[_S_ROUNDS].add(1)
    stats = stats.at[_S_REMOVED].add(j_take)
    stats = stats.at[_S_GATHERED].add(total_t)
    return stats.at[_S_MAXF].max(nf)


# ---------------------------------------------------------------------------
# fixed-capacity kernels (jit / vmap / shard_map compatible)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cap_f", "cap_t", "max_k", "axis"))
def peel_classes_fixedcap(sup0, tris, tri_indptr, tri_ids, alive0, phi0, k0,
                          stats0, *, cap_f, cap_t, max_k=None, axis=None):
    """Frontier peel to a fixed point (or overflow) at static capacities.

    Full state in / full state out so the host wrapper can resume after
    doubling a capacity.  Returns (alive, sup, phi, k, stats, overflow).

    ``axis`` names a mesh axis (or tuple of axes) the caller sharded the
    triangle list + incidence over: edge state is then replicated, the
    frontier prefix agreed by pmin and decrements merged by psum
    (``_frontier_round``'s sharded form) — the remove-vs-jump branch and
    the k jump depend only on the replicated edge state, so every shard
    takes the same path.  Used by the multi-axis batched peel
    (``distributed``, DESIGN.md §13), where lanes live on one mesh axis
    and each lane's triangles on another.
    """

    def cond(state):
        alive, sup, phi, k, stats, overflow = state
        ok = jnp.any(alive) & ~overflow
        if max_k is not None:
            ok &= k <= max_k
        return ok

    def body(state):
        alive, sup, phi, k, stats, overflow = state
        rm = alive & (sup <= k - 2)

        def do_remove(_):
            alive2, sup2, rm_sub, nf, j_take, total_t, ovf = _frontier_round(
                alive, sup, rm, tris, tri_indptr, tri_ids,
                cap_f=cap_f, cap_t=cap_t, axis=axis)
            phi2 = jnp.where(rm_sub, k, phi)
            return (alive2, sup2, phi2, k,
                    _bump_stats(stats, nf, j_take, total_t), ovf)

        def do_jump(_):
            min_sup = jnp.min(jnp.where(alive, sup, _BIG))
            new_k = jnp.maximum(k + 1, min_sup + 2)
            return alive, sup, phi, new_k, stats, overflow

        return jax.lax.cond(jnp.any(rm), do_remove, do_jump, operand=None)

    state0 = (alive0, sup0, phi0, k0, stats0, jnp.bool_(False))
    return jax.lax.while_loop(cond, body, state0)


@partial(jax.jit, static_argnames=("cap_f", "cap_t"))
def peel_threshold_fixedcap(sup0, tris, tri_indptr, tri_ids, alive0,
                            removable, thresh, stats0, *, cap_f, cap_t):
    """Single-level frontier peel at static capacities.

    Returns (alive, sup, stats, overflow).
    """

    def cond(state):
        alive, sup, stats, overflow = state
        return jnp.any(alive & removable & (sup <= thresh)) & ~overflow

    def body(state):
        alive, sup, stats, overflow = state
        rm = alive & removable & (sup <= thresh)
        alive2, sup2, _, nf, j_take, total_t, ovf = _frontier_round(
            alive, sup, rm, tris, tri_indptr, tri_ids,
            cap_f=cap_f, cap_t=cap_t)
        return alive2, sup2, _bump_stats(stats, nf, j_take, total_t), ovf

    state0 = (alive0, sup0, stats0, jnp.bool_(False))
    return jax.lax.while_loop(cond, body, state0)


# ---------------------------------------------------------------------------
# host wrappers: incidence construction + capacity doubling fallback
# ---------------------------------------------------------------------------

def _default_caps(m: int, incidence, cap_f, cap_t):
    """Capacity heuristic: large rounds are chunked anyway, so capacities
    trade static per-round gather width against extra sub-rounds.  The
    m//48 and 3T//96 divisors came out of a sweep on the power-law benchmark
    graphs (BENCH_peel.json); the floor on ``cap_t`` must cover the largest
    single incidence row or progress could stall."""
    indptr, tri_ids = incidence
    max_row = int((indptr[1:] - indptr[:-1]).max()) if m else 0
    n_inc = len(tri_ids)
    if cap_f is None:
        cap_f = _pow2_ceil(min(max(m, 1), max(256, m // 48)))
    if cap_t is None:
        # auto-sizing covers the largest row up front; an explicit (too
        # small) cap_t is honored and recovered via the overflow fallback
        cap_t = max(_pow2_ceil(min(max(n_inc, 1), max(1024, n_inc // 96))),
                    _pow2_ceil(max_row))
    return cap_f, cap_t


def _prep_incidence(tris, m, incidence):
    if incidence is None:
        incidence = triangle_incidence_np(np.asarray(tris), m)
    indptr, tri_ids = incidence
    if len(tri_ids) == 0:  # keep gather shapes non-empty
        tri_ids = np.zeros(1, np.int32)
    return np.asarray(indptr), np.asarray(tri_ids)


def _pick_engine(engine: str, tris, m: int, with_stats: bool) -> str:
    """"auto" routes triangle-rich graphs (3T > m) to the frontier engine;
    when the incidence is smaller than the edge list the dense engine's
    O(T)-per-round rescans are already cheaper than any O(m) frontier mask
    work.  Stats only exist for the frontier engine, so ``with_stats``
    forces it."""
    if engine == "auto":
        if with_stats or 3 * int(np.asarray(tris).shape[0]) > m:
            return "frontier"
        return "dense"
    return engine


def peel_classes(sup0, tris, edge_alive0, max_k=None, *, incidence=None,
                 cap_f=None, cap_t=None, with_stats=False, engine="auto"):
    """Compute trussness phi(e) for every edge.

    Args:
      sup0: (m,) int32 initial supports (w.r.t. alive edges).
      tris: (T, 3) int32 triangle edge-id triples (may include triangles of
        dead edges; they are masked out).
      edge_alive0: (m,) bool — initial alive mask (padding / pre-removed edges
        are False).
      max_k: optional static cap: stop after classes <= max_k are emitted
        (used by the bottom-up per-k candidate peel).
      incidence: optional precomputed ``triangle_incidence_np(tris, m)``; pass
        it when peeling the same triangle list repeatedly.
      cap_f, cap_t: frontier / triangle-gather capacities (power-of-two
        recommended to bound recompiles); sized automatically when None.
      with_stats: also return a :class:`PeelStats` ("auto" then picks the
        frontier engine; an explicit engine="dense" returns stats=None —
        the dense baseline has no frontier counters).
      engine: "auto" (default), "frontier", or "dense" (see ``_pick_engine``).

    Returns:
      (phi, alive) — or (phi, alive, stats) with ``with_stats=True``.  phi is
      (m,) int32 trussness, 0 for edges never alive; if ``max_k`` is given,
      edges with trussness > max_k keep phi == 0 and stay alive in the
      returned mask.
    """
    m = int(sup0.shape[0])
    if _pick_engine(engine, tris, m, with_stats) == "dense":
        phi, alive = peel_classes_dense(
            jnp.asarray(sup0), jnp.asarray(tris), jnp.asarray(edge_alive0),
            max_k=max_k)
        # the dense baseline has no frontier counters (explicit engine="dense")
        return (phi, alive, None) if with_stats else (phi, alive)
    indptr, tri_ids = _prep_incidence(tris, m, incidence)
    cap_f, cap_t = _default_caps(m, (indptr, tri_ids), cap_f, cap_t)
    tris_j = jnp.asarray(tris)
    indptr_j = jnp.asarray(indptr)
    tids_j = jnp.asarray(tri_ids)
    alive = jnp.asarray(edge_alive0)
    sup = jnp.asarray(sup0)
    phi = jnp.zeros(m, jnp.int32)
    k = jnp.int32(2)
    stats = jnp.zeros(N_STATS, jnp.int32)
    resumes = 0
    while True:
        # trusscheck: allow[TRK104] -- loop-carried arrays keep their (m,)/(T,3) shapes; only cap_t changes, and that retrace IS the deliberate capacity-resume (at most log2 resumes)
        alive, sup, phi, k, stats, overflow = peel_classes_fixedcap(
            sup, tris_j, indptr_j, tids_j, alive, phi, k, stats,
            cap_f=cap_f, cap_t=cap_t, max_k=max_k)
        # trusscheck: allow[TRK105] -- capacity-resume: the host must read the overflow flag to decide the recompile-at-2x resume (one sync per resume, not per round)
        if not bool(overflow):
            break
        cap_t *= 2          # host fallback: double and resume
        resumes += 1
    if with_stats:
        return phi, alive, PeelStats.from_vec(stats, cap_f, cap_t, resumes)
    return phi, alive


def peel_threshold(sup0, tris, alive0, removable, thresh, *, incidence=None,
                   cap_f=None, cap_t=None, with_stats=False, engine="auto"):
    """Single-level peel: repeatedly remove removable alive edges with
    ``sup <= thresh`` (decrementing surviving supports) until fixed point.

    This is Procedure 5 (thresh = k-2, bottom-up: removed edges are the
    k-class) and Procedure 8 (thresh = k-3, top-down: SURVIVING internal
    edges are the k-class) in bulk-synchronous, frontier-compacted form.
    ``removable`` masks the paper's internal edges — external edges are never
    deleted.

    Returns (alive, sup, removed_mask) — plus a PeelStats with
    ``with_stats=True``.
    """
    m = int(sup0.shape[0])
    if _pick_engine(engine, tris, m, with_stats) == "dense":
        alive, sup, removed = peel_threshold_dense(
            jnp.asarray(sup0), jnp.asarray(tris), jnp.asarray(alive0),
            jnp.asarray(removable), jnp.int32(thresh))
        return (alive, sup, removed, None) if with_stats else \
            (alive, sup, removed)
    indptr, tri_ids = _prep_incidence(tris, m, incidence)
    cap_f, cap_t = _default_caps(m, (indptr, tri_ids), cap_f, cap_t)
    tris_j = jnp.asarray(tris)
    indptr_j = jnp.asarray(indptr)
    tids_j = jnp.asarray(tri_ids)
    alive0 = jnp.asarray(alive0)
    alive = alive0
    sup = jnp.asarray(sup0)
    removable = jnp.asarray(removable)
    thresh = jnp.int32(thresh)
    stats = jnp.zeros(N_STATS, jnp.int32)
    resumes = 0
    while True:
        # trusscheck: allow[TRK104] -- loop-carried arrays keep their (m,)/(T,3) shapes; only cap_t changes, and that retrace IS the deliberate capacity-resume (at most log2 resumes)
        alive, sup, stats, overflow = peel_threshold_fixedcap(
            sup, tris_j, indptr_j, tids_j, alive, removable, thresh, stats,
            cap_f=cap_f, cap_t=cap_t)
        # trusscheck: allow[TRK105] -- capacity-resume: the host must read the overflow flag to decide the recompile-at-2x resume (one sync per resume, not per round)
        if not bool(overflow):
            break
        cap_t *= 2
        resumes += 1
    if with_stats:
        return alive, sup, alive0 & ~alive, PeelStats.from_vec(
            stats, cap_f, cap_t, resumes)
    return alive, sup, alive0 & ~alive


# ---------------------------------------------------------------------------
# batched local peels (out-of-core engine, DESIGN.md §8, §9)
# ---------------------------------------------------------------------------

def _peel_classes_vmapped_impl(sup_b, tris_b, indptr_b, tids_b, alive_b,
                               *, cap_f, cap_t):
    """vmap of the fixed-cap frontier peel over the lanes of one bucket."""
    Em = sup_b.shape[1]

    def one(s, t, ip, ti, a):
        phi0 = jnp.zeros(Em, jnp.int32)
        st0 = jnp.zeros(N_STATS, jnp.int32)
        _, _, phi, _, st, _ = peel_classes_fixedcap(
            s, t, ip, ti, a, phi0, jnp.int32(2), st0,
            cap_f=cap_f, cap_t=cap_t)
        return phi, st

    return jax.vmap(one)(sup_b, tris_b, indptr_b, tids_b, alive_b)


# The support buffer is donated: it is rebuilt from scratch by the host
# every round and its (B, cap_e) int32 layout is exactly what the phi
# output needs, so XLA reuses it in place.  (alive is NOT donated — no
# bool output exists to absorb it, so donating it only triggers the
# unused-donation warning.)
_peel_classes_vmapped = jax.jit(
    _peel_classes_vmapped_impl, static_argnames=("cap_f", "cap_t"),
    donate_argnums=(0,))


class PendingPeel:
    """Handle to one asynchronously dispatched device peel (DESIGN.md §9).

    JAX dispatch is asynchronous: the device arrays behind this handle are
    futures, so host work done between dispatch and :meth:`result` overlaps
    the device peel — the consumer half of the drivers' double-buffered
    rounds.  ``result()`` blocks, converts to numpy, applies the host-side
    epilogue and caches the answer.  ``new_compile`` is known at dispatch
    time (shape-cache lookup), so stats never wait on the device;
    ``sharded`` records whether the dispatch spanned a mesh (DESIGN.md §10).

    The finalize handle is consumed (cleared) BEFORE it runs: the dispatch
    donated its support buffers, so a failed finalize must never be
    re-invoked — the kernel would read donated (dead) memory.  A failing
    :meth:`result` raises the original error once and poisons the handle;
    later calls raise a ``RuntimeError`` chained to that error.

    ``fault_ctx`` (optional) names this dispatch at the ``"finalize"``
    fault-injection site (DESIGN.md §12): an injected failure there lands
    inside the consume path exactly like a real asynchronous device error
    surfacing at block time, and poisons the handle the same way.
    """

    def __init__(self, finalize, new_compile: bool, sharded: bool = False,
                 fault_ctx: Optional[dict] = None):
        self._finalize = finalize
        self.new_compile = bool(new_compile)
        self.sharded = bool(sharded)
        self._fault_ctx = fault_ctx
        self._out = None
        self._error = None

    def result(self):
        if self._error is not None:
            raise RuntimeError(
                "PendingPeel finalize failed previously; the dispatch's "
                "donated buffers are gone, so it cannot be retried"
            ) from self._error
        if self._finalize is not None:
            finalize, self._finalize = self._finalize, None
            try:
                if self._fault_ctx is not None:
                    faults.check(faults.FINALIZE, **self._fault_ctx)
                self._out = finalize()
            except BaseException as e:
                self._error = e
                raise
        return self._out


def _mesh_axes(mesh_axis) -> tuple:
    """Normalize a ``mesh_axis`` knob (one axis name or a sequence of them)
    to a tuple of axis names; axes[0] is always the lane axis."""
    if isinstance(mesh_axis, str):
        return (mesh_axis,)
    return tuple(mesh_axis)


def peel_classes_batched(sup_b, tris_b, indptr_b, tids_b, alive_b,
                         *, shape_cache=None, blocking=True,
                         mesh=None, mesh_axis="data", kernel: str = "auto",
                         fault_ctx: Optional[dict] = None):
    """Local trussness of every NS lane of one bucket in ONE device call.

    Arrays are the (B, cap_e)-padded stacks a ``partition.PartBucket``
    carries; capacities are pinned to the padded lane shape (``cap_f`` =
    cap_e, ``cap_t`` = full incidence width), so the overflow/resume path is
    statically impossible and the kernel is one compile per bucket shape.
    Padded lanes start dead and exit the while loop immediately; padded edge
    slots are dead and every padding triangle points at the drop slot, so
    neither can contribute support.  The support buffer is donated to the
    kernel (the host rebuilds it from scratch every round; its layout is
    reused in place for phi — alive is not donated, no output matches it).

    ``shape_cache``: a caller-owned set of shape keys; returns whether this
    call added a new key (the driver's ``compiles`` counter).  The jit cache
    itself is process-global, so the counter reports at most the true number
    of XLA compiles.

    With ``blocking=False`` the call returns a :class:`PendingPeel`
    immediately after (asynchronous) dispatch; ``handle.result()`` yields
    ``(phi, stats)`` and ``handle.new_compile`` is available at once — the
    producer half of the double-buffered rounds (DESIGN.md §9).

    With a ``mesh``, the bucket's lane dimension is split over ``mesh_axis``
    and the peel spans the pod (``distributed.peel_classes_batched_sharded``,
    DESIGN.md §10): the lane count is padded to a multiple of the axis size
    with dead lanes, the dispatch stays asynchronous, and the handle's
    ``sharded`` flag records the routing.  ``mesh_axis`` may also be a
    TUPLE of axis names (DESIGN.md §13): lanes split over the first axis
    and each lane's triangle list + incidence over the second, so a bucket
    with few big lanes still uses the whole pod.  Triangle-free buckets
    still short-circuit on host (nothing to shard).

    ``kernel`` ("pallas" | "xla" | "auto") picks the per-lane peel engine
    for the single-process dispatch: "pallas" runs the fused
    one-call-per-round kernel (``kernels.frontier_peel``, interpreted
    off-TPU) straight off the (B, T, 3) triangle stacks — the incidence CSR
    inputs are ignored; "auto" routes by backend, VMEM budget and triangle
    density (``frontier_peel.ops.resolve_kernel``).  A ``mesh`` dispatch
    always uses the XLA shard_map engines.

    ``fault_ctx`` names this call at the ``"dispatch"`` fault-injection
    site (and its handle at ``"finalize"``, DESIGN.md §12); ``None`` (the
    default) skips both hooks.

    Returns (phi (B, cap_e) int32 ndarray, stats (B, N_STATS) ndarray,
    newly_compiled bool) when blocking.
    """
    if fault_ctx is not None:
        faults.check(faults.DISPATCH, **fault_ctx)
    cap_e = int(sup_b.shape[1])
    n_inc = int(tids_b.shape[1])
    tris_np = np.asarray(tris_b)
    if (tris_np[:, :, 0] >= cap_e).all():
        # triangle-free bucket: every alive edge has support 0 and peels
        # at k = 2 — no device work needed
        phi = np.where(np.asarray(alive_b), 2, 0).astype(np.int32)
        st = np.zeros(tris_np.shape[:1] + (N_STATS,), np.int32)
        if not blocking:
            return PendingPeel(lambda: (phi, st), False, fault_ctx=fault_ctx)
        return phi, st, False
    # frontier capacities: local decompositions peel every lane to EMPTY,
    # so total frontier throughput matters more than per-round width — the
    # divisors are a sweep over the rmat benchmark rounds (wider than the
    # _default_caps tuning for sparse single-graph peels).  cap_t covering
    # the largest incidence row of any lane makes overflow statically
    # impossible (no resume path under vmap).
    max_row = int(np.max(indptr_b[:, 1:] - indptr_b[:, :-1])) if cap_e else 0
    cap_f = _pow2_ceil(min(cap_e, max(512, cap_e // 8)))
    cap_t = max(_pow2_ceil(min(max(n_inc, 1), max(2048, n_inc // 16))),
                _pow2_ceil(max(max_row, 1)))
    if mesh is not None:
        from repro.core.distributed import peel_classes_batched_sharded
        from repro.core.partition import round_up_to_multiple

        axes = _mesh_axes(mesh_axis)
        n_lane = int(mesh.shape[axes[0]])
        B = int(sup_b.shape[0])
        # key on the PADDED lane count — that is the shape jit compiles
        # (the counter must stay <= the true number of XLA compiles)
        B_pad = round_up_to_multiple(B, n_lane)
        key = ((B_pad,) + tuple(sup_b.shape[1:]),
               (B_pad,) + tuple(tris_b.shape[1:]),
               cap_f, cap_t,
               ("mesh",) + tuple(int(mesh.shape[a]) for a in axes))
        new = shape_cache is not None and key not in shape_cache
        if shape_cache is not None:
            shape_cache.add(key)
        phi_d, st_d = peel_classes_batched_sharded(
            mesh, np.asarray(sup_b), tris_np, np.asarray(indptr_b),
            np.asarray(tids_b), np.asarray(alive_b),
            cap_f=cap_f, cap_t=cap_t, axis=mesh_axis)

        def _finish():
            # drop the lanes pad_bucket_lanes appended for the mesh split
            return np.asarray(phi_d)[:B], np.asarray(st_d)[:B]

        if not blocking:
            return PendingPeel(_finish, new, sharded=True,
                               fault_ctx=fault_ctx)
        phi, st = _finish()
        return phi, st, new
    from repro.kernels.frontier_peel import ops as frontier_ops

    if frontier_ops.resolve_kernel(kernel, cap_e,
                                   int(tris_np.shape[1])) == "pallas":
        interpret = jax.default_backend() != "tpu"
        bt = frontier_ops.resolve_tile(cap_e, int(tris_np.shape[1]),
                                       "auto", interpret)
        key = (sup_b.shape, tris_b.shape, ("pallas", bt))
        new = shape_cache is not None and key not in shape_cache
        if shape_cache is not None:
            shape_cache.add(key)
        phi_d, st_d = frontier_ops.peel_classes_fused(
            np.asarray(sup_b), tris_np, np.asarray(alive_b),
            bt=bt, interpret=interpret)
        if not blocking:
            return PendingPeel(
                lambda: (np.asarray(phi_d), np.asarray(st_d)), new,
                fault_ctx=fault_ctx)
        return np.asarray(phi_d), np.asarray(st_d), new
    key = (sup_b.shape, tris_b.shape, cap_f, cap_t)
    new = shape_cache is not None and key not in shape_cache
    if shape_cache is not None:
        shape_cache.add(key)
    phi, st = _peel_classes_vmapped(
        jnp.asarray(sup_b), jnp.asarray(tris_b), jnp.asarray(indptr_b),
        jnp.asarray(tids_b), jnp.asarray(alive_b),
        cap_f=cap_f, cap_t=cap_t)
    if not blocking:
        return PendingPeel(lambda: (np.asarray(phi), np.asarray(st)), new,
                           fault_ctx=fault_ctx)
    return np.asarray(phi), np.asarray(st), new


def local_threshold_peel(sup0, tris, removable, thresh, *, alive0=None,
                         shape_cache=None, blocking=True, mesh=None,
                         mesh_axis="data", kernel: str = "auto",
                         fault_ctx: Optional[dict] = None):
    """Single-level peel of a COMPACTED candidate subgraph on padded shapes.

    The out-of-core k-class extraction (bottom-up Procedure 5, top-down
    Procedure 8) peels one candidate subgraph per k.  Peeling it at its
    natural (dynamic) shape would recompile every k; this pads edges and
    triangles to pow4 capacities (at most 4x pad, far fewer shapes) so
    consecutive k values reuse the same compiled kernel (``thresh`` is
    traced, not static).  All ``m`` real edges start alive unless
    ``alive0`` masks some out — the stage-2 candidate pipeline
    (DESIGN.md §11) pre-builds level k+1's candidate while level k still
    peels, then kills the edges that peel removed via this mask instead of
    re-extracting: dead edges never enter the frontier, never report as
    removed, and their triangles never repair supports (the caller must
    compute ``sup0`` from fully-alive triangles only).  ``removable``
    marks the internal/tentative edges (intersected with ``alive0``).

    With ``blocking=False`` returns a :class:`PendingPeel` right after
    dispatch (``handle.result()`` -> (alive_mask, removed_mask)), so the
    caller's host work overlaps the device peel (DESIGN.md §9).

    With a ``mesh``, the padded triangle list (rows rounded up to a multiple
    of the axis size) and its per-shard incidence are sharded over
    ``mesh_axis`` and the peel runs pod-wide with replicated edge state
    (``distributed.local_threshold_peel_sharded``, DESIGN.md §10); the
    handle's ``sharded`` flag records the routing.  A TUPLE ``mesh_axis``
    shards the triangles over the flattened product of the named axes
    (pmin/psum take tuples of axis names), so one huge candidate peel
    spreads its psum volume across the whole multi-axis mesh.

    ``kernel`` ("pallas" | "xla" | "auto") picks the single-process peel
    engine: "pallas" runs the fused one-call-per-round kernel on the padded
    triangle list directly — no incidence CSR is built at all; "auto"
    routes by backend/VMEM/density (``frontier_peel.ops.resolve_kernel``).
    A ``mesh`` dispatch always uses the XLA shard_map engine.

    ``fault_ctx`` names this call at the ``"dispatch"`` fault-injection
    site (and its handle at ``"finalize"``, DESIGN.md §12); ``None`` (the
    default) skips both hooks.

    Returns (alive_mask (m,), removed_mask (m,), newly_compiled bool)
    when blocking.
    """
    if fault_ctx is not None:
        faults.check(faults.DISPATCH, **fault_ctx)
    m = int(len(sup0))
    T = int(len(tris))
    alive0 = (np.ones(m, bool) if alive0 is None
              else np.asarray(alive0, dtype=bool))
    removable = np.asarray(removable, bool) & alive0
    if T == 0:
        # no triangles: removals cascade nothing, one sweep is the fixpoint
        removed = removable & (np.asarray(sup0) <= thresh)
        alive_out = alive0 & ~removed
        if not blocking:
            return PendingPeel(lambda: (alive_out, removed), False,
                               fault_ctx=fault_ctx)
        return alive_out, removed, False
    # pow4 capacities: consecutive k levels shrink the candidate slowly, so
    # the coarser grid makes most of a run's peels share one compiled shape
    cap_e = _pow4_ceil(max(m, 1))
    cap_tri = _pow4_ceil(max(T, 1))
    if mesh is not None:
        from repro.core.distributed import local_threshold_peel_sharded
        from repro.core.partition import round_up_to_multiple

        axes = _mesh_axes(mesh_axis)
        n_dev = 1
        for a in axes:
            n_dev *= int(mesh.shape[a])
        # shape ladder (DESIGN.md §13): if an already-compiled sharded
        # shape (read back off the caller's shape_cache keys — stage-2
        # mesh keys are the int-headed 5-tuples) can hold this candidate,
        # adopt the tightest one so the dispatch is a cache hit instead of
        # a pod-wide recompile stall; the extra rows are dead padding
        # whose per-shard cost is 1/n_dev, and a candidate no entry holds
        # peels at its natural pow4 shape (adding it to the cache)
        if shape_cache is not None:
            best = None
            for k in shape_cache:
                if (len(k) == 5 and isinstance(k[0], int)
                        and k[4] == ("mesh", n_dev)
                        and k[0] >= cap_e and k[1] >= cap_tri):
                    if best is None or k[0] * k[1] < best[0] * best[1]:
                        best = k
            if best is not None:
                cap_e, cap_tri = best[0], best[1]
        # contiguous triangle shards need equal row counts per device
        cap_tri = round_up_to_multiple(cap_tri, n_dev)
    tris_p = np.full((cap_tri, 3), cap_e, np.int32)
    if T:
        tris_p[:T] = tris
    sup_p = np.zeros(cap_e, np.int32)
    sup_p[:m] = sup0
    alive_p = np.zeros(cap_e, bool)
    alive_p[:m] = alive0
    rem_p = np.zeros(cap_e, bool)
    rem_p[:m] = removable
    if mesh is not None:
        alive_dev, cap_f, cap_t = local_threshold_peel_sharded(
            mesh, sup_p, tris_p, alive_p, rem_p, thresh, axis=mesh_axis)
        key = (cap_e, cap_tri, cap_f, cap_t, ("mesh", n_dev))
        new = shape_cache is not None and key not in shape_cache
        if shape_cache is not None:
            shape_cache.add(key)

        def _finish_sharded():
            alive = np.asarray(alive_dev)[:m]
            return alive, alive0 & ~alive

        if not blocking:
            return PendingPeel(_finish_sharded, new, sharded=True,
                               fault_ctx=fault_ctx)
        alive, removed = _finish_sharded()
        return alive, removed, new
    from repro.kernels.frontier_peel import ops as frontier_ops

    if frontier_ops.resolve_kernel(kernel, cap_e, cap_tri) == "pallas":
        interpret = jax.default_backend() != "tpu"
        bt = frontier_ops.resolve_tile(cap_e, cap_tri, "auto", interpret)
        key = (cap_e, cap_tri, ("pallas", bt))
        new = shape_cache is not None and key not in shape_cache
        if shape_cache is not None:
            shape_cache.add(key)
        alive_dev = frontier_ops.peel_threshold_fused(
            sup_p, tris_p, rem_p, thresh, alive_p,
            bt=bt, interpret=interpret)

        def _finish_fused():
            alive = np.asarray(alive_dev)[:m] > 0
            return alive, alive0 & ~alive

        if not blocking:
            return PendingPeel(_finish_fused, new, fault_ctx=fault_ctx)
        alive, removed = _finish_fused()
        return alive, removed, new
    indptr, tids = triangle_incidence_np(tris_p, cap_e)
    tids_p = np.zeros(3 * cap_tri, np.int32)
    tids_p[: len(tids)] = tids
    cap_f, cap_t = _default_caps(cap_e, (indptr, tids_p), None, None)
    key = (cap_e, cap_tri, cap_f, cap_t)
    new = shape_cache is not None and key not in shape_cache
    if shape_cache is not None:
        shape_cache.add(key)
    st0 = jnp.zeros(N_STATS, jnp.int32)
    # _default_caps covers the largest incidence row, so overflow is
    # impossible and no resume loop is needed
    alive_dev, _, _, _ = peel_threshold_fixedcap(
        jnp.asarray(sup_p), jnp.asarray(tris_p), jnp.asarray(indptr),
        jnp.asarray(tids_p), jnp.asarray(alive_p), jnp.asarray(rem_p),
        jnp.int32(thresh), st0, cap_f=cap_f, cap_t=cap_t)

    def _finish():
        alive = np.asarray(alive_dev)[:m]
        return alive, alive0 & ~alive

    if not blocking:
        return PendingPeel(_finish, new, fault_ctx=fault_ctx)
    alive, removed = _finish()
    return alive, removed, new


# ---------------------------------------------------------------------------
# dense (seed) engine — O(T) scatter work per round; baseline + oracle
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("max_k",))
def peel_classes_dense(sup0, tris, edge_alive0, max_k=None):
    """Seed bulk peel: every round rescans the full triangle list.

    Kept as the before/after benchmark baseline for the frontier engine and
    as a second independent implementation for cross-checks.
    """
    m = sup0.shape[0]
    phi0 = jnp.zeros(m, jnp.int32)
    k0 = jnp.int32(2)

    def cond(state):
        alive, sup, phi, k = state
        any_alive = jnp.any(alive)
        if max_k is None:
            return any_alive
        return any_alive & (k <= max_k)

    def body(state):
        alive, sup, phi, k = state
        rm = alive & (sup <= k - 2)
        has_rm = jnp.any(rm)

        def do_remove(_):
            alive2 = alive & ~rm
            phi2 = jnp.where(rm, k, phi)
            died = _tri_alive(alive, tris) & ~_tri_alive(alive2, tris)
            dec = jnp.zeros(m + 1, jnp.int32)
            for c in range(3):
                e = tris[:, c]
                contrib = (died & alive2[e]).astype(jnp.int32)
                dec = dec.at[e].add(contrib, mode="drop")
            return alive2, sup - dec[:m], phi2, k

        def do_jump(_):
            min_sup = jnp.min(jnp.where(alive, sup, _BIG))
            new_k = jnp.maximum(k + 1, min_sup + 2)
            return alive, sup, phi, new_k

        return jax.lax.cond(has_rm, do_remove, do_jump, operand=None)

    alive, sup, phi, k = jax.lax.while_loop(cond, body, (edge_alive0, sup0, phi0, k0))
    return phi, alive


@jax.jit
def peel_threshold_dense(sup0, tris, alive0, removable, thresh):
    """Seed single-level peel (full-triangle-list rescans); baseline."""
    m = sup0.shape[0]

    def cond(state):
        alive, sup = state
        return jnp.any(alive & removable & (sup <= thresh))

    def body(state):
        alive, sup = state
        rm = alive & removable & (sup <= thresh)
        alive2 = alive & ~rm
        died = _tri_alive(alive, tris) & ~_tri_alive(alive2, tris)
        dec = jnp.zeros(m + 1, jnp.int32)
        for c in range(3):
            e = tris[:, c]
            contrib = (died & alive2[e]).astype(jnp.int32)
            dec = dec.at[e].add(contrib, mode="drop")
        return alive2, sup - dec[:m]

    alive, sup = jax.lax.while_loop(cond, body, (alive0, sup0))
    return alive, sup, alive0 & ~alive


@partial(jax.jit, static_argnames=("m",))
def support_from_triangles(tris, alive, m):
    """sup(e) = number of fully-alive triangles containing e."""
    ta = _tri_alive(alive, tris).astype(jnp.int32)
    sup = jnp.zeros(m + 1, jnp.int32)
    for c in range(3):
        sup = sup.at[tris[:, c]].add(ta, mode="drop")
    return sup[:m]


@jax.jit
def peel_recompute(tris, edge_alive0):
    """Global-iterate baseline (MapReduce [16] stand-in): each round recounts
    every support from scratch, removes all violating edges, repeats.

    Deliberately NOT frontier-compacted — its O(T)-every-round recount is the
    algorithmic property the paper's Table 4 comparison measures.
    """
    m = edge_alive0.shape[0]
    phi0 = jnp.zeros(m, jnp.int32)
    k0 = jnp.int32(2)

    def cond(state):
        alive, phi, k = state
        return jnp.any(alive)

    def body(state):
        alive, phi, k = state
        sup = support_from_triangles(tris, alive, m)
        rm = alive & (sup <= k - 2)
        has_rm = jnp.any(rm)
        min_sup = jnp.min(jnp.where(alive, sup, _BIG))
        new_k = jnp.where(has_rm, k, jnp.maximum(k + 1, min_sup + 2))
        phi = jnp.where(rm, k, phi)
        alive = alive & ~rm
        return alive, phi, new_k

    alive, phi, k = jax.lax.while_loop(cond, body, (edge_alive0, phi0, k0))
    return phi


def estimate_working_set(g) -> int:
    """In-memory peel working set, in int32 entries (dispatch heuristic).

    Edge state (alive/sup/phi/frontier ≈ 4m) plus triangle list + incidence
    (6T), with T bounded by the oriented wedge count Σ_a deg⁺(a)² — the
    quantity the enumeration actually materializes.  An upper bound: real
    triangle counts are usually far lower, so ``memory_budget`` should be
    read as "route to out-of-core once even the wedge bound doesn't fit".
    """
    out_deg = (g.indptr[1:] - g.indptr[:-1]).astype(np.int64)
    return 4 * g.m + 6 * int((out_deg * out_deg).sum())


def truss_decompose(n: int, edges: np.ndarray, *, engine: str = "auto",
                    memory_budget=None, partitioner: str = "sequential",
                    partitioner_seed: int = 0, mesh=None,
                    mesh_axis="data", mesh_axes=None,
                    kernel: str = "auto", with_stats: bool = False,
                    checkpoint_dir=None, checkpoint_every=1,
                    resume: bool = False, max_retries: int = 2,
                    store=None, host_memory_budget=None,
                    edits=None, phi0=None):
    """End-to-end decomposition — the unified host entry point.

    ``engine``:
      * "auto" (default) — in-memory frontier/dense dispatch; when
        ``memory_budget`` is given and ``estimate_working_set`` exceeds it,
        routes to the batched out-of-core bottom-up engine instead.
      * "frontier" / "dense" — force the in-memory engines (DESIGN.md §3).
      * "bottom-up" / "top-down" — force the batched out-of-core engines
        (DESIGN.md §8); the per-part NS budget is ``memory_budget`` edge
        entries (default m // 8).  ``partitioner`` picks the round splitter
        ("sequential", "random", or the locality-aware "locality" —
        DESIGN.md §9) and ``partitioner_seed`` offsets the randomized
        partitioner's per-round reseed.  A non-positive ``memory_budget``
        raises.

    ``mesh``: span each out-of-core partition round across the mesh
    (DESIGN.md §10) — bucket lanes split over ``mesh_axis``, per-k candidate
    peels triangle-sharded.  The in-memory engines are single-program and
    ignore it (``distributed.peel_classes_sharded`` is their mesh form).
    ``mesh_axes`` (a sequence of axis names) overrides ``mesh_axis`` with a
    MULTI-AXIS layout (DESIGN.md §13): bucket lanes split over the first
    axis while each lane's triangles shard over the second, and candidate
    peels spread their psum volume over the flattened product — so late
    rounds with few lanes still use the whole pod.

    ``kernel`` ("pallas" | "xla" | "auto") picks the out-of-core engines'
    per-lane peel engine (the fused Pallas round kernel vs the XLA frontier
    chain — ``peel.peel_classes_batched``); the in-memory engines have
    their own ``engine=`` dispatch and ignore it.

    ``checkpoint_dir`` enables the out-of-core engines' round journal
    (DESIGN.md §12): every ``checkpoint_every``-th completed partition
    round / class level snapshots the host-side state through
    ``checkpoint.manager.save``'s atomic tmp+rename path, and
    ``resume=True`` restores the latest intact snapshot and continues,
    producing φ bit-identical to an uninterrupted run.  ``max_retries``
    bounds the lane-split retries a device OOM gets before the engine
    degrades (mesh drop, then smaller rounds).  The in-memory engines run
    in one device call and have nothing to journal — a ``checkpoint_dir``
    that ends up routed to them warns and is ignored.
    ``checkpoint_every`` also accepts a duration string (``"30s"``) to
    gate snapshots by wall clock.

    ``store`` / ``host_memory_budget`` make the out-of-core engines'
    working graph itself non-resident (DESIGN.md §15): pass a
    :class:`~repro.core.store.GraphStore`, or just a byte budget —
    ``host_memory_budget=`` alone builds a ``ChunkedDiskStore`` in a fresh
    temp directory capping retained graph chunks at that many bytes.  φ is
    bit-identical to the in-memory run; ``OocStats`` gains the chunk I/O
    and prefetch counters.  Like ``checkpoint_dir``, both warn and are
    ignored when the run routes to an in-memory engine.  A non-positive
    ``host_memory_budget`` raises.

    ``edits`` routes the call through incremental maintenance
    (DESIGN.md §16) instead of a fresh decomposition: the pre-edit graph
    ``(n, edges)`` is decomposed (or its known trussness accepted via
    ``phi0``, indexed by the canonical pre-edit edge list) and the edit
    batch — a :class:`~repro.core.maintain.EditBatch` or ``(op, u, v)``
    sequence — is applied by :func:`~repro.core.maintain.truss_maintain`.
    The returned φ indexes the canonical POST-edit edge list, and
    ``checkpoint_dir`` / ``resume`` journal the maintenance itself (one
    snapshot per committed edit).  ``phi0`` without ``edits`` raises.

    With ``with_stats`` the second return value is a :class:`PeelStats`
    (in-memory frontier), ``None`` (dense), or an ``OocStats`` (out-of-core
    and maintenance runs).
    """
    import warnings

    from repro.core.graph import build_graph

    if memory_budget is not None and memory_budget <= 0:
        # a falsy budget must be rejected, not silently replaced by the
        # m // 8 default (a budget of 0 entries can never be honored)
        raise ValueError(
            f"memory_budget must be a positive number of working-set "
            f"entries, got {memory_budget!r}")
    if host_memory_budget is not None and host_memory_budget <= 0:
        raise ValueError(
            f"host_memory_budget must be a positive byte count, got "
            f"{host_memory_budget!r}")
    if mesh_axes is not None:
        axes = _mesh_axes(mesh_axes)
        mesh_axis = axes[0] if len(axes) == 1 else axes
    if phi0 is not None and edits is None:
        raise ValueError("phi0= is only meaningful together with edits=")
    if edits is not None:
        from repro.core.maintain import truss_maintain

        if phi0 is None:
            phi0 = truss_decompose(
                n, edges, engine=engine, memory_budget=memory_budget,
                partitioner=partitioner, partitioner_seed=partitioner_seed,
                mesh=mesh, mesh_axis=mesh_axis, kernel=kernel,
                max_retries=max_retries)
        res = truss_maintain(
            (n, np.asarray(edges)), phi0, edits, kernel=kernel, mesh=mesh,
            mesh_axis=mesh_axis, store=store,
            checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every, resume=resume)
        phi = np.asarray(res.phi, dtype=np.int64)
        return (phi, res.stats) if with_stats else phi
    g = build_graph(n, edges)
    if g.m == 0:
        phi = np.zeros(0, np.int64)
        return (phi, None) if with_stats else phi
    est = estimate_working_set(g)
    if engine == "auto" and memory_budget is not None and est > memory_budget:
        engine = "bottom-up"
    if engine in ("bottom-up", "top-down"):
        if store is None and host_memory_budget is not None:
            import tempfile

            from repro.core.store import ChunkedDiskStore

            store = ChunkedDiskStore(
                tempfile.mkdtemp(prefix="truss-store-"),
                host_memory_budget=host_memory_budget)
        if memory_budget is not None:
            # memory_budget is in working-set ENTRIES; the partitioners'
            # budget is in NS edge cost (sum of incident degrees, 2m
            # total).  Scale by the graph's entries-per-edge density so a
            # part's estimated working set fits the budget — without this
            # any budget above 2m would yield one whole-graph "partition".
            part_budget = max(64, (2 * g.m * memory_budget) // max(est, 1))
        else:
            part_budget = max(64, g.m // 8)
        if engine == "bottom-up":
            from repro.core.bottom_up import bottom_up_decompose

            res = bottom_up_decompose(n, edges, part_budget,
                                      partitioner=partitioner,
                                      partitioner_seed=partitioner_seed,
                                      mesh=mesh, mesh_axis=mesh_axis,
                                      kernel=kernel,
                                      checkpoint_dir=checkpoint_dir,
                                      checkpoint_every=checkpoint_every,
                                      resume=resume, max_retries=max_retries,
                                      store=store)
        else:
            from repro.core.top_down import top_down_decompose

            res = top_down_decompose(n, edges, budget=part_budget,
                                     partitioner=partitioner,
                                     partitioner_seed=partitioner_seed,
                                     mesh=mesh, mesh_axis=mesh_axis,
                                     kernel=kernel,
                                     checkpoint_dir=checkpoint_dir,
                                     checkpoint_every=checkpoint_every,
                                     resume=resume, max_retries=max_retries,
                                     store=store)
        phi = np.asarray(res.phi).astype(np.int64)
        return (phi, res.stats) if with_stats else phi
    if checkpoint_dir is not None:
        warnings.warn(
            "checkpoint_dir is ignored by the in-memory engines (one device "
            "call, nothing to journal); pass a memory_budget that routes to "
            "an out-of-core engine, or engine='bottom-up'/'top-down'",
            stacklevel=2)
    if store is not None or host_memory_budget is not None:
        warnings.warn(
            "store=/host_memory_budget= are ignored by the in-memory "
            "engines (the whole graph is resident by construction); pass a "
            "memory_budget that routes to an out-of-core engine, or "
            "engine='bottom-up'/'top-down'",
            stacklevel=2)
    tris = list_triangles_np(g)
    sup = support_from_triangle_list(tris, g.m).astype(np.int32)
    if len(tris) == 0:
        tris = np.full((1, 3), g.m, np.int32)  # points at the drop slot
    args = (jnp.asarray(sup), jnp.asarray(tris), jnp.ones(g.m, bool))
    if with_stats:
        phi, _, stats = peel_classes(*args, engine=engine, with_stats=True)
    else:
        phi, _ = peel_classes(*args, engine=engine)
        stats = None
    phi = np.asarray(phi).astype(np.int64)
    return (phi, stats) if with_stats else phi


def kmax_truss(n: int, edges: np.ndarray) -> tuple[int, np.ndarray]:
    """The k_max-truss (paper Section 7.4): returns (k_max, its edge list)."""
    phi = truss_decompose(n, edges)
    if len(phi) == 0:
        return 2, np.zeros((0, 2), np.int32)
    from repro.core.graph import canonical_edges

    edges = canonical_edges(edges, n)
    kmax = int(phi.max())
    return kmax, edges[phi == kmax]
