"""Pure-jnp oracle for the dense-block triangle-count kernel.

S = (A @ A) ∘ A over a dense 0/1 adjacency block: S[u, v] = number of common
neighbors of u and v if (u, v) is an edge, else 0 — i.e. sup(e) for every
edge of the block (the paper's Definition 1 in matrix form).
"""

from __future__ import annotations

import jax.numpy as jnp


def support_dense(A: jnp.ndarray) -> jnp.ndarray:
    """A: (n, n) 0/1 symmetric, zero diagonal.  Returns f32 (n, n)."""
    Af = A.astype(jnp.float32)
    return (Af @ Af) * Af


def triangle_total(S: jnp.ndarray) -> jnp.ndarray:
    """Total triangle count: each triangle hits 6 ordered edge slots."""
    return jnp.sum(S) / 6.0


def edge_support(S: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """Per-edge support gathered from the dense support matrix."""
    return S[edges[:, 0], edges[:, 1]]
