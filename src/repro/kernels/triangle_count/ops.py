"""jit'd public wrappers for the triangle-count kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.triangle_count import ref
from repro.kernels.triangle_count.kernel import (autotune_tiles,
                                                 triangle_count_kernel)


def _pad_pow(A: jnp.ndarray, multiple: int) -> jnp.ndarray:
    n = A.shape[0]
    n_pad = -(-n // multiple) * multiple
    if n_pad == n:
        return A
    out = jnp.zeros((n_pad, n_pad), A.dtype)
    return out.at[:n, :n].set(A)


def _resolve_blocks(block, n, dtype, interpret):
    """``block`` may be an int (cubic tiles), an (bm, bn, bk) tuple, or
    "auto" (tile sweep via ``autotune_tiles``)."""
    if block == "auto":
        return autotune_tiles(n, dtype, interpret=interpret)
    if isinstance(block, int):
        return block, block, block
    bm, bn, bk = block
    return bm, bn, bk


@partial(jax.jit, static_argnames=("block", "interpret", "use_kernel"))
def _dense_support_jit(A, *, block, interpret, use_kernel):
    n = A.shape[0]
    bm, bn, bk = block
    mult = max(bm, bn, bk)
    Ap = _pad_pow(A, mult) if n % mult else A
    if use_kernel:
        S = triangle_count_kernel(Ap, bm=bm, bn=bn, bk=bk, interpret=interpret)
    else:
        S = ref.support_dense(Ap)
    return S[:n, :n]


def dense_support(
    A: jnp.ndarray,
    *,
    block=256,
    interpret: bool = True,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Per-edge support matrix for a dense adjacency block.

    Pads to a tile multiple, runs the Pallas kernel (or the jnp reference
    when ``use_kernel=False``), slices back.  ``block`` accepts an int, an
    (bm, bn, bk) tuple, or "auto" for the tile sweep.
    """
    blocks = _resolve_blocks(block, A.shape[0], A.dtype, interpret)
    return _dense_support_jit(
        A, block=blocks, interpret=interpret, use_kernel=use_kernel)


def adjacency_from_edges(n: int, edges: np.ndarray, dtype=np.float32) -> np.ndarray:
    A = np.zeros((n, n), dtype)
    if len(edges):
        A[edges[:, 0], edges[:, 1]] = 1
        A[edges[:, 1], edges[:, 0]] = 1
    return A


def dense_edge_support(
    n: int, edges: np.ndarray, *, block=256, interpret: bool = True,
    use_kernel: bool = True, dtype=np.float32,
) -> np.ndarray:
    """sup(e) per canonical edge via the dense MXU path (for dense cores).

    ``use_kernel=False`` runs the jnp reference matmul — the dispatch uses it
    off-TPU where interpret-mode Pallas would defeat the point.  ``dtype``
    may be bf16: 0/1 adjacency is exact and accumulation stays f32.
    """
    A = jnp.asarray(adjacency_from_edges(n, edges, np.float32)).astype(dtype)
    S = dense_support(A, block=block, interpret=interpret, use_kernel=use_kernel)
    return np.asarray(S)[edges[:, 0], edges[:, 1]].astype(np.int64)
