"""jit'd public wrappers for the triangle-count kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.triangle_count import ref
from repro.kernels.triangle_count.kernel import triangle_count_kernel


def _pad_pow(A: jnp.ndarray, multiple: int) -> jnp.ndarray:
    n = A.shape[0]
    n_pad = -(-n // multiple) * multiple
    if n_pad == n:
        return A
    out = jnp.zeros((n_pad, n_pad), A.dtype)
    return out.at[:n, :n].set(A)


@partial(jax.jit, static_argnames=("block", "interpret", "use_kernel"))
def dense_support(
    A: jnp.ndarray,
    *,
    block: int = 256,
    interpret: bool = True,
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Per-edge support matrix for a dense adjacency block.

    Pads to a tile multiple, runs the Pallas kernel (or the jnp reference
    when ``use_kernel=False``), slices back.
    """
    n = A.shape[0]
    Ap = _pad_pow(A, block) if n % block else A
    if use_kernel:
        S = triangle_count_kernel(Ap, bm=block, bn=block, bk=block, interpret=interpret)
    else:
        S = ref.support_dense(Ap)
    return S[:n, :n]


def adjacency_from_edges(n: int, edges: np.ndarray, dtype=np.float32) -> np.ndarray:
    A = np.zeros((n, n), dtype)
    if len(edges):
        A[edges[:, 0], edges[:, 1]] = 1
        A[edges[:, 1], edges[:, 0]] = 1
    return A


def dense_edge_support(
    n: int, edges: np.ndarray, *, block: int = 256, interpret: bool = True
) -> np.ndarray:
    """sup(e) per canonical edge via the dense MXU path (for dense cores)."""
    A = jnp.asarray(adjacency_from_edges(n, edges))
    S = dense_support(A, block=block, interpret=interpret)
    return np.asarray(S)[edges[:, 0], edges[:, 1]].astype(np.int64)
