"""Pallas TPU kernel: blocked dense triangle counting S = (A @ A) ∘ A.

This is the paper's support computation (its hot spot) mapped onto the MXU
(DESIGN.md §2): the neighborhood-subgraph-fits-in-memory discipline becomes
adjacency *tiles* that fit in VMEM.  Grid (i, j, k) with the contraction k
innermost; each (i, j) output tile accumulates A[i,k] @ A[k,j] in an f32
VMEM scratch accumulator and applies the edge mask A[i,j] once on the last
k step.  All tile dims should be multiples of 128 to align with the MXU;
inputs may be bf16 (0/1 values are exact in bf16), accumulation is f32.

VMEM budget per step (see ``kernel_vmem_bytes`` and DESIGN.md §5): the
pipeliner double-buffers the three input tiles, the accumulator and output
tile are single instances — ``2*(bm*bk + bk*bn + bm*bn)*in_bytes +
2*bm*bn*4``.  With 256x256x256 f32 that is ~2 MiB, comfortably inside the
~16 MiB/core VMEM; bf16 inputs (0/1 adjacency is exact in bf16) halve the
input-tile traffic and let 512-wide k tiles fit.  ``autotune_tiles`` sweeps
the budget-feasible (bm, bn, bk) candidates and caches the fastest.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# VMEM the tile working set may claim; real VMEM is ~16 MiB/core but the
# pipeliner needs headroom for semaphores/regs, so budget conservatively.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024

DEFAULT_TILE_CANDIDATES = (
    (128, 128, 128),
    (128, 128, 256),
    (256, 128, 256),
    (256, 256, 128),
    (256, 256, 256),
    (256, 256, 512),
    (512, 256, 256),
)


def kernel_vmem_bytes(bm: int, bn: int, bk: int, in_dtype=jnp.float32) -> int:
    """Per-step VMEM working set of the blocked kernel (DESIGN.md §5).

    Double-buffered input tiles A[i,k], A[k,j], A[i,j] plus the f32
    accumulator scratch and output tile.
    """
    in_bytes = jnp.dtype(in_dtype).itemsize
    tiles_in = (bm * bk + bk * bn + bm * bn) * in_bytes * 2
    acc_out = bm * bn * 4 * 2
    return tiles_in + acc_out


def _kernel(a_ik, a_kj, a_ij, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ik[...], a_kj[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = acc_ref[...] * a_ij[...].astype(jnp.float32)


def triangle_count_kernel(
    A: jnp.ndarray,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """S = (A @ A) ∘ A.  A: (n, n) in f32 or bf16, n divisible by the tiles.

    0/1 adjacency values and their per-tile dot products are exact in bf16
    up to n = 256 per k-tile step; accumulation across k steps is always f32
    (the scratch accumulator), so bf16 inputs lose no precision for counts
    below 2^24 triangles per edge.
    """
    n = A.shape[0]
    if A.shape != (n, n):
        raise ValueError(f"adjacency must be square, got {A.shape}")
    if A.dtype not in (jnp.float32, jnp.bfloat16):
        raise TypeError(f"adjacency dtype must be f32 or bf16, got {A.dtype}")
    bm, bn, bk = (min(b, n) for b in (bm, bn, bk))
    if n % bm or n % bn or n % bk:
        raise ValueError(f"tile shapes must divide n={n}, got "
                         f"(bm, bn, bk)=({bm}, {bn}, {bk})")
    grid = (n // bm, n // bn, n // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(A, A, A)


# ---------------------------------------------------------------------------
# tile autotuning (DESIGN.md §5)
# ---------------------------------------------------------------------------

_TUNE_CACHE: dict = {}


def feasible_tiles(n: int, dtype=jnp.float32, candidates=None,
                   budget_bytes: int = VMEM_BUDGET_BYTES):
    """Candidate (bm, bn, bk) triples that divide n and fit the VMEM budget."""
    out = []
    for bm, bn, bk in (candidates or DEFAULT_TILE_CANDIDATES):
        bm, bn, bk = min(bm, n), min(bn, n), min(bk, n)
        if n % bm or n % bn or n % bk:
            continue
        if kernel_vmem_bytes(bm, bn, bk, dtype) > budget_bytes:
            continue
        if (bm, bn, bk) not in out:
            out.append((bm, bn, bk))
    return out or [(min(128, n),) * 3]


def autotune_tiles(
    n: int,
    dtype=jnp.float32,
    *,
    candidates=None,
    budget_bytes: int = VMEM_BUDGET_BYTES,
    interpret: bool = False,
    repeats: int = 2,
    seed: int = 0,
) -> tuple[int, int, int]:
    """Sweep the feasible tile shapes on a random 0/1 matrix; return the
    fastest.  Results are cached per (n, dtype, backend, interpret,
    candidates, budget)."""
    key = (n, jnp.dtype(dtype).name, jax.default_backend(), interpret,
           tuple(candidates) if candidates is not None else None,
           budget_bytes)
    if key in _TUNE_CACHE:
        return _TUNE_CACHE[key]
    rng = jax.random.PRNGKey(seed)
    A = (jax.random.uniform(rng, (n, n)) < 0.3).astype(dtype)
    best, best_t = None, float("inf")
    for tiles in feasible_tiles(n, dtype, candidates, budget_bytes):
        bm, bn, bk = tiles
        try:
            fn = jax.jit(functools.partial(
                triangle_count_kernel, bm=bm, bn=bn, bk=bk,
                interpret=interpret))
            jax.block_until_ready(fn(A))          # compile + warm up
            t0 = time.perf_counter()
            for _ in range(repeats):
                jax.block_until_ready(fn(A))
            t = (time.perf_counter() - t0) / repeats
        except Exception:                          # infeasible on this backend
            continue
        if t < best_t:
            best, best_t = tiles, t
    best = best or (min(128, n),) * 3
    _TUNE_CACHE[key] = best
    return best
