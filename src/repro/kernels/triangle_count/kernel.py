"""Pallas TPU kernel: blocked dense triangle counting S = (A @ A) ∘ A.

This is the paper's support computation (its hot spot) mapped onto the MXU
(DESIGN.md §2): the neighborhood-subgraph-fits-in-memory discipline becomes
adjacency *tiles* that fit in VMEM.  Grid (i, j, k) with the contraction k
innermost; each (i, j) output tile accumulates A[i,k] @ A[k,j] in an f32
VMEM scratch accumulator and applies the edge mask A[i,j] once on the last
k step.  All tile dims should be multiples of 128 to align with the MXU;
inputs may be bf16 (0/1 values are exact in bf16), accumulation is f32.

VMEM budget per step: bm*bk + bk*bn + 2*bm*bn tiles.  With 256x256x256 f32
that is 4 * 256KiB = 1 MiB — comfortably inside the ~16 MiB/core VMEM, and
the k-loop gives the pipeliner double-buffering room.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ik, a_kj, a_ij, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ik[...], a_kj[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _finish():
        o_ref[...] = acc_ref[...] * a_ij[...].astype(jnp.float32)


def triangle_count_kernel(
    A: jnp.ndarray,
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """S = (A @ A) ∘ A.  A: (n, n), n divisible by the tile dims."""
    n = A.shape[0]
    assert A.shape == (n, n)
    bm, bn, bk = (min(b, n) for b in (bm, bn, bk))
    assert n % bm == 0 and n % bn == 0 and n % bk == 0, (n, bm, bn, bk)
    grid = (n // bm, n // bn, n // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(A, A, A)
