"""jit'd public wrapper for the embedding-bag kernel (lane padding)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag import ref
from repro.kernels.embedding_bag.kernel import embedding_bag_kernel

_LANES = 128


@partial(jax.jit, static_argnames=("mode", "interpret", "use_kernel"))
def embedding_bag(table, idx, *, mode="mean", interpret=True, use_kernel=True):
    """Bag-reduce embedding lookup; pads the feature dim to the lane width."""
    if not use_kernel:
        return ref.embedding_bag(table, idx, mode=mode)
    V, D = table.shape
    Dp = -(-D // _LANES) * _LANES
    tbl = table if Dp == D else jnp.pad(table, ((0, 0), (0, Dp - D)))
    out = embedding_bag_kernel(tbl, idx.astype(jnp.int32), mode=mode,
                               interpret=interpret)
    return out[:, :D]
