"""Pure-jnp oracle for the embedding-bag gather-reduce.

JAX has no native EmbeddingBag; the reference is ``take`` + reduce, the
production sparse path is ``take`` + ``segment_sum`` (models/recsys), and
the Pallas kernel streams rows via scalar-prefetch indexing.
"""

from __future__ import annotations

import jax.numpy as jnp


def embedding_bag(
    table: jnp.ndarray,    # (V, D)
    idx: jnp.ndarray,      # (B, L) int32
    *,
    mode: str = "mean",
    weights: jnp.ndarray | None = None,   # (B, L) optional per-sample weights
) -> jnp.ndarray:
    rows = table[idx]                      # (B, L, D)
    if weights is not None:
        rows = rows * weights[..., None]
    if mode == "sum":
        return rows.sum(axis=1)
    if mode == "mean":
        return rows.mean(axis=1)
    raise ValueError(mode)
