"""Pallas TPU kernel: embedding-bag (ragged gather + reduce).

The recsys hot path (kernel_taxonomy §RecSys): bag lookups into a huge
embedding table.  The table stays in HBM; the kernel uses
``PrefetchScalarGridSpec`` so the grid's BlockSpec index_map reads the
*prefetched* bag indices and DMAs exactly the needed rows HBM→VMEM — the
TPU-idiomatic replacement for a gather kernel (indices are known one grid
step ahead, so the pipeliner overlaps row fetch with accumulation).

Grid (B, L): for bag b, step l accumulates table[idx[b, l]] into the (1, D)
output tile; mean bags divide on the last step.  D must be lane-aligned
(pad to 128 in ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# VMEM the tile working set may claim; real VMEM is ~16 MiB/core but the
# pipeliner needs headroom for semaphores/regs, so budget conservatively.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def kernel_vmem_bytes(D: int, in_dtype=jnp.float32) -> int:
    """Per-step VMEM working set (DESIGN.md §5): the double-buffered
    (1, D) row and output tiles plus the f32 accumulator scratch."""
    in_bytes = jnp.dtype(in_dtype).itemsize
    return 2 * (D * in_bytes + D * in_bytes) + D * 4


def _bag_kernel(idx_ref, row_ref, o_ref, acc_ref, *, mode, bag_len):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += row_ref[...].astype(jnp.float32)

    @pl.when(l == bag_len - 1)
    def _finish():
        acc = acc_ref[...]
        if mode == "mean":
            acc = acc / jnp.float32(bag_len)
        o_ref[...] = acc.astype(o_ref.dtype)


def embedding_bag_kernel(
    table: jnp.ndarray,   # (V, D), D lane-aligned
    idx: jnp.ndarray,     # (B, L) int32
    *,
    mode: str = "mean",
    interpret: bool = False,
) -> jnp.ndarray:
    if mode not in ("sum", "mean"):
        raise ValueError(f"mode must be 'sum' or 'mean', got {mode!r}")
    B, L = idx.shape
    V, D = table.shape
    need = kernel_vmem_bytes(D, table.dtype)
    if need > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"row working set {need} B exceeds the VMEM budget "
            f"{VMEM_BUDGET_BYTES} B; shard the embedding dim D={D}")
    kernel = functools.partial(_bag_kernel, mode=mode, bag_len=L)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, L),
            in_specs=[
                pl.BlockSpec((1, D), lambda b, l, idx_ref: (idx_ref[b, l], 0)),
            ],
            out_specs=pl.BlockSpec((1, D), lambda b, l, idx_ref: (b, 0)),
            scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(idx, table)
