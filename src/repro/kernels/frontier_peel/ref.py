"""Pure-jnp oracle for the fused frontier-peel kernel.

``fused_round_ref`` states the round's semantics with plain gathers and a
scatter-add (no one-hot matmuls, no tiling); ``peel_classes_ref`` runs the
whole lockstep class peel on top of it.  The parity suite checks
``kernel.fused_round`` / ``ops.peel_classes_fused`` against these, and the
conformance matrix checks both against the XLA frontier engine.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_BIG = jnp.int32(np.iinfo(np.int32).max // 2)


def _pad_drop(x):
    """Append the per-lane drop slot (id cap_e) padding triangles target."""
    B = x.shape[0]
    return jnp.concatenate([x, jnp.zeros((B, 1), x.dtype)], axis=1)


def fused_round_ref(sup, alive, rm, tris):
    """One dense removal round; same contract as ``kernel.fused_round``.

    sup/alive/rm: (B, E) int32 masks/counts; tris: (B, T, 3) int32 with
    padding rows on the drop slot E.  A triangle dies when all corners were
    alive and >= 1 was removed; each died triangle decrements each of its
    surviving corners once.
    """
    B, cap_e = sup.shape
    alive_p = _pad_drop(alive)
    rm_p = _pad_drop(rm)
    a = [jnp.take_along_axis(alive_p, tris[:, :, c], axis=1) for c in range(3)]
    r = [jnp.take_along_axis(rm_p, tris[:, :, c], axis=1) for c in range(3)]
    tri_alive = a[0] * a[1] * a[2]
    any_rm = 1 - (1 - r[0]) * (1 - r[1]) * (1 - r[2])
    died = tri_alive * any_rm                                    # (B, T)

    alive2 = alive * (1 - rm)
    alive2_p = _pad_drop(alive2)
    dec = jnp.zeros((B, cap_e + 1), jnp.int32)
    rows = jnp.arange(B)[:, None]
    for c in range(3):
        tgt = tris[:, :, c]
        contrib = died * jnp.take_along_axis(alive2_p, tgt, axis=1)
        dec = dec.at[rows, tgt].add(contrib)
    return sup - dec[:, :cap_e], alive2


def peel_classes_ref(sup0, tris, alive0):
    """Trussness of every lane via lockstep dense rounds (host loop).

    sup0/alive0: (B, E); tris: (B, T, 3).  Returns phi (B, E) int32 — the
    same fixed point as ``peel.peel_classes`` restricted to the alive mask.
    """
    sup = jnp.asarray(sup0, jnp.int32)
    alive = jnp.asarray(alive0, jnp.int32)
    tris = jnp.asarray(tris, jnp.int32)
    B, cap_e = sup.shape
    phi = jnp.zeros((B, cap_e), jnp.int32)
    k = jnp.full((B,), 2, jnp.int32)
    while bool(jnp.any(alive > 0)):
        rm = alive * (sup <= k[:, None] - 2)
        lane_alive = alive.sum(axis=1) > 0
        has_rm = rm.sum(axis=1) > 0
        min_sup = jnp.min(jnp.where(alive > 0, sup, _BIG), axis=1)
        jump = jnp.maximum(k + 1, min_sup + 2)
        k_next = jnp.where(lane_alive & ~has_rm, jump, k)
        phi = jnp.where(rm > 0, k[:, None], phi)
        sup, alive = fused_round_ref(sup, alive, rm, tris)
        k = k_next
    return phi
