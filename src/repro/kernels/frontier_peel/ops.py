"""Outer peel loops over the fused round kernel + auto-dispatch helpers.

``peel_classes_fused`` / ``peel_threshold_fused`` are the drop-in fused
counterparts of ``peel._peel_classes_vmapped`` and
``peel.peel_threshold_fixedcap``: a jit'd ``lax.while_loop`` whose body is
ONE ``pallas_call`` (the whole round) plus a handful of jnp reductions for
the k-jump glue — versus the XLA frontier engine's per-round
compact/gather/dedup/scatter dispatch chain.  The fused path needs no
edge→triangle incidence CSR at all (the kernel sweeps the triangle list
directly), so callers also skip the host-side ``triangle_incidence_np``
build.

``resolve_kernel`` is the ``kernel="auto"`` routing rule (DESIGN.md §13):
Pallas only on a TPU backend, only when a tile fits the VMEM budget, and
only when the lane is triangle-dense enough (3T >= E) for the dense sweep
to beat sparse gathers — the same backend discipline as
``support.edge_support_auto``'s dense-core kernel routing.  Off-TPU, forced
``kernel="pallas"`` runs the Pallas interpreter (the CI parity path).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.frontier_peel import kernel as fk

_BIG = jnp.int32(np.iinfo(np.int32).max // 2)

# mirrors peel.N_STATS layout (rounds, removed, gathered, max frontier);
# test_frontier_peel_kernel pins the two layouts together
N_STATS = 4
_S_ROUNDS, _S_REMOVED, _S_GATHERED, _S_MAXF = range(N_STATS)


def fused_working_set_bytes(cap_e: int, n_tris: int) -> int:
    """``estimate_working_set``-style per-round footprint of the fused path:
    the resident edge-state rows plus one streamed pass over the triangle
    list (tiles are transient, so the stream counts once)."""
    return 6 * cap_e * 4 + 3 * n_tris * 4


def resolve_kernel(kernel: str, cap_e: int, n_tris: int, *,
                   backend: str | None = None) -> str:
    """Resolve a ``kernel="pallas"|"xla"|"auto"`` knob to a concrete engine.

    "auto" picks Pallas only when (a) the backend is TPU — jax 0.4.37 has no
    CPU Pallas lowering, so off-TPU auto always takes the XLA oracle, the
    ``edge_support_auto`` precedent; (b) some tile fits the VMEM budget for
    this cap_e; and (c) the lane is triangle-dense (3T >= E), where the
    dense sweep's MXU work beats the sparse gather chain.
    """
    if kernel in ("pallas", "xla"):
        return kernel
    if kernel != "auto":
        raise ValueError(f"unknown kernel {kernel!r}")
    backend = backend or jax.default_backend()
    if backend != "tpu":
        return "xla"
    from repro.core.support import triangle_density
    fits = [c for c in fk.DEFAULT_TILE_CANDIDATES
            if fk.kernel_vmem_bytes(cap_e, c) <= fk.VMEM_BUDGET_BYTES]
    if not fits or triangle_density(cap_e, n_tris) < 1.0:
        return "xla"
    return "pallas"


def resolve_tile(cap_e: int, n_tris: int, bt, interpret: bool) -> int:
    """Concrete tile size: explicit int passes through; "auto" takes the
    largest budget-feasible candidate no bigger than the (pow2-rounded)
    triangle count — divisibility is handled by padding, not rejection."""
    if bt != "auto":
        return int(bt)
    fits = [c for c in fk.DEFAULT_TILE_CANDIDATES
            if fk.kernel_vmem_bytes(cap_e, c) <= fk.VMEM_BUDGET_BYTES]
    if not fits:
        return 128
    cover = 1
    while cover < max(1, n_tris):
        cover *= 2
    under = [c for c in fits if c <= max(cover, min(fits))]
    return max(under) if under else min(fits)


def _pad_tris(tris, bt: int, cap_e: int):
    """Pad the triangle dimension to a multiple of ``bt`` with rows on the
    per-lane drop slot ``cap_e`` (the bucket builders' padding convention —
    the kernel's one-hot is all-zero there, so padding rows are inert)."""
    B, T = tris.shape[0], tris.shape[1]
    T_pad = max(bt, -(-T // bt) * bt)
    if T_pad == T:
        return jnp.asarray(tris, jnp.int32)
    pad = jnp.full((B, T_pad - T, 3), cap_e, jnp.int32)
    return jnp.concatenate([jnp.asarray(tris, jnp.int32), pad], axis=1)


@partial(jax.jit, static_argnames=("bt", "interpret"), donate_argnums=(0,))
def _peel_classes_fused_impl(sup_b, tris_b, alive_b, *, bt, interpret):
    B, cap_e = sup_b.shape
    T = tris_b.shape[1]

    def cond(state):
        alive, _, _, _, _ = state
        return jnp.any(alive > 0)

    def body(state):
        alive, sup, phi, k, st = state
        rm = jnp.where(sup <= k[:, None] - 2, alive, 0)
        nf = jnp.sum(rm, axis=1)
        has_rm = nf > 0
        lane_alive = jnp.sum(alive, axis=1) > 0
        min_sup = jnp.min(jnp.where(alive > 0, sup, _BIG), axis=1)
        k2 = jnp.where(lane_alive & ~has_rm,
                       jnp.maximum(k + 1, min_sup + 2), k)
        phi2 = jnp.where(rm > 0, k[:, None], phi)
        sup2, alive2 = fk.fused_round(sup, alive, rm, tris_b,
                                      bt=bt, interpret=interpret)
        st2 = st.at[:, _S_ROUNDS].add(lane_alive.astype(jnp.int32))
        st2 = st2.at[:, _S_REMOVED].add(nf)
        # dense-sweep accounting: every remove round touches all 3T slots
        st2 = st2.at[:, _S_GATHERED].add(
            jnp.where(has_rm, jnp.int32(3 * T), 0))
        st2 = st2.at[:, _S_MAXF].max(nf)
        return alive2, sup2, phi2, k2, st2

    state0 = (
        jnp.asarray(alive_b, jnp.int32),
        jnp.asarray(sup_b, jnp.int32),
        jnp.zeros((B, cap_e), jnp.int32),
        jnp.full((B,), 2, jnp.int32),
        jnp.zeros((B, N_STATS), jnp.int32),
    )
    _, _, phi, _, st = jax.lax.while_loop(cond, body, state0)
    return phi, st


def peel_classes_fused(sup_b, tris_b, alive_b, *, bt="auto",
                       interpret: bool | None = None):
    """Trussness of every lane via fused lockstep rounds.

    Same contract as ``peel._peel_classes_vmapped``: (B, E) sup/alive and
    (B, T, 3) triangles in, (phi (B, E), stats (B, N_STATS)) out — but one
    kernel invocation per round and no incidence CSR inputs.  ``interpret``
    defaults to True off-TPU (interpreter parity path).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    cap_e = int(sup_b.shape[1])
    bt = resolve_tile(cap_e, int(tris_b.shape[1]), bt, interpret)
    tris_p = _pad_tris(jnp.asarray(tris_b, jnp.int32), bt, cap_e)
    return _peel_classes_fused_impl(
        jnp.asarray(sup_b, jnp.int32), tris_p,
        jnp.asarray(alive_b, jnp.int32), bt=bt, interpret=bool(interpret))


@partial(jax.jit, static_argnames=("bt", "interpret"))
def _peel_threshold_fused_impl(sup, tris, alive, removable, thresh, *,
                               bt, interpret):
    def cond(state):
        alive_c, sup_c = state
        return jnp.any((alive_c > 0) & (removable > 0) & (sup_c <= thresh))

    def body(state):
        alive_c, sup_c = state
        rm = jnp.where((removable > 0) & (sup_c <= thresh), alive_c, 0)
        sup2, alive2 = fk.fused_round(sup_c, alive_c, rm, tris,
                                      bt=bt, interpret=interpret)
        return alive2, sup2

    alive_f, _ = jax.lax.while_loop(cond, body, (alive, sup))
    return alive_f


def peel_threshold_fused(sup, tris, removable, thresh, alive0, *, bt="auto",
                         interpret: bool | None = None):
    """Single-level candidate peel (both OOC drivers' per-k kernel) via
    fused rounds.  (E,) sup / removable / alive0 and (T, 3) triangles in,
    final (E,) int32 alive mask out — no incidence CSR needed."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    cap_e = int(sup.shape[0])
    bt = resolve_tile(cap_e, int(tris.shape[0]), bt, interpret)
    tris_p = _pad_tris(jnp.asarray(tris, jnp.int32)[None], bt, cap_e)
    alive_f = _peel_threshold_fused_impl(
        jnp.asarray(sup, jnp.int32)[None], tris_p,
        jnp.asarray(alive0, jnp.int32)[None],
        jnp.asarray(removable, jnp.int32)[None],
        jnp.int32(thresh), bt=bt, interpret=bool(interpret))
    return alive_f[0]
