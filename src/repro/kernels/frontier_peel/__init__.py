"""Fused Pallas frontier-peel kernel (DESIGN.md §13).

One ``pallas_call`` per removal round replaces the XLA dispatch chain of
``peel._frontier_round`` (compact → gather → dedup → scatter): per-lane edge
state stays VMEM-resident while the triangle list streams through in tiles.
``ops`` holds the jit'd outer peel loops and the auto-dispatch helpers used
by ``peel.peel_classes_batched`` / ``peel.local_threshold_peel``; ``ref`` is
the pure-jnp oracle the parity suite checks the kernel against.
"""

from repro.kernels.frontier_peel.kernel import (DEFAULT_TILE_CANDIDATES,
                                                VMEM_BUDGET_BYTES,
                                                autotune_tiles, feasible_tiles,
                                                fused_round,
                                                kernel_vmem_bytes)
from repro.kernels.frontier_peel.ops import (peel_classes_fused,
                                             peel_threshold_fused,
                                             resolve_kernel)
from repro.kernels.frontier_peel.ref import fused_round_ref, peel_classes_ref

__all__ = [
    "DEFAULT_TILE_CANDIDATES",
    "VMEM_BUDGET_BYTES",
    "autotune_tiles",
    "feasible_tiles",
    "fused_round",
    "kernel_vmem_bytes",
    "peel_classes_fused",
    "peel_threshold_fused",
    "resolve_kernel",
    "fused_round_ref",
    "peel_classes_ref",
]
