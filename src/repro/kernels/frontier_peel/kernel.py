"""Fused frontier-peel round as a single Pallas TPU kernel.

One invocation computes one WHOLE removal round for a batch of peel lanes:
given per-lane edge state (support, alive mask) and the round's removal
frontier ``rm = alive & (sup <= thresh)``, it produces the post-round state

    alive' = alive & ~rm
    sup'   = sup - #{died triangles incident to each surviving edge}

where a triangle dies when all three corners were alive and at least one was
removed.  This is the dense-sweep form of ``peel._frontier_round``'s
gather/dedup/scatter loop: because the entire frontier is removed in one
round (no cap_f chunking), the owner-dedup reduces to "each died triangle
decrements each of its surviving corners exactly once", and the kernel is
statically overflow-free — there is no cap_f/cap_t resume path.

Memory layout (DESIGN.md §13): grid is (lanes, triangle tiles).  Each lane's
edge-state rows — sup, alive, rm in; sup', alive' out; a f32 decrement
accumulator in scratch — live in VMEM for the whole sweep (BlockSpec index
maps pin them to the lane, so Pallas revisits the same block across the tile
loop).  The (bt, 3) triangle tile is the only streamed operand.  Corner
gathers and the decrement scatter both go through a one-hot (bt, E) matmul,
so the inner loop is MXU work with NO dynamic indexing — the layout Pallas
TPU lowers well, same trick as the ``triangle_count`` kernel's masked-dot
formulation.

The f32 accumulator is exact while per-round decrements stay below 2^24 per
edge — guaranteed here because an edge's decrement is bounded by its support,
an int32 well under 2^24 in every OOC lane (cap_e <= 2^20).
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

try:  # TPU-specific scratch shapes; absent on some CPU-only installs
    from jax.experimental.pallas import tpu as pltpu

    _SCRATCH = lambda shape: pltpu.VMEM(shape, jnp.float32)  # noqa: E731
except Exception:  # pragma: no cover - fallback for pallas builds without tpu
    _SCRATCH = lambda shape: pl.pallas_core.ScratchShape(shape, jnp.float32)  # type: ignore[attr-defined]  # noqa: E731

VMEM_BUDGET_BYTES = 12 * 1024 * 1024  # leave headroom below the ~16 MB/core
DEFAULT_TILE_CANDIDATES = (128, 256, 512, 1024)


def kernel_vmem_bytes(cap_e: int, bt: int) -> int:
    """Conservative VMEM working set of one (lane, tile) kernel step.

    Five int32 edge-state rows + one f32 accumulator row (6 * cap_e words),
    the streamed (bt, 3) triangle tile, and the transient (bt, cap_e) f32
    one-hot used for the gather/scatter matmuls — counted twice for the
    operand copy the MXU pipeline holds in flight.
    """
    edge_rows = 6 * cap_e * 4
    tri_tile = bt * 3 * 4
    onehot = 2 * bt * cap_e * 4
    return edge_rows + tri_tile + onehot


def _round_kernel(sup_ref, alive_ref, rm_ref, tris_ref,
                  sup_out_ref, alive_out_ref, dec_ref):
    """Grid (B, T // bt): lane i's edge state resident, tile j streamed."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dec_ref[...] = jnp.zeros_like(dec_ref)

    cap_e = sup_ref.shape[1]
    bt = tris_ref.shape[1]
    alive_f = alive_ref[...].astype(jnp.float32).reshape(cap_e, 1)
    rm_f = rm_ref[...].astype(jnp.float32).reshape(cap_e, 1)
    alive2_f = alive_f * (1.0 - rm_f)

    cols = jax.lax.broadcasted_iota(jnp.int32, (bt, cap_e), 1)

    def onehot(c):
        # padding rows carry the drop slot cap_e -> all-zero row -> inert
        e_c = tris_ref[0, :, c]
        return (cols == e_c[:, None]).astype(jnp.float32)

    # pass 1: which triangles of this tile die this round?
    live = jnp.ones((bt, 1), jnp.float32)
    surv = jnp.ones((bt, 1), jnp.float32)
    for c in range(3):
        oh = onehot(c)
        live = live * jnp.dot(oh, alive_f,
                              preferred_element_type=jnp.float32)
        surv = surv * (1.0 - jnp.dot(oh, rm_f,
                                     preferred_element_type=jnp.float32))
    died = live * (1.0 - surv)                                   # (bt, 1)

    # pass 2: each died triangle decrements each surviving corner once
    for c in range(3):
        oh = onehot(c)
        corner_alive2 = jnp.dot(oh, alive2_f,
                                preferred_element_type=jnp.float32)
        contrib = (died * corner_alive2).reshape(1, bt)
        dec_ref[...] += jnp.dot(contrib, oh,
                                preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        sup_out_ref[...] = sup_ref[...] - dec_ref[...].astype(jnp.int32)
        alive_out_ref[...] = alive_ref[...] * (1 - rm_ref[...])


def fused_round(sup, alive, rm, tris, *, bt: int = 256,
                interpret: bool = False):
    """One fused removal round over a batch of lanes.

    sup/alive/rm: (B, E) int32 (alive, rm are 0/1 masks, rm ⊆ alive);
    tris: (B, T, 3) int32 with T divisible by ``bt`` and padding rows on the
    per-lane drop slot E.  Returns (sup', alive') as (B, E) int32.

    ``interpret=True`` runs the Pallas interpreter (CPU test path);
    compiled mode targets TPU (jax 0.4.37 has no CPU Pallas lowering).
    """
    B, cap_e = sup.shape
    T = tris.shape[1]
    if T % bt:
        raise ValueError(f"tile {bt} must divide triangle count {T}")
    grid = (B, T // bt)
    lane = lambda i, j: (i, 0)  # noqa: E731
    return pl.pallas_call(
        _round_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cap_e), lane),
            pl.BlockSpec((1, cap_e), lane),
            pl.BlockSpec((1, cap_e), lane),
            pl.BlockSpec((1, bt, 3), lambda i, j: (i, j, 0)),
        ],
        out_specs=[pl.BlockSpec((1, cap_e), lane),
                   pl.BlockSpec((1, cap_e), lane)],
        out_shape=[jax.ShapeDtypeStruct((B, cap_e), jnp.int32),
                   jax.ShapeDtypeStruct((B, cap_e), jnp.int32)],
        scratch_shapes=[_SCRATCH((1, cap_e))],
        interpret=interpret,
    )(sup, alive, rm, tris)


def feasible_tiles(cap_e: int, cap_t: int,
                   candidates=DEFAULT_TILE_CANDIDATES,
                   budget_bytes: int = VMEM_BUDGET_BYTES):
    """Tile sizes that divide the (padded) triangle capacity and whose
    working set fits the VMEM budget, largest first (fewer grid steps)."""
    out = [bt for bt in candidates
           if cap_t % bt == 0 and kernel_vmem_bytes(cap_e, bt) <= budget_bytes]
    return sorted(set(out), reverse=True)


_TUNE_CACHE: dict = {}


def autotune_tiles(cap_e: int, cap_t: int, *,
                   candidates=None,
                   budget_bytes: int = VMEM_BUDGET_BYTES,
                   interpret: bool = False, repeats: int = 2,
                   seed: int = 0) -> int:
    """Pick the fastest feasible ``bt`` by timing one fused round per
    candidate on synthetic data; cached per (shape, backend) like the
    ``triangle_count`` tuner.  Falls back to the largest divisor tile when
    nothing is feasible under the budget."""
    cands = tuple(candidates or DEFAULT_TILE_CANDIDATES)
    key = (cap_e, cap_t, jax.default_backend(), bool(interpret), cands,
           budget_bytes)
    if key in _TUNE_CACHE:
        return _TUNE_CACHE[key]
    feas = feasible_tiles(cap_e, cap_t, cands, budget_bytes)
    if not feas:
        bt = next((b for b in (128, 64, 32, 16, 8, 4, 2, 1)
                   if cap_t % b == 0), 1)
        _TUNE_CACHE[key] = bt
        return bt
    rng = np.random.default_rng(seed)
    sup = jnp.asarray(rng.integers(0, 8, (1, cap_e)), jnp.int32)
    alive = jnp.ones((1, cap_e), jnp.int32)
    rm = jnp.asarray(rng.integers(0, 2, (1, cap_e)), jnp.int32)
    tris = jnp.asarray(rng.integers(0, cap_e, (1, cap_t, 3)), jnp.int32)
    best, best_t = feas[0], float("inf")
    for bt in feas:
        fn = functools.partial(fused_round, bt=bt, interpret=interpret)
        try:
            jax.block_until_ready(fn(sup, alive, rm, tris))  # warm up
        except Exception:
            continue
        t0 = time.perf_counter()
        for _ in range(repeats):
            jax.block_until_ready(fn(sup, alive, rm, tris))
        dt = (time.perf_counter() - t0) / repeats
        if dt < best_t:
            best, best_t = bt, dt
    _TUNE_CACHE[key] = best
    return best
