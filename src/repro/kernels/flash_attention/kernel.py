"""Pallas TPU flash attention (forward): causal / sliding-window GQA.

Online-softmax tiling (FlashAttention re-thought for TPU):
  * grid (batch, q_head, q_block, kv_block), kv innermost so the running
    (m, l, acc) state lives in VMEM scratch across kv steps;
  * GQA without materializing repeated KV: the kv BlockSpec index_map sends
    q-head h to kv-head h // group — the MXU reads each KV tile once per
    group from HBM, never expanding it;
  * causal + window masking at block granularity: fully-masked kv blocks are
    skipped with pl.when (no MXU work, no VMEM traffic for the skipped tile
    beyond the pipelined fetch), partial blocks are masked elementwise;
  * q tile (bq, d) and kv tiles (bk, d) with d padded to lane width; f32
    accumulation, bf16-friendly inputs.

VMEM per step: q (bq*d) + k,v (2*bk*d) + acc (bq*d) + m,l (2*bq).
bq = bk = 512, d = 128 in f32: ~1.3 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30

# VMEM the tile working set may claim; real VMEM is ~16 MiB/core but the
# pipeliner needs headroom for semaphores/regs, so budget conservatively.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def kernel_vmem_bytes(bq: int, bk: int, d: int, in_dtype=jnp.float32) -> int:
    """Per-step VMEM working set (DESIGN.md §5).

    Double-buffered q (bq, d), k and v (bk, d) input tiles and output
    tile, plus the single-instance f32 scratch: acc (bq, d) and the
    (m, l) running-softmax columns (bq, 1) each.
    """
    in_bytes = jnp.dtype(in_dtype).itemsize
    tiles_io = (bq * d + 2 * bk * d + bq * d) * in_bytes * 2
    scratch = (bq * d + 2 * bq) * 4
    return tiles_io + scratch


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, scale, causal, window, bq, bk):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block-level relevance: q rows [qi*bq, qi*bq+bq), kv cols [kj*bk, ...).
    q_lo = qi * bq
    q_hi = q_lo + bq - 1
    k_lo = kj * bk
    relevant = True
    if causal:
        relevant = jnp.asarray(k_lo <= q_hi)
    if window is not None:
        relevant = relevant & jnp.asarray(kj * bk + bk - 1 > q_lo - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]                          # (bq, 1)
        m_cur = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=1))[:, None]
        alpha = jnp.exp(m_prev - m_cur)              # (bq, 1)
        p = jnp.exp(s - m_cur)                       # (bq, bk)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)[:, None]
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_cur

    @pl.when(kj == nk - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)              # fully-masked rows -> 0
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jnp.ndarray,   # (B, Hq, S, D)
    k: jnp.ndarray,   # (B, Hkv, Skv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    bq: int = 512,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, s, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError(f"q heads must be a multiple of kv heads for GQA, "
                         f"got hq={hq}, hkv={hkv}")
    group = hq // hkv
    bq = min(bq, s)
    bk = min(bk, skv)
    if s % bq or skv % bk:
        raise ValueError(f"block sizes must divide the sequence lengths: "
                         f"s={s} %% bq={bq}, skv={skv} %% bk={bk}")
    need = kernel_vmem_bytes(bq, bk, d, q.dtype)
    if need > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"tile working set {need} B exceeds the VMEM budget "
            f"{VMEM_BUDGET_BYTES} B; shrink bq/bk (got bq={bq}, bk={bk}, "
            f"d={d}, dtype={q.dtype})")
    scale = 1.0 / (d ** 0.5)
    grid = (b, hq, s // bq, skv // bk)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, i, j: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, h, i, j: (bb, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, h, i, j: (bb, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, h, i, j: (bb, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
