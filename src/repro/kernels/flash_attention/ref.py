"""Pure-jnp oracle for causal (optionally sliding-window) GQA attention."""

from __future__ import annotations

import jax.numpy as jnp


def mha_reference(
    q: jnp.ndarray,   # (B, Hq, S, D)
    k: jnp.ndarray,   # (B, Hkv, S, D)
    v: jnp.ndarray,   # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: int | None = None,
) -> jnp.ndarray:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    if hq % hkv:
        raise ValueError(f"q heads must be a multiple of kv heads for GQA, "
                         f"got hq={hq}, hkv={hkv}")
    group = hq // hkv
    kf = jnp.repeat(k, group, axis=1)
    vf = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kf.astype(jnp.float32)) * scale
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf.astype(jnp.float32))
    return out.astype(q.dtype)
