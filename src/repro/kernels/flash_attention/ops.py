"""jit'd public wrapper for flash attention with a jnp fallback.

``flash_attention(..., use_kernel=False)`` routes to the reference — that is
also the path the dry-run lowers (the Pallas kernel targets real TPUs; on
the CPU host platform XLA has no Mosaic backend, so lowering substitutes the
mathematically identical jnp formulation; see DESIGN.md §7).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.kernel import flash_attention_kernel


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                   "interpret", "use_kernel"))
def flash_attention(
    q, k, v, *, causal=True, window=None,
    bq=512, bk=512, interpret=True, use_kernel=True,
):
    if use_kernel:
        return flash_attention_kernel(
            q, k, v, causal=causal, window=window,
            bq=bq, bk=bk, interpret=interpret,
        )
    return ref.mha_reference(q, k, v, causal=causal, window=window)
