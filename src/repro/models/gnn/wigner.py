"""Real-spherical-harmonic rotation matrices (Wigner D, real basis).

Ivanic & Ruedenberg recursion ("Rotation Matrices for Real Spherical
Harmonics", J. Phys. Chem. 1996 + 1998 erratum): D^l is built from D^{l-1}
and the l=1 rotation, elementwise, with static Python loops over (l, m, n)
— fully vectorizable over a batch of rotations (one per graph edge).

Convention: real SH index order within degree l is m = -l..l; the l=1 block
in this basis equals the 3x3 rotation conjugated by the (y, z, x) axis
permutation.  ``wigner_stack`` returns the block-diagonal (S, S) matrix for
S = (l_max+1)^2, the layout used by the eSCN layer.

Used by equiformer-v2: rotate features into the edge-aligned frame, mix
SO(2) (m-diagonal) there, rotate back — the O(L^6) -> O(L^3) trick
[arXiv:2302.03655, arXiv:2306.12059].
"""

from __future__ import annotations

import math
from functools import lru_cache

import jax.numpy as jnp
import numpy as np


def _delta(a, b):
    return 1.0 if a == b else 0.0


def _uvw(l: int, m: int, n: int):
    """Recursion coefficients u, v, w (Table 1 of Ivanic–Ruedenberg)."""
    am = abs(m)
    if abs(n) < l:
        d = (l + n) * (l - n)
    else:
        d = (2 * l) * (2 * l - 1)
    u = math.sqrt((l + m) * (l - m) / d)
    v = 0.5 * math.sqrt((1 + _delta(m, 0)) * (l + am - 1) * (l + am) / d) \
        * (1 - 2 * _delta(m, 0))
    w = -0.5 * math.sqrt((l - am - 1) * (l - am) / d) * (1 - _delta(m, 0))
    return u, v, w


def _get(M, l, a, b):
    """Entry M^l_{a,b} (batched (..., 2l+1, 2l+1)); 0 if out of range."""
    if abs(a) > l or abs(b) > l:
        return 0.0
    return M[..., a + l, b + l]


def _P(i, l, a, b, r, Mprev):
    """Helper P_i(l; a, b) of the recursion; r is the l=1 block."""
    if b == -l:
        return (_get(r, 1, i, 1) * _get(Mprev, l - 1, a, -l + 1)
                + _get(r, 1, i, -1) * _get(Mprev, l - 1, a, l - 1))
    if b == l:
        return (_get(r, 1, i, 1) * _get(Mprev, l - 1, a, l - 1)
                - _get(r, 1, i, -1) * _get(Mprev, l - 1, a, -l + 1))
    return _get(r, 1, i, 0) * _get(Mprev, l - 1, a, b)


def _rot_to_sh1(R):
    """3x3 rotation -> l=1 real-SH block (basis order y, z, x).

    R maps column vectors (x, y, z); in the SH basis (m=-1,0,1)=(y,z,x):
    D^1 = Pinv R P with P the (x,y,z)->(y,z,x) permutation.
    """
    # D1[i, j] = R[axis(i), axis(j)] with axis map m=-1->y(1), 0->z(2), 1->x(0)
    perm = jnp.array([1, 2, 0])
    return R[..., perm[:, None], perm[None, :]]


def wigner_blocks(R: jnp.ndarray, l_max: int):
    """Per-degree rotation blocks [D^0, D^1, ..., D^{l_max}].

    R: (..., 3, 3) rotation matrices.  Returns list of (..., 2l+1, 2l+1).
    """
    batch = R.shape[:-2]
    blocks = [jnp.ones(batch + (1, 1), R.dtype)]
    if l_max == 0:
        return blocks
    r = _rot_to_sh1(R)
    blocks.append(r)
    Mprev = r
    for l in range(2, l_max + 1):
        rows = []
        for m in range(-l, l + 1):
            cols = []
            for n in range(-l, l + 1):
                u, v, w = _uvw(l, m, n)
                am = abs(m)
                val = 0.0
                if u != 0.0:
                    val = val + u * _P(0, l, m, n, r, Mprev)
                if v != 0.0:
                    if m == 0:
                        Vmn = _P(1, l, 1, n, r, Mprev) + _P(-1, l, -1, n, r, Mprev)
                    elif m > 0:
                        Vmn = (_P(1, l, m - 1, n, r, Mprev)
                               * math.sqrt(1 + _delta(m, 1))
                               - _P(-1, l, -m + 1, n, r, Mprev)
                               * (1 - _delta(m, 1)))
                    else:
                        Vmn = (_P(1, l, m + 1, n, r, Mprev)
                               * (1 - _delta(m, -1))
                               + _P(-1, l, -m - 1, n, r, Mprev)
                               * math.sqrt(1 + _delta(m, -1)))
                    val = val + v * Vmn
                if w != 0.0:
                    if m > 0:
                        Wmn = (_P(1, l, m + 1, n, r, Mprev)
                               + _P(-1, l, -m - 1, n, r, Mprev))
                    else:
                        Wmn = (_P(1, l, m - 1, n, r, Mprev)
                               - _P(-1, l, -m + 1, n, r, Mprev))
                    val = val + w * Wmn
                cols.append(val)
            rows.append(jnp.stack(cols, axis=-1))
        M = jnp.stack(rows, axis=-2)
        blocks.append(M)
        Mprev = M
    return blocks


def wigner_stack(R: jnp.ndarray, l_max: int) -> jnp.ndarray:
    """Block-diagonal (..., S, S) rotation over all degrees, S=(l_max+1)^2."""
    blocks = wigner_blocks(R, l_max)
    S = (l_max + 1) ** 2
    batch = R.shape[:-2]
    out = jnp.zeros(batch + (S, S), R.dtype)
    off = 0
    for l, B in enumerate(blocks):
        w = 2 * l + 1
        out = out.at[..., off:off + w, off:off + w].set(B)
        off += w
    return out


def rotation_to_z(d: jnp.ndarray, eps: float = 1e-9) -> jnp.ndarray:
    """Rotation R with R @ d_hat = z_hat (rows are the new frame axes)."""
    d = d / (jnp.linalg.norm(d, axis=-1, keepdims=True) + eps)
    ref = jnp.where(
        (jnp.abs(d[..., 2:3]) > 0.99), jnp.array([1.0, 0.0, 0.0], d.dtype),
        jnp.array([0.0, 0.0, 1.0], d.dtype),
    )
    x = ref - d * jnp.sum(ref * d, axis=-1, keepdims=True)
    x = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + eps)
    y = jnp.cross(d, x)
    return jnp.stack([x, y, d], axis=-2)   # rows: x', y', z'=d
