"""GNN message-passing primitives (segment-op based; JAX has no CSR SpMM).

Message passing IS ``jnp.take`` over an edge index + ``jax.ops.segment_sum``
(or max) back into nodes — this module is the system's SpMM/SDDMM layer
(kernel_taxonomy §GNN).  All shapes static; padded edges carry a mask.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common as cm


def segment_softmax(scores, seg_ids, n_segments, mask=None):
    """Softmax over entries grouped by seg_ids (edge-softmax for GAT)."""
    scores = scores.astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    smax = jax.ops.segment_max(scores, seg_ids, num_segments=n_segments)
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    ex = jnp.exp(scores - smax[seg_ids])
    if mask is not None:
        ex = jnp.where(mask, ex, 0.0)
    denom = jax.ops.segment_sum(ex, seg_ids, num_segments=n_segments)
    return ex / jnp.maximum(denom[seg_ids], 1e-9)


def aggregate(msgs, dst, n_nodes, agg="sum", mask=None):
    """Scatter-aggregate edge messages into destination nodes."""
    if mask is not None:
        msgs = jnp.where(mask[:, None], msgs, 0.0)
    if agg == "sum":
        return jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    if agg == "mean":
        s = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
        ones = jnp.ones(msgs.shape[0], msgs.dtype)
        if mask is not None:
            ones = jnp.where(mask, ones, 0.0)
        cnt = jax.ops.segment_sum(ones, dst, num_segments=n_nodes)
        return s / jnp.maximum(cnt[:, None], 1.0)
    if agg == "max":
        if mask is not None:
            msgs = jnp.where(mask[:, None], msgs, -1e30)
        out = jax.ops.segment_max(msgs, dst, num_segments=n_nodes)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(agg)


def mlp(params: list, x, act=jax.nn.relu, final_act=False):
    for i, (w, b) in enumerate(params):
        x = x @ w + b
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


def mlp_init(key, dims, dtype=jnp.float32):
    ks = cm.split_keys(key, len(dims) - 1)
    return [
        (cm.dense_init(ks[i], (dims[i], dims[i + 1]), dtype=dtype),
         jnp.zeros((dims[i + 1],), dtype))
        for i in range(len(dims) - 1)
    ]


# ---------------------------------------------------------------------------
# Layers used by the assigned archs
# ---------------------------------------------------------------------------

def sage_layer(params, h, src, dst, n_nodes, edge_mask=None, agg="mean"):
    """GraphSAGE: h' = ReLU(W_self h ++ W_nbr mean_j h_j)."""
    nbr = aggregate(h[src], dst, n_nodes, agg=agg, mask=edge_mask)
    out = h @ params["w_self"] + nbr @ params["w_nbr"] + params["b"]
    return jax.nn.relu(out)


def gat_layer(params, h, src, dst, n_nodes, n_heads, d_head, edge_mask=None,
              negative_slope=0.2, final=False):
    """GAT: multi-head edge attention (SDDMM -> edge softmax -> SpMM)."""
    H, Dh = n_heads, d_head
    z = (h @ params["w"]).reshape(-1, H, Dh)           # (N, H, Dh)
    a_src = jnp.einsum("nhd,hd->nh", z, params["a_src"])
    a_dst = jnp.einsum("nhd,hd->nh", z, params["a_dst"])
    e = jax.nn.leaky_relu(a_src[src] + a_dst[dst], negative_slope)  # (E, H)
    alpha = jax.vmap(
        lambda s: segment_softmax(s, dst, n_nodes, mask=edge_mask),
        in_axes=1, out_axes=1,
    )(e)                                               # (E, H)
    msgs = z[src] * alpha[..., None]                   # (E, H, Dh)
    out = aggregate(msgs.reshape(msgs.shape[0], -1), dst, n_nodes,
                    agg="sum", mask=edge_mask).reshape(-1, H, Dh)
    if final:
        return out.mean(axis=1)                        # average heads
    return jax.nn.elu(out.reshape(-1, H * Dh))
