"""The four assigned GNN architectures.

Common batch format (static shapes, padded):
  node_feat (N, F) f32 | edge_index (E, 2) int32 (src, dst, both directions
  for undirected graphs) | edge_mask (E,) bool | node_mask (N,) bool |
  labels (N,) int32 (node tasks) or (G,) f32 (graph tasks) |
  label_mask | positions (N, 3) for geometric models (synthetic when the
  assigned dataset has none — DESIGN.md §4).

Each model: Config, init_params, forward, loss_fn, param_specs.
Full-graph sharding: edge arrays P(dp), node arrays replicated (baseline) —
the ring-schedule optimization lives in gnn/distributed.py.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import common as cm
from repro.models.common import dp_spec, shard
from repro.models.gnn import layers as L
from repro.models.gnn.wigner import rotation_to_z, wigner_stack


# ---------------------------------------------------------------------------
# MeshGraphNet  [arXiv:2010.03409]
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshGraphNetConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 16
    d_edge_in: int = 4      # relative position (3) + norm (1)
    d_out: int = 3
    aggregator: str = "sum"
    edge_chunks: int = 1    # scan over edge chunks for huge graphs


def _mgn_mlp_dims(cfg, d_in):
    return [d_in] + [cfg.d_hidden] * cfg.mlp_layers


def mgn_init(key, cfg: MeshGraphNetConfig):
    ks = cm.split_keys(key, 2 * cfg.n_layers + 3)
    params = {
        "node_enc": L.mlp_init(ks[0], _mgn_mlp_dims(cfg, cfg.d_node_in)),
        "edge_enc": L.mlp_init(ks[1], _mgn_mlp_dims(cfg, cfg.d_edge_in)),
        "decoder": L.mlp_init(ks[2], [cfg.d_hidden] * cfg.mlp_layers + [cfg.d_out]),
        "blocks": [],
    }
    for i in range(cfg.n_layers):
        params["blocks"].append({
            "edge_mlp": L.mlp_init(ks[3 + 2 * i], _mgn_mlp_dims(cfg, 3 * cfg.d_hidden)),
            "node_mlp": L.mlp_init(ks[4 + 2 * i], _mgn_mlp_dims(cfg, 2 * cfg.d_hidden)),
        })
    return params


def _edge_spec():
    """Edge arrays shard over every mesh axis (pure edge parallelism)."""
    from repro.models.common import mesh_axis_names

    ax = tuple(a for a in ("pod", "data", "model") if a in mesh_axis_names())
    return P(ax if len(ax) > 1 else (ax[0] if ax else None), None)


def mgn_forward(params, batch, cfg: MeshGraphNetConfig):
    src, dst = batch["edge_index"][:, 0], batch["edge_index"][:, 1]
    emask = batch.get("edge_mask")
    n = batch["node_feat"].shape[0]
    h = L.mlp(params["node_enc"], batch["node_feat"])
    e = L.mlp(params["edge_enc"], batch["edge_feat"])
    e = shard(e, _edge_spec())
    # node state sharded too: with 15 layers of remat-saved node buffers,
    # replicated (N, C) states blow past HBM on ogb_products (§Perf P8)
    h = shard(h, _edge_spec())

    def block(carry, blk):
        h, e = carry
        e_in = jnp.concatenate([e, h[src], h[dst]], axis=-1)
        e = shard(e + L.mlp(blk["edge_mlp"], e_in), _edge_spec())
        agg = L.aggregate(e, dst, n, agg=cfg.aggregator, mask=emask)
        h = shard(h + L.mlp(blk["node_mlp"],
                            jnp.concatenate([h, agg], axis=-1)), _edge_spec())
        return (h, e)

    for blk in params["blocks"]:
        h, e = jax.checkpoint(block)((h, e), blk)
    return L.mlp(params["decoder"], h)


def mgn_loss(params, batch, cfg):
    out = mgn_forward(params, batch, cfg)
    err = jnp.square(out - batch["targets"])
    if batch.get("node_mask") is not None:
        err = err * batch["node_mask"][:, None]
        return err.sum() / jnp.maximum(batch["node_mask"].sum() * cfg.d_out, 1.0)
    return err.mean()


# ---------------------------------------------------------------------------
# GraphSAGE  [arXiv:1706.02216]
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GraphSAGEConfig:
    name: str = "graphsage"
    n_layers: int = 2
    d_hidden: int = 128
    d_in: int = 602
    n_classes: int = 41
    aggregator: str = "mean"


def sage_init(key, cfg: GraphSAGEConfig):
    ks = cm.split_keys(key, cfg.n_layers + 1)
    dims = [cfg.d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.d_hidden]
    layers = []
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(ks[i])
        layers.append({
            "w_self": cm.dense_init(k1, (dims[i], dims[i + 1])),
            "w_nbr": cm.dense_init(k2, (dims[i], dims[i + 1])),
            "b": jnp.zeros((dims[i + 1],)),
        })
    return {"layers": layers,
            "head": cm.dense_init(ks[-1], (cfg.d_hidden, cfg.n_classes))}


def sage_forward(params, batch, cfg: GraphSAGEConfig):
    src, dst = batch["edge_index"][:, 0], batch["edge_index"][:, 1]
    h = batch["node_feat"]
    n = h.shape[0]
    for lp in params["layers"]:
        h = L.sage_layer(lp, h, src, dst, n, batch.get("edge_mask"),
                         agg=cfg.aggregator)
        h = shard(h, dp_spec(None))
    return h @ params["head"]


def sage_loss(params, batch, cfg):
    logits = sage_forward(params, batch, cfg)
    return cm.cross_entropy(logits, batch["labels"], batch.get("label_mask"))


# ---------------------------------------------------------------------------
# GAT  [arXiv:1710.10903]
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat"
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_in: int = 1433
    n_classes: int = 7


def gat_init(key, cfg: GATConfig):
    ks = cm.split_keys(key, cfg.n_layers)
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        final = i == cfg.n_layers - 1
        heads = cfg.n_heads
        d_head = cfg.n_classes if final else cfg.d_hidden
        k1, k2, k3 = jax.random.split(ks[i], 3)
        layers.append({
            "w": cm.dense_init(k1, (d_in, heads * d_head)),
            "a_src": cm.dense_init(k2, (heads, d_head)),
            "a_dst": cm.dense_init(k3, (heads, d_head)),
        })
        d_in = heads * d_head
    return {"layers": layers}


def _gat_layer_dims(cfg: "GATConfig", i: int):
    final = i == cfg.n_layers - 1
    return cfg.n_heads, (cfg.n_classes if final else cfg.d_hidden), final


def gat_forward(params, batch, cfg: GATConfig):
    src, dst = batch["edge_index"][:, 0], batch["edge_index"][:, 1]
    h = batch["node_feat"]
    n = h.shape[0]
    for i, lp in enumerate(params["layers"]):
        heads, dh, final = _gat_layer_dims(cfg, i)
        h = L.gat_layer(lp, h, src, dst, n, heads, dh,
                        batch.get("edge_mask"), final=final)
        h = shard(h, dp_spec(None))
    return h


def gat_loss(params, batch, cfg):
    logits = gat_forward(params, batch, cfg)
    return cm.cross_entropy(logits, batch["labels"], batch.get("label_mask"))


# ---------------------------------------------------------------------------
# EquiformerV2 (eSCN SO(2) convolutions)  [arXiv:2306.12059]
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer_v2"
    n_layers: int = 12
    d_hidden: int = 128      # channels per irrep slot
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    d_in: int = 16           # scalar input features
    d_out: int = 1           # graph/node scalar output
    n_rbf: int = 16
    edge_chunks: int = 1     # scan over edge chunks (memory control)
    ring_dtype: str = "f32"  # ring payload dtype ("bf16" halves ICI bytes)

    @property
    def n_sph(self) -> int:
        return (self.l_max + 1) ** 2


def _sph_index(l, m):
    return l * l + l + m


def _m_slots(cfg, m):
    """Irrep slots with degree >= m (the SO(2) conv operand rows for |m|=m)."""
    return [_sph_index(l, m) for l in range(m, cfg.l_max + 1)], \
           [_sph_index(l, -m) for l in range(m, cfg.l_max + 1)]


def eqv2_init(key, cfg: EquiformerV2Config):
    C = cfg.d_hidden
    ks = cm.split_keys(key, 4 * cfg.n_layers + 4)
    params = {
        "embed": cm.dense_init(ks[0], (cfg.d_in, C)),
        "head": L.mlp_init(ks[1], [C, C, cfg.d_out]),
        "blocks": [],
    }
    for i in range(cfg.n_layers):
        kk = cm.split_keys(ks[2 + i], 8)
        blk = {"rbf_mlp": L.mlp_init(kk[0], [cfg.n_rbf, C, (cfg.m_max + 1)]),
               "attn_mlp": L.mlp_init(kk[1], [C + cfg.n_rbf, C, cfg.n_heads]),
               "gate_mlp": L.mlp_init(kk[2], [C, C, cfg.l_max * C]),
               "so2": {}}
        for m in range(cfg.m_max + 1):
            n_l = cfg.l_max + 1 - m
            blk["so2"][f"wc_{m}"] = cm.dense_init(
                kk[3 + m], (n_l, n_l, C, C), in_axis=-2)
            if m > 0:
                blk["so2"][f"ws_{m}"] = cm.dense_init(
                    jax.random.fold_in(kk[3 + m], 1), (n_l, n_l, C, C), in_axis=-2)
        params["blocks"].append(blk)
    return params


def _so2_conv(x_rot, blk, radial, cfg):
    """x_rot: (E, S, C) features in edge-aligned frames; SO(2) m-mixing."""
    C = cfg.d_hidden
    y = jnp.zeros_like(x_rot)
    for m in range(cfg.m_max + 1):
        pos, neg = _m_slots(cfg, m)
        r = radial[:, None, m:m + 1]                       # (E, 1, 1)
        if m == 0:
            xm = x_rot[:, pos, :]                          # (E, n_l, C)
            ym = jnp.einsum("eic,iocd->eod", xm, blk["so2"]["wc_0"]) * r
            y = y.at[:, pos, :].add(ym)
        else:
            xp = x_rot[:, pos, :]
            xn = x_rot[:, neg, :]
            wc, ws = blk["so2"][f"wc_{m}"], blk["so2"][f"ws_{m}"]
            yp = (jnp.einsum("eic,iocd->eod", xp, wc)
                  - jnp.einsum("eic,iocd->eod", xn, ws)) * r
            yn = (jnp.einsum("eic,iocd->eod", xp, ws)
                  + jnp.einsum("eic,iocd->eod", xn, wc)) * r
            y = y.at[:, pos, :].add(yp)
            y = y.at[:, neg, :].add(yn)
    return y


def _rbf(dist, n_rbf, cutoff=5.0):
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    return jnp.exp(-((dist[:, None] - centers) ** 2) / (cutoff / n_rbf) ** 2)


def eqv2_forward(params, batch, cfg: EquiformerV2Config):
    src, dst = batch["edge_index"][:, 0], batch["edge_index"][:, 1]
    emask = batch.get("edge_mask")
    pos = batch["positions"]
    n = batch["node_feat"].shape[0]
    C, S = cfg.d_hidden, cfg.n_sph
    E = src.shape[0]

    x = jnp.zeros((n, S, C))
    x = x.at[:, 0, :].set(batch["node_feat"] @ params["embed"])

    d_vec = pos[dst] - pos[src]
    dist = jnp.linalg.norm(d_vec, axis=-1) + 1e-9
    rbf = _rbf(dist, cfg.n_rbf)
    Rz = rotation_to_z(d_vec)
    D = wigner_stack(Rz, cfg.l_max)                       # (E, S, S)

    def edge_messages(x, blk):
        def chunk_fn(carry, idx):
            s, d_, Dc, rbfc, maskc = idx
            xr = jnp.einsum("est,etc->esc", Dc, x[s])     # rotate to edge frame
            radial = L.mlp(blk["rbf_mlp"], rbfc)          # (Ec, m_max+1)
            y = _so2_conv(xr, blk, radial, cfg)
            msg = jnp.einsum("ets,etc->esc", Dc, y)       # rotate back (D^T)
            # invariant attention over incoming edges (logits soft-clipped so
            # the ring path can normalize with raw exp; source scalars + rbf
            # only, so the logits are computable at the source owner)
            att_in = jnp.concatenate([x[s][:, 0], rbfc], axis=-1)
            logit = L.mlp(blk["attn_mlp"], att_in)        # (Ec, heads)
            logit = 10.0 * jnp.tanh(logit / 10.0)
            return carry, (msg, logit, d_, maskc)

        if cfg.edge_chunks <= 1:
            _, (msg, logit, d_, maskc) = chunk_fn(
                None, (src, dst, D, rbf,
                       emask if emask is not None else jnp.ones(E, bool)))
        else:
            k = cfg.edge_chunks
            Ec = E // k
            resh = lambda a: a.reshape((k, Ec) + a.shape[1:])
            _, (msg, logit, d_, maskc) = jax.lax.scan(
                chunk_fn, None,
                (resh(src), resh(dst), resh(D), resh(rbf),
                 resh(emask if emask is not None else jnp.ones(E, bool))))
            msg = msg.reshape((E,) + msg.shape[2:])
            logit = logit.reshape((E,) + logit.shape[2:])
            d_ = d_.reshape((E,))
            maskc = maskc.reshape((E,))
        return msg, logit, d_, maskc

    def one_block(x, blk):
        msg, logit, d_, maskc = edge_messages(x, blk)
        alpha = jax.vmap(lambda s: L.segment_softmax(s, d_, n, mask=maskc),
                         in_axes=1, out_axes=1)(logit)     # (E, heads)
        hd = C // cfg.n_heads
        msg_h = msg.reshape(E, S, cfg.n_heads, hd) * alpha[:, None, :, None]
        agg = L.aggregate(msg_h.reshape(E, -1), d_, n, agg="sum", mask=maskc)
        agg = agg.reshape(n, S, C)
        # gated nonlinearity: scalars gate the l>0 channels
        gates = jax.nn.sigmoid(
            L.mlp(blk["gate_mlp"], agg[:, 0]).reshape(n, cfg.l_max, C))
        gated = [jax.nn.silu(agg[:, 0:1])]
        for l in range(1, cfg.l_max + 1):
            sl = slice(l * l, (l + 1) * (l + 1))
            gated.append(agg[:, sl] * gates[:, None, l - 1])
        return x + jnp.concatenate(gated, axis=1)

    for blk in params["blocks"]:
        x = jax.checkpoint(one_block)(x, blk)
    return L.mlp(params["head"], x[:, 0])                  # invariant readout


def eqv2_loss(params, batch, cfg):
    out = eqv2_forward(params, batch, cfg)
    if out.shape[-1] == 1:
        err = jnp.square(out[:, 0] - batch["targets"])
        if batch.get("node_mask") is not None:
            err = err * batch["node_mask"]
            return err.sum() / jnp.maximum(batch["node_mask"].sum(), 1.0)
        return err.mean()
    return cm.cross_entropy(out, batch["labels"], batch.get("label_mask"))
