"""Neighbor sampler for minibatch GNN training (GraphSAGE-style fanouts).

Host-side (numpy) — this is data pipeline, like tokenization.  Produces
fixed-shape padded subgraphs consumed by the device step.  Supports
uniform and *truss-weighted* sampling (the paper's trussness as edge
importance — strong ties first; core/sparsify.sampling_weights).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class CSR:
    indptr: np.ndarray
    nbrs: np.ndarray
    edge_w: Optional[np.ndarray] = None   # per-entry sampling weight

    @staticmethod
    def from_edges(n: int, edges: np.ndarray, edge_w=None) -> "CSR":
        """Symmetric CSR from a canonical (u < v) edge list."""
        src = np.concatenate([edges[:, 0], edges[:, 1]])
        dst = np.concatenate([edges[:, 1], edges[:, 0]])
        w = None if edge_w is None else np.concatenate([edge_w, edge_w])
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        if w is not None:
            w = w[order]
        indptr = np.zeros(n + 1, np.int64)
        np.add.at(indptr, src + 1, 1)
        return CSR(np.cumsum(indptr), dst.astype(np.int32), w)


def sample_subtree(
    csr: CSR,
    seeds: np.ndarray,
    fanouts: Sequence[int],
    rng: np.random.Generator,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fanout-sample a k-hop subtree.

    Returns (nodes, edge_index, edge_mask): ``nodes`` is the padded flat
    node-id array (seeds first); ``edge_index`` (E, 2) connects sampled
    neighbors (src = neighbor, dst = parent) as *local* indices into
    ``nodes``; padding entries repeat node 0 with mask False.
    """
    nodes = [seeds.astype(np.int32)]
    edges = []
    masks = []
    frontier = seeds.astype(np.int64)
    offset = 0
    for f in fanouts:
        deg = csr.indptr[frontier + 1] - csr.indptr[frontier]
        picks = np.zeros((len(frontier), f), np.int64)
        ok = deg > 0
        # vectorized uniform / weighted pick with replacement
        r = rng.random((len(frontier), f))
        if csr.edge_w is None:
            idx = (r * np.maximum(deg, 1)[:, None]).astype(np.int64)
            picks = csr.nbrs[csr.indptr[frontier][:, None] + idx]
        else:
            for i, v in enumerate(frontier):   # weighted: per-row choice
                s, e = csr.indptr[v], csr.indptr[v + 1]
                if e > s:
                    w = csr.edge_w[s:e].astype(np.float64)
                    w = w / w.sum()
                    picks[i] = csr.nbrs[s + rng.choice(e - s, size=f, p=w)]
        mask = np.broadcast_to(ok[:, None], (len(frontier), f)).copy()
        child_base = offset + len(frontier)
        parent_local = np.repeat(np.arange(offset, offset + len(frontier)), f)
        child_local = np.arange(child_base, child_base + frontier.size * f)
        edges.append(np.stack([child_local, parent_local], axis=1))
        masks.append(mask.reshape(-1))
        nodes.append(np.where(mask, picks, 0).astype(np.int32).reshape(-1))
        frontier = picks.reshape(-1)
        offset = child_base
    all_nodes = np.concatenate(nodes)
    edge_index = np.concatenate(edges).astype(np.int32)
    edge_mask = np.concatenate(masks)
    return all_nodes, edge_index, edge_mask


def minibatch(
    csr: CSR,
    feats: np.ndarray,
    labels: np.ndarray,
    batch_nodes: int,
    fanouts: Sequence[int],
    rng: np.random.Generator,
) -> dict:
    """One padded training minibatch for the sampled-training shape."""
    n = len(feats)
    seeds = rng.integers(0, n, size=batch_nodes)
    nodes, edge_index, edge_mask = sample_subtree(csr, seeds, fanouts, rng)
    label_mask = np.zeros(len(nodes), np.float32)
    label_mask[:batch_nodes] = 1.0
    lab = np.zeros(len(nodes), np.int32)
    lab[:batch_nodes] = labels[seeds]
    return {
        "node_feat": feats[nodes].astype(np.float32),
        "edge_index": edge_index,
        "edge_mask": edge_mask,
        "labels": lab,
        "label_mask": label_mask,
    }
