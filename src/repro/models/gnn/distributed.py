"""Ring-scheduled full-graph message passing (shard_map).

The memory problem: equiformer-v2 node features on ogb_products are
(2.45M, 49, 128) f32 ≈ 61 GB — they must live sharded, and a naive
``x[src]`` gather would all-gather the whole array.  The paper's discipline
(partition into neighborhood subgraphs, stream sequentially — DESIGN.md §2)
maps to a **compute-fused ring reduce-scatter**:

* nodes are block-sharded over the flattened mesh axes (owner = src block);
* every device keeps the edges whose SOURCE it owns, bucketed by the
  destination block (host prep below) — so the feature gather is local;
* the per-block partial aggregations travel the ring (`ppermute`), each
  device adding its contribution for the block the accumulator is destined
  to; after P steps each device holds the full aggregation for its own
  block.  Peak memory: x_loc + ONE rotating block (≈ 2×240 MB) instead of
  61 GB; per-device traffic equals the reduce-scatter lower bound
  ((P-1)/P of the message volume) — a psum-per-block schedule would be P×
  worse (measured in EXPERIMENTS.md §Perf).

Attention normalization across devices: per-edge weights are
``exp(soft-clipped logit)`` computed from source-side invariants; the ring
carries (numerator, denominator), the owner divides — identical to the
plain path's segment-softmax of clipped logits.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.gnn import layers as L
from repro.models.gnn.models import EquiformerV2Config, _rbf, _so2_conv
from repro.models.gnn.wigner import rotation_to_z, wigner_stack


# ---------------------------------------------------------------------------
# Host prep: owner-bucketed edges
# ---------------------------------------------------------------------------

def bucket_edges_by_owner(
    n_pad: int, edge_index: np.ndarray, positions: np.ndarray,
    n_devices: int, pad_factor: float = 2.0,
) -> dict:
    """Bucket directed edges by (owner = src block, dst block).

    Returns (P, P, Eb) arrays: src_loc, dst_loc (block-local ids), edge_mask,
    and dst_pos (P, P, Eb, 3).  n_pad must be divisible by n_devices.
    """
    Pn = n_devices
    if n_pad % Pn:
        raise ValueError(f"n_pad={n_pad} must be divisible by "
                         f"n_devices={Pn}; pad the vertex count first")
    W = n_pad // Pn
    src = edge_index[:, 0].astype(np.int64)
    dst = edge_index[:, 1].astype(np.int64)
    own = src // W
    blk = dst // W
    counts = np.zeros((Pn, Pn), np.int64)
    np.add.at(counts, (own, blk), 1)
    Eb = max(1, int(counts.max()),
             int(np.ceil(pad_factor * len(src) / (Pn * Pn))))
    key = own * Pn + blk
    order = np.argsort(key, kind="stable")
    ssrc, sdst, skey = src[order], dst[order], key[order]
    slot = np.arange(len(skey)) - np.searchsorted(skey, skey, side="left")
    keep = slot < Eb
    src_loc = np.zeros((Pn, Pn, Eb), np.int32)
    dst_loc = np.zeros((Pn, Pn, Eb), np.int32)
    mask = np.zeros((Pn, Pn, Eb), bool)
    dst_pos = np.zeros((Pn, Pn, Eb, 3), np.float32)
    o, b, s_ = own[order][keep], blk[order][keep], slot[keep]
    src_loc[o, b, s_] = (ssrc[keep] - o * W).astype(np.int32)
    dst_loc[o, b, s_] = (sdst[keep] - b * W).astype(np.int32)
    mask[o, b, s_] = True
    dst_pos[o, b, s_] = positions[np.minimum(sdst[keep], len(positions) - 1)]
    return {"src_loc": src_loc, "dst_loc": dst_loc, "edge_mask": mask,
            "dst_pos": dst_pos, "overflow": int((~keep).sum())}


def pad_nodes(arr: np.ndarray, n_pad: int) -> np.ndarray:
    out = np.zeros((n_pad,) + arr.shape[1:], arr.dtype)
    out[: len(arr)] = arr
    return out


# ---------------------------------------------------------------------------
# Ring reduce-scatter with fused compute
# ---------------------------------------------------------------------------

def ring_aggregate(contrib_fn: Callable, acc_init, axis, axis_size: int):
    """After the ring, each device holds  sum_dev contrib_fn(dev -> my block).

    Schedule: the accumulator for block b starts at device (b+1) mod P; at
    step j device d adds its contribution for block (d-1-j) mod P, then the
    accumulators rotate +1.  After P add-rotate steps a final rotate(-1)
    lands block b's accumulator on device b.
    """
    Pn = axis_size
    perm_f = [(j, (j + 1) % Pn) for j in range(Pn)]
    perm_b = [(j, (j - 1) % Pn) for j in range(Pn)]
    my = jax.lax.axis_index(axis)

    def step(acc, j):
        b = (my - 1 - j) % Pn
        acc = jax.tree.map(jnp.add, acc, contrib_fn(b))
        acc = jax.tree.map(lambda a: jax.lax.ppermute(a, axis, perm_f), acc)
        return acc, None

    acc, _ = jax.lax.scan(step, acc_init, jnp.arange(Pn, dtype=jnp.int32))
    return jax.tree.map(lambda a: jax.lax.ppermute(a, axis, perm_b), acc)


def _float0_like(x):
    import numpy as _np

    return _np.zeros(x.shape, jax.dtypes.float0)


def make_ring_layer(contrib_fn: Callable, axis, axis_size: int):
    """custom-VJP ring: O(1 block) memory in BOTH passes.

    ``contrib_fn(b, x, blk, pos, dpos, src, dst, emask) -> {"num","den"}``.
    Differentiating through the forward scan would save every ring carry
    (P × block ≈ 61 GB on ogb_products); instead the backward runs its OWN
    ring — the transpose of reduce-scatter is an all-gather, so the output
    cotangent blocks rotate the ring while each device re-computes its
    per-step contribution and applies the step VJP (2× recompute, O(block)
    memory; EXPERIMENTS.md §Perf: 800 GiB -> ~4 GiB temp).
    """
    Pn = axis_size
    perm_f = [(j, (j + 1) % Pn) for j in range(Pn)]
    perm_b = [(j, (j - 1) % Pn) for j in range(Pn)]

    @jax.custom_vjp
    def ring_layer(x, blk, pos, dpos_b, src_b, dst_b, emask_b):
        my = jax.lax.axis_index(axis)

        def step(acc, j):
            b = (my - 1 - j) % Pn
            add = contrib_fn(b, x, blk, pos, dpos_b, src_b, dst_b, emask_b)
            acc = jax.tree.map(jnp.add, acc, add)
            return jax.tree.map(
                lambda a: jax.lax.ppermute(a, axis, perm_f), acc), None

        W = x.shape[0]
        probe = jax.eval_shape(
            contrib_fn, jax.ShapeDtypeStruct((), jnp.int32),
            x, blk, pos, dpos_b, src_b, dst_b, emask_b)
        acc0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), probe)
        acc, _ = jax.lax.scan(step, acc0, jnp.arange(Pn, dtype=jnp.int32))
        return jax.tree.map(
            lambda a: jax.lax.ppermute(a, axis, perm_b), acc)

    def fwd(x, blk, pos, dpos_b, src_b, dst_b, emask_b):
        out = ring_layer(x, blk, pos, dpos_b, src_b, dst_b, emask_b)
        return out, (x, blk, pos, dpos_b, src_b, dst_b, emask_b)

    def bwd(res, g):
        x, blk, pos, dpos_b, src_b, dst_b, emask_b = res
        my = jax.lax.axis_index(axis)

        def step(carry, j):
            gblk, dx, dblk, dpos, ddpos = carry
            b = (my + j) % Pn   # block whose cotangent we currently hold

            def f(x_, blk_, pos_, dpos_):
                return contrib_fn(b, x_, blk_, pos_, dpos_, src_b, dst_b,
                                  emask_b)

            _, vjp = jax.vjp(f, x, blk, pos, dpos_b)
            dxj, dblkj, dposj, ddposj = vjp(gblk)
            dx = jax.tree.map(jnp.add, dx, dxj)
            dblk = jax.tree.map(jnp.add, dblk, dblkj)
            dpos = dpos + dposj
            ddpos = ddpos + ddposj
            gblk = jax.tree.map(
                lambda a: jax.lax.ppermute(a, axis, perm_b), gblk)
            return (gblk, dx, dblk, dpos, ddpos), None

        dx0 = jnp.zeros_like(x)
        dblk0 = jax.tree.map(jnp.zeros_like, blk)
        dpos0 = jnp.zeros_like(pos)
        ddpos0 = jnp.zeros_like(dpos_b)
        (_, dx, dblk, dpos, ddpos), _ = jax.lax.scan(
            step, (g, dx0, dblk0, dpos0, ddpos0),
            jnp.arange(Pn, dtype=jnp.int32))
        return (dx, dblk, dpos, ddpos, _float0_like(src_b),
                _float0_like(dst_b), _float0_like(emask_b))

    ring_layer.defvjp(fwd, bwd)
    return ring_layer


# ---------------------------------------------------------------------------
# EquiformerV2 ring forward (node-sharded)
# ---------------------------------------------------------------------------

def eqv2_ring_loss(params, batch, cfg: EquiformerV2Config, mesh,
                   axes=("data", "model")):
    """Masked-MSE loss with node features sharded over the flattened axes.

    batch: node_feat (N, F), positions (N, 3), targets (N,), node_mask (N,)
    node-sharded; src_loc/dst_loc/edge_mask/dst_pos from
    ``bucket_edges_by_owner`` — sharded on dim 0 (owner).
    """
    ax = tuple(a for a in axes if a in mesh.axis_names)
    Pn = int(np.prod([mesh.shape[a] for a in ax]))
    S, C = cfg.n_sph, cfg.d_hidden

    def _contrib(b, x, blk, pos, dpos_b, src_b, dst_b, emask_b):
        W = x.shape[0]
        s_l = src_b[b]                        # (Eb,)
        d_l = dst_b[b]
        msk = emask_b[b]
        d_vec = dpos_b[b] - pos[s_l]
        dist = jnp.linalg.norm(d_vec, axis=-1) + 1e-9
        rbf = _rbf(dist, cfg.n_rbf)
        D = wigner_stack(rotation_to_z(d_vec), cfg.l_max)
        xr = jnp.einsum("est,etc->esc", D, x[s_l])
        radial = L.mlp(blk["rbf_mlp"], rbf)
        y = _so2_conv(xr, blk, radial, cfg)
        msg = jnp.einsum("ets,etc->esc", D, y)
        att_in = jnp.concatenate([x[s_l][:, 0], rbf], axis=-1)
        logit = 10.0 * jnp.tanh(L.mlp(blk["attn_mlp"], att_in) / 10.0)
        w = jnp.exp(logit) * msk[:, None]     # (Eb, H)
        hd = C // cfg.n_heads
        msg_h = (msg.reshape(-1, S, cfg.n_heads, hd)
                 * w[:, None, :, None]).reshape(-1, S * C)
        msg_h = jnp.where(msk[:, None], msg_h, 0.0)
        dt = jnp.bfloat16 if cfg.ring_dtype == "bf16" else jnp.float32
        return {
            "num": jax.ops.segment_sum(msg_h, d_l, num_segments=W).astype(dt),
            "den": jax.ops.segment_sum(w, d_l, num_segments=W).astype(dt),
        }

    def body(node_feat, pos, targets, node_mask, src_b, dst_b, emask_b, dpos_b):
        # bucketed arrays arrive (1, P, Eb[, 3]) — drop the device dim
        src_b, dst_b, emask_b, dpos_b = (
            a[0] for a in (src_b, dst_b, emask_b, dpos_b))
        W = node_feat.shape[0]
        x0 = jnp.zeros((W, S, C))
        x0 = x0.at[:, 0, :].set(node_feat @ params["embed"])
        ring_layer = make_ring_layer(_contrib, ax, Pn)

        def layer(x, blk):
            agg = ring_layer(x, blk, pos, dpos_b, src_b, dst_b, emask_b)
            hd = C // cfg.n_heads
            num = agg["num"].astype(jnp.float32).reshape(W, S, cfg.n_heads, hd)
            den = jnp.maximum(agg["den"].astype(jnp.float32),
                              1e-9)[:, None, :, None]
            out = (num / den).reshape(W, S, C)
            gates = jax.nn.sigmoid(
                L.mlp(blk["gate_mlp"], out[:, 0]).reshape(W, cfg.l_max, C))
            parts = [jax.nn.silu(out[:, 0:1])]
            for l in range(1, cfg.l_max + 1):
                sl = slice(l * l, (l + 1) * (l + 1))
                parts.append(out[:, sl] * gates[:, None, l - 1])
            return x + jnp.concatenate(parts, axis=1)

        x = x0
        for blk in params["blocks"]:
            x = jax.checkpoint(layer)(x, blk)
        out = L.mlp(params["head"], x[:, 0])[:, 0]
        err = jnp.square(out - targets) * node_mask
        num = jax.lax.psum(err.sum(), ax)
        den = jax.lax.psum(node_mask.sum(), ax)
        return num / jnp.maximum(den, 1.0)

    spec = P(ax if len(ax) > 1 else ax[0])
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh,
        in_specs=(spec,) * 8,
        out_specs=P(),
        check_vma=False,
    ))
    return fn(batch["node_feat"], batch["positions"], batch["targets"],
              batch["node_mask"], batch["src_loc"], batch["dst_loc"],
              batch["edge_mask"], batch["dst_pos"])


# ---------------------------------------------------------------------------
# GraphSAGE ring forward (the paper-representative hillclimb pair)
# ---------------------------------------------------------------------------

def sage_ring_loss(params, batch, cfg, mesh, axes=("data", "model")):
    """GraphSAGE full-graph training with node-sharded features and the same
    owner-bucketed ring reduce-scatter as equiformer (EXPERIMENTS.md §Perf
    P6): replaces the replicate-nodes + psum-per-layer baseline.

    batch: node_feat (N, F) node-sharded, labels/label_mask node-sharded,
    src_loc/dst_loc/edge_mask from bucket_edges_by_owner (sharded dim 0).
    """
    import repro.models.gnn.layers as L2

    ax = tuple(a for a in axes if a in mesh.axis_names)
    Pn = int(np.prod([mesh.shape[a] for a in ax]))

    def body(node_feat, labels, label_mask, src_b, dst_b, emask_b):
        src_b, dst_b, emask_b = (a[0] for a in (src_b, dst_b, emask_b))
        W = node_feat.shape[0]

        def make_contrib():
            def contrib(b, x, blk, pos, dpos, s_b, d_b, m_b):
                s_l, d_l, msk = s_b[b], d_b[b], m_b[b]
                rows = jnp.where(msk[:, None], x[s_l], 0.0)
                return {
                    "num": jax.ops.segment_sum(rows, d_l, num_segments=W),
                    "den": jax.ops.segment_sum(
                        msk.astype(jnp.float32), d_l, num_segments=W),
                }
            return contrib

        h = node_feat
        zero3 = jnp.zeros((W, 3))
        zdpos = jnp.zeros(src_b.shape + (3,))
        for lp in params["layers"]:
            ring = make_ring_layer(make_contrib(), ax, Pn)
            agg = ring(h, {}, zero3, zdpos, src_b, dst_b, emask_b)
            nbr = agg["num"] / jnp.maximum(agg["den"], 1.0)[:, None]
            h = jax.nn.relu(h @ lp["w_self"] + nbr @ lp["w_nbr"] + lp["b"])
        logits = h @ params["head"]
        from repro.models import common as cm

        lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logits.astype(jnp.float32),
                                 labels[:, None], axis=-1)[:, 0]
        nll = (lse - ll) * label_mask
        num = jax.lax.psum(nll.sum(), ax)
        den = jax.lax.psum(label_mask.sum(), ax)
        return num / jnp.maximum(den, 1.0)

    spec = P(ax if len(ax) > 1 else ax[0])
    fn = jax.jit(jax.shard_map(
        body, mesh=mesh, in_specs=(spec,) * 6, out_specs=P(),
        check_vma=False,
    ))
    return fn(batch["node_feat"], batch["labels"], batch["label_mask"],
              batch["src_loc"], batch["dst_loc"], batch["edge_mask"])
