"""Decoder-only LM family: GQA (+optional QKV bias), RoPE, local:global
attention mixes, dense SwiGLU or MoE FFN, KV-cache serving.

Covers the five assigned LM architectures (qwen2.5-14b, gemma3-4b,
granite-8b, phi3.5-moe, moonshot-v1-16b-a3b) from one configurable stack:

* layers are stored stacked (leading L dim) and executed with
  ``lax.scan`` over *periods* of the layer-kind pattern (gemma3's 5 local : 1
  global becomes period = 6 with an unrolled pattern inside the scan body) —
  scan keeps compile time flat across 48-layer configs;
* per-layer remat (configurable policy) + microbatch gradient accumulation
  bound activation memory (the fits-in-fast-memory discipline, DESIGN.md §2);
* tensor parallelism Megatron-style over the ``model`` axis (heads / ffn /
  vocab), data parallelism over ``pod``×``data``; activation sharding is
  annotated with ``common.shard`` so the same code runs unsharded on CPU;
* MoE: top-k routing with capacity dispatch into an (E, C, D) buffer that is
  expert-sharded over ``model`` (expert parallelism), optional shared
  experts (moonshot / DeepSeek style);
* serving: ``prefill`` (flash-attention path) returns a KV cache + last
  logits; ``decode_step`` appends one token; the cache seq dim is sharded
  over ``model`` (flash-decoding style partial softmax via XLA collectives).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import common as cm
from repro.models.common import dp_spec, shard


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_q: int = 4
    n_kv: int = 2
    d_head: int = 64
    d_ff: int = 1024
    vocab: int = 1024
    qkv_bias: bool = False
    tie_embed: bool = False
    # attention pattern: tuple over one period, e.g. ("full",) or
    # ("local",)*5 + ("global",); "local" uses sliding window.
    pattern: tuple = ("full",)
    window: int = 1024
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0
    # MoE (n_experts == 0 -> dense)
    n_experts: int = 0
    top_k: int = 2
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # numerics / execution
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    remat: bool = True
    remat_policy: str = "full"          # "full" | "dots" | "none"
    microbatches: int = 1
    seq_shard_activations: bool = False  # sequence-parallel residuals
    use_flash_kernel: bool = False       # Pallas path (real TPU / tests)
    flash_block: int = 512
    attn_chunk: int = 1024               # > this seq len: chunked/banded attn

    @property
    def moe(self) -> bool:
        return self.n_experts > 0

    @property
    def n_periods(self) -> int:
        """Full pattern periods (scanned); the remainder is unrolled."""
        return self.n_layers // len(self.pattern)

    @property
    def n_remainder(self) -> int:
        return self.n_layers % len(self.pattern)

    def param_count(self) -> int:
        d, dh = self.d_model, self.d_head
        attn = d * (self.n_q + 2 * self.n_kv) * dh + self.n_q * dh * d
        if self.qkv_bias:
            attn += (self.n_q + 2 * self.n_kv) * dh
        if self.moe:
            ff = self.n_experts * 3 * d * self.d_ff_expert + d * self.n_experts
            ff += self.n_shared_experts * 3 * d * self.d_ff_expert
        else:
            ff = 3 * d * self.d_ff
        per_layer = attn + ff + 2 * d
        emb = self.vocab * d * (1 if self.tie_embed else 2)
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """6*N_active*D convention for the MoE roofline (DESIGN/EXPERIMENTS)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        dense_like = dataclasses.replace(self, n_experts=0, d_ff=0).param_count()
        ff_active = (self.top_k + self.n_shared_experts) * 3 * d * self.d_ff_expert
        ff_active += d * self.n_experts  # router
        return dense_like + self.n_layers * ff_active


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(key, cfg: LMConfig):
    L, d, dh = cfg.n_layers, cfg.d_model, cfg.d_head
    ks = cm.split_keys(key, 16)
    pd = cfg.param_dtype
    layers: dict[str, jnp.ndarray] = {
        "ln1": jnp.zeros((L, d), pd),
        "ln2": jnp.zeros((L, d), pd),
        "wq": cm.dense_init(ks[0], (L, d, cfg.n_q * dh), dtype=pd),
        "wk": cm.dense_init(ks[1], (L, d, cfg.n_kv * dh), dtype=pd),
        "wv": cm.dense_init(ks[2], (L, d, cfg.n_kv * dh), dtype=pd),
        "wo": cm.dense_init(ks[3], (L, cfg.n_q * dh, d), dtype=pd),
    }
    if cfg.qkv_bias:
        layers["bq"] = jnp.zeros((L, cfg.n_q * dh), pd)
        layers["bk"] = jnp.zeros((L, cfg.n_kv * dh), pd)
        layers["bv"] = jnp.zeros((L, cfg.n_kv * dh), pd)
    if cfg.moe:
        E, fe = cfg.n_experts, cfg.d_ff_expert
        layers["router"] = cm.dense_init(ks[4], (L, d, E), dtype=jnp.float32)
        layers["we_gate"] = cm.dense_init(ks[5], (L, E, d, fe), in_axis=-2, dtype=pd)
        layers["we_up"] = cm.dense_init(ks[6], (L, E, d, fe), in_axis=-2, dtype=pd)
        layers["we_down"] = cm.dense_init(ks[7], (L, E, fe, d), in_axis=-2, dtype=pd)
        if cfg.n_shared_experts:
            fs = fe * cfg.n_shared_experts
            layers["ws_gate"] = cm.dense_init(ks[8], (L, d, fs), dtype=pd)
            layers["ws_up"] = cm.dense_init(ks[9], (L, d, fs), dtype=pd)
            layers["ws_down"] = cm.dense_init(ks[10], (L, fs, d), dtype=pd)
    else:
        layers["w_gate"] = cm.dense_init(ks[4], (L, d, cfg.d_ff), dtype=pd)
        layers["w_up"] = cm.dense_init(ks[5], (L, d, cfg.d_ff), dtype=pd)
        layers["w_down"] = cm.dense_init(ks[6], (L, cfg.d_ff, d), dtype=pd)
    params = {
        "embed": cm.embed_init(ks[11], (cfg.vocab, d), dtype=pd),
        "final_norm": jnp.zeros((d,), pd),
        "layers": layers,
    }
    if not cfg.tie_embed:
        params["lm_head"] = cm.dense_init(ks[12], (d, cfg.vocab), dtype=pd)
    return params


def param_specs(cfg: LMConfig):
    """PartitionSpecs mirroring init_params (Megatron TP over 'model')."""
    specs_layers = {
        "ln1": P(None, None),
        "ln2": P(None, None),
        "wq": P(None, None, "model"),
        "wk": P(None, None, "model"),
        "wv": P(None, None, "model"),
        "wo": P(None, "model", None),
    }
    if cfg.qkv_bias:
        specs_layers |= {"bq": P(None, "model"), "bk": P(None, "model"),
                         "bv": P(None, "model")}
    if cfg.moe:
        specs_layers |= {
            "router": P(None, None, None),
            "we_gate": P(None, "model", None, None),
            "we_up": P(None, "model", None, None),
            "we_down": P(None, "model", None, None),
        }
        if cfg.n_shared_experts:
            specs_layers |= {
                "ws_gate": P(None, None, "model"),
                "ws_up": P(None, None, "model"),
                "ws_down": P(None, "model", None),
            }
    else:
        specs_layers |= {
            "w_gate": P(None, None, "model"),
            "w_up": P(None, None, "model"),
            "w_down": P(None, "model", None),
        }
    specs = {
        "embed": P("model", None),
        "final_norm": P(None),
        "layers": specs_layers,
    }
    if not cfg.tie_embed:
        specs["lm_head"] = P(None, "model")
    return specs


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _attention_full(q, k, v, positions_q, positions_kv, window, cfg):
    """Reference-path attention: (B, S, H, D) layout; causal (+window)."""
    b, sq, hq, dh = q.shape
    skv = k.shape[1]
    group = hq // k.shape[2]
    kf = jnp.repeat(k, group, axis=2)
    vf = jnp.repeat(v, group, axis=2)
    scale = 1.0 / math.sqrt(dh)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kf.astype(jnp.float32)) * scale
    mask = positions_kv[:, None, :] <= positions_q[:, :, None]   # (B, Sq, Skv)
    if window is not None:
        mask &= positions_kv[:, None, :] > positions_q[:, :, None] - window
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf.astype(jnp.float32))
    return out.astype(q.dtype)


def _decode_attention(q, ck, cv, pos, window, cfg):
    """Flash-decoding style: grouped GQA einsum over the (seq-sharded)
    cache — no KV repeat, softmax partials combine via XLA collectives.

    q: (B, 1, Hq, D); ck/cv: (B, S, Hkv, D); pos: (B,) current position.
    """
    b, _, hq, dh = q.shape
    s, hkv = ck.shape[1], ck.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, ck.astype(jnp.float32)) * scale
    kvpos = jnp.arange(s, dtype=jnp.int32)
    valid = kvpos[None, :] <= pos[:, None]
    if window is not None:
        valid &= kvpos[None, :] > pos[:, None] - window
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, cv.astype(jnp.float32))
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


def _attention(q, k, v, positions_q, positions_kv, window, cfg):
    if cfg.use_flash_kernel and q.shape[1] == k.shape[1]:
        from repro.kernels.flash_attention.ops import flash_attention

        qt = q.transpose(0, 2, 1, 3)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        o = flash_attention(qt, kt, vt, causal=True, window=window,
                            bq=cfg.flash_block, bk=cfg.flash_block,
                            interpret=True)
        return o.transpose(0, 2, 1, 3)
    s = q.shape[1]
    if s > cfg.attn_chunk and s == k.shape[1]:
        from repro.models import attention as att

        if window is not None:
            return att.banded_attention(q, k, v, window=window,
                                        q_chunk=cfg.attn_chunk)
        return att.chunked_attention(q, k, v, causal=True,
                                     q_chunk=cfg.attn_chunk,
                                     k_chunk=cfg.attn_chunk)
    return _attention_full(q, k, v, positions_q, positions_kv, window, cfg)


def _attn_block(x, lp, kind, positions, cfg, cache=None, cache_pos=None):
    """x: (B, S, D).  Returns (out, new_kv) where new_kv is (k, v) to cache."""
    b, s, d = x.shape
    dh = cfg.d_head
    h = cm.rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    # constrain the FLAT head dim (always divisible by the model axis —
    # head counts like qwen's 40 q / 8 kv are not, and per-head constraints
    # force involuntary resharding copies; EXPERIMENTS.md §Perf P2)
    q = shard(q, dp_spec(None, "model"))
    k = shard(k, dp_spec(None, "model"))
    v = shard(v, dp_spec(None, "model"))
    q = q.reshape(b, s, cfg.n_q, dh)
    k = k.reshape(b, s, cfg.n_kv, dh)
    v = v.reshape(b, s, cfg.n_kv, dh)
    theta = cfg.rope_theta_local if kind == "local" else cfg.rope_theta
    q = cm.apply_rope(q, positions, theta)
    k = cm.apply_rope(k, positions, theta)
    window = cfg.window if kind == "local" else None
    if cache is None:
        o = _attention(q, k, v, positions, positions, window, cfg)
        new_kv = (k, v)
    else:
        ck, cv = cache                      # (B, Smax, n_kv, dh)
        # shard-local cache insert: one-hot select along the (sharded) seq
        # dim instead of dynamic_update_slice, which forces a resharding
        # collective when seq is model-sharded (EXPERIMENTS.md §Perf).
        sel = (jnp.arange(ck.shape[1], dtype=jnp.int32)
               == cache_pos)[None, :, None, None]
        ck = jnp.where(sel, k.astype(ck.dtype), ck)
        cv = jnp.where(sel, v.astype(cv.dtype), cv)
        o = _decode_attention(q, ck, cv, positions[:, -1], window, cfg)
        new_kv = (ck, cv)
    o = o.reshape(b, s, cfg.n_q * dh)
    return (o @ lp["wo"]), new_kv


# ---------------------------------------------------------------------------
# FFN (dense / MoE)
# ---------------------------------------------------------------------------

def _dense_ffn(x, lp, cfg):
    h = cm.rms_norm(x, lp["ln2"], cfg.norm_eps)
    g = h @ lp["w_gate"]
    u = h @ lp["w_up"]
    g = shard(g, dp_spec(None, "model"))
    return cm.swiglu(g, u) @ lp["w_down"]


def _moe_ffn(x, lp, cfg):
    """Top-k capacity dispatch; buffer expert-sharded over 'model'."""
    b, s, d = x.shape
    h = cm.rms_norm(x, lp["ln2"], cfg.norm_eps)
    T = b * s
    xt = h.reshape(T, d)
    E, K = cfg.n_experts, cfg.top_k
    C = max(1, int(cfg.capacity_factor * T * K / E))
    logits = (xt.astype(jnp.float32) @ lp["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    topw, tope = jax.lax.top_k(probs, K)                        # (T, K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    fe = tope.reshape(-1)                                       # (T*K,)
    ft = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    fw = topw.reshape(-1)
    # rank of each slot within its expert (cumsum over one-hot)
    oh = jax.nn.one_hot(fe, E, dtype=jnp.int32)                 # (T*K, E)
    rank = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(T * K), fe]
    keep = rank < C
    slot = jnp.where(keep, fe * C + rank, E * C)                # drop slot
    buf = jnp.zeros((E * C + 1, d), xt.dtype).at[slot].add(xt[ft])
    buf = shard(buf[: E * C].reshape(E, C, d), P("model", None, None))
    # constrain the expert einsum RESULTS as well: without this, SPMD
    # partitions the expert matmuls over capacity and replicates experts
    # across 'model' — a measured 14x forward-flop blowup (§Perf P7)
    g = shard(jnp.einsum("ecd,edf->ecf", buf, lp["we_gate"]),
              P("model", None, None))
    u = shard(jnp.einsum("ecd,edf->ecf", buf, lp["we_up"]),
              P("model", None, None))
    y = jnp.einsum("ecf,efd->ecd", cm.swiglu(g, u), lp["we_down"])
    y = shard(y, P("model", None, None)).reshape(E * C, d)
    y = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)], axis=0)
    contrib = y[slot] * fw[:, None].astype(y.dtype)
    out = jnp.zeros((T, d), xt.dtype).at[ft].add(contrib)
    if cfg.n_shared_experts:
        g = xt @ lp["ws_gate"]
        u = xt @ lp["ws_up"]
        out = out + cm.swiglu(g, u) @ lp["ws_down"]
    # auxiliary load-balance loss (Switch-style), returned via stash
    me = probs.mean(axis=0)
    ce_frac = jnp.bincount(fe, length=E).astype(jnp.float32) / (T * K)
    aux = E * jnp.sum(me * ce_frac)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Layer stack (scan over periods)
# ---------------------------------------------------------------------------

def _period_params(params, cfg: LMConfig):
    """Split stacked (L, ...) layer params into (scanned, remainder):
    scanned (n_periods, p, ...) + remainder (n_remainder, ...) (e.g. gemma3's
    34 = 5 full local:local:local:local:local:global periods + 4 layers)."""
    p = len(cfg.pattern)
    nf = cfg.n_periods * p
    scanned = jax.tree.map(
        lambda a: a[:nf].reshape((cfg.n_periods, p) + a.shape[1:]),
        params["layers"])
    rem = jax.tree.map(lambda a: a[nf:], params["layers"])
    return scanned, rem


def _residual_spec(cfg):
    return dp_spec("model", None) if cfg.seq_shard_activations else dp_spec(None, None)


def forward(params, tokens, cfg: LMConfig, positions=None):
    """tokens (B, S) -> logits (B, S, V); training/prefill path."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x = shard(x, _residual_spec(cfg))
    aux0 = jnp.zeros((), jnp.float32)

    def one_layer(x, lp, kind):
        a, _ = _attn_block(x, lp, kind, positions, cfg)
        x = shard(x + a, _residual_spec(cfg))
        if cfg.moe:
            f, aux = _moe_ffn(x, lp, cfg)
        else:
            f, aux = _dense_ffn(x, lp, cfg), jnp.zeros((), jnp.float32)
        x = shard(x + f, _residual_spec(cfg))
        return x, aux

    def apply_layer(x, lp, kind):
        if cfg.remat and cfg.remat_policy != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots" else None)
            return jax.checkpoint(partial(one_layer, kind=kind),
                                  policy=policy)(x, lp)
        return one_layer(x, lp, kind)

    def period_body(carry, period_lp):
        x, aux = carry
        for j, kind in enumerate(cfg.pattern):
            lp = jax.tree.map(lambda a: a[j], period_lp)
            x, aux_j = apply_layer(x, lp, kind)
            aux = aux + aux_j
        return (x, aux), None

    scanned, rem = _period_params(params, cfg)
    (x, aux), _ = jax.lax.scan(period_body, (x, aux0), scanned)
    for j in range(cfg.n_remainder):
        lp = jax.tree.map(lambda a: a[j], rem)
        x, aux_j = apply_layer(x, lp, cfg.pattern[j])
        aux = aux + aux_j
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    logits = x @ head.astype(cfg.compute_dtype)
    logits = shard(logits, dp_spec(None, "model"))
    return logits, aux


def loss_fn(params, batch, cfg: LMConfig):
    logits, aux = forward(params, batch["tokens"], cfg)
    loss = cm.cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss + 0.01 * aux, {"loss": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=None):
    dtype = dtype or cfg.compute_dtype
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((batch,), jnp.int32)}


def cache_specs(cfg: LMConfig, long_context: bool = False):
    if long_context:  # batch too small to shard: shard seq over everything
        seq = ("data", "model")
        return {"k": P(None, None, seq, None, None),
                "v": P(None, None, seq, None, None), "pos": P()}
    return {"k": P(None, ("pod", "data"), "model", None, None),
            "v": P(None, ("pod", "data"), "model", None, None),
            "pos": P(("pod", "data"))}


def prefill(params, tokens, cfg: LMConfig, max_seq: Optional[int] = None):
    """Returns (cache filled for s positions, last-token logits)."""
    b, s = tokens.shape
    if max_seq is None:
        max_seq = s
    elif max_seq < s:
        raise ValueError(f"max_seq={max_seq} is shorter than the prompt "
                         f"(s={s}); the cache would truncate live tokens")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    x = shard(x, _residual_spec(cfg))

    ks, vs = [], []

    def one_layer(x, lp, kind):
        a, (k, v) = _attn_block(x, lp, kind, positions, cfg)
        x = shard(x + a, _residual_spec(cfg))
        f = _moe_ffn(x, lp, cfg)[0] if cfg.moe else _dense_ffn(x, lp, cfg)
        x = shard(x + f, _residual_spec(cfg))
        return x, (k, v)

    def period_body(x, period_lp):
        kvs = []
        for j, kind in enumerate(cfg.pattern):
            lp = jax.tree.map(lambda a: a[j], period_lp)
            fn = partial(one_layer, kind=kind)
            if cfg.remat:
                fn = jax.checkpoint(fn)
            x, kv = fn(x, lp)
            kvs.append(kv)
        k = jnp.stack([k for k, _ in kvs])          # (p, B, S, n_kv, dh)
        v = jnp.stack([v for _, v in kvs])
        return x, (k, v)

    scanned, rem = _period_params(params, cfg)
    x, (k_all, v_all) = jax.lax.scan(period_body, x, scanned)
    # (n_periods, p, B, S, ...) -> (nf, B, S, ...)
    k_all = k_all.reshape((-1,) + k_all.shape[2:])
    v_all = v_all.reshape((-1,) + v_all.shape[2:])
    rem_k, rem_v = [], []
    for j in range(cfg.n_remainder):
        lp = jax.tree.map(lambda a: a[j], rem)
        fn = partial(one_layer, kind=cfg.pattern[j])
        if cfg.remat:
            fn = jax.checkpoint(fn)
        x, (k, v) = fn(x, lp)
        rem_k.append(k)
        rem_v.append(v)
    if rem_k:
        k_all = jnp.concatenate([k_all, jnp.stack(rem_k)], axis=0)
        v_all = jnp.concatenate([v_all, jnp.stack(rem_v)], axis=0)
    pad = max_seq - s
    if pad:
        zeros = jnp.zeros(k_all.shape[:2] + (pad,) + k_all.shape[3:], k_all.dtype)
        k_all = jnp.concatenate([k_all, zeros], axis=2)
        v_all = jnp.concatenate([v_all, zeros], axis=2)
    x = cm.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    logits = x @ head.astype(cfg.compute_dtype)
    cache = {"k": k_all, "v": v_all,
             "pos": jnp.full((b,), s, jnp.int32)}
    return cache, logits[:, 0]


def decode_step(params, cache, tokens, cfg: LMConfig):
    """One decode step: tokens (B,) -> (new_cache, logits (B, V))."""
    b = tokens.shape[0]
    pos = cache["pos"]                                   # (B,)
    positions = pos[:, None]                             # (B, 1)
    x = params["embed"].astype(cfg.compute_dtype)[tokens[:, None]]
    cache_pos = pos[0]                                   # uniform batch pos

    def one_layer(x, lp_kv, kind):
        lp, (ck, cv) = lp_kv
        a, (nk, nv) = _attn_block(x, lp, kind, positions, cfg,
                                  cache=(ck, cv), cache_pos=cache_pos)
        x = x + a
        f = _moe_ffn(x, lp, cfg)[0] if cfg.moe else _dense_ffn(x, lp, cfg)
        return x + f, (nk, nv)

    p = len(cfg.pattern)
    nf = cfg.n_periods * p
    k_p = cache["k"][:nf].reshape((cfg.n_periods, p) + cache["k"].shape[1:])
    v_p = cache["v"][:nf].reshape((cfg.n_periods, p) + cache["v"].shape[1:])

    def period_body(x, scanned):
        period_lp, ck, cv = scanned
        nks, nvs = [], []
        for j, kind in enumerate(cfg.pattern):
            lp = jax.tree.map(lambda a: a[j], period_lp)
            x, (nk, nv) = one_layer(x, (lp, (ck[j], cv[j])), kind)
            nks.append(nk)
            nvs.append(nv)
        return x, (jnp.stack(nks), jnp.stack(nvs))

    scanned_lp, rem_lp = _period_params(params, cfg)
    x, (nk, nv) = jax.lax.scan(period_body, x, (scanned_lp, k_p, v_p))
    nk = nk.reshape((nf,) + cache["k"].shape[1:])
    nv = nv.reshape((nf,) + cache["v"].shape[1:])
    rem_ks, rem_vs = [], []
    for j in range(cfg.n_remainder):
        lp = jax.tree.map(lambda a: a[j], rem_lp)
        x, (k2, v2) = one_layer(
            x, (lp, (cache["k"][nf + j], cache["v"][nf + j])), cfg.pattern[j])
        rem_ks.append(k2)
        rem_vs.append(v2)
    if rem_ks:
        nk = jnp.concatenate([nk, jnp.stack(rem_ks)], axis=0)
        nv = jnp.concatenate([nv, jnp.stack(rem_vs)], axis=0)
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embed else params["lm_head"]
    logits = (x @ head.astype(cfg.compute_dtype))[:, 0]
    new_cache = {"k": nk, "v": nv, "pos": pos + 1}
    return new_cache, logits
