"""Deep Interest Network [arXiv:1706.06978] — the assigned recsys arch.

Exact assigned dims: embed_dim=18, seq_len=100, attn MLP 80-40,
final MLP 200-80, target attention interaction.  Vocabulary sizes follow
the DIN paper's scale (10M items / 1k categories; DESIGN.md §7).

Shapes served: train_batch (B=65536 BCE training), serve_p99 (B=512),
serve_bulk (B=262144), retrieval_cand (1 user × 1M candidates, scored by
chunked scan — a batched-dot-plus-attention sweep, not a loop).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import common as cm
from repro.models.common import dp_spec, shard
from repro.models.gnn.layers import mlp, mlp_init


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    n_items: int = 10_000_000
    n_cats: int = 1_000
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: tuple = (80, 40)
    mlp: tuple = (200, 80)
    cand_chunks: int = 1024       # scan chunks for retrieval scoring
    sharded_tables: bool = True   # use the shard_map lookup path

    def param_count(self) -> int:
        d = self.embed_dim
        attn = (4 * d + 1) * self.attn_mlp[0] + \
               (self.attn_mlp[0] + 1) * self.attn_mlp[1] + self.attn_mlp[1] + 1
        head_in = 3 * d
        head = (head_in + 1) * self.mlp[0] + (self.mlp[0] + 1) * self.mlp[1] \
               + self.mlp[1] + 1
        return (self.n_items + self.n_cats) * d + attn + head


def din_init(key, cfg: DINConfig):
    ks = cm.split_keys(key, 4)
    d = cfg.embed_dim
    return {
        "item_emb": cm.embed_init(ks[0], (cfg.n_items, d)),
        "cat_emb": cm.embed_init(ks[1], (cfg.n_cats, d)),
        "attn": mlp_init(ks[2], [4 * d, *cfg.attn_mlp, 1]),
        "head": mlp_init(ks[3], [3 * d, *cfg.mlp, 1]),
    }


def param_specs(cfg: DINConfig):
    return {
        "item_emb": P("model", None),
        "cat_emb": P(None, None),       # tiny: replicate
        "attn": [(P(None, None), P(None))] * 3,
        "head": [(P(None, None), P(None))] * 3,
    }


def _lookup(params, cfg, item_ids, cat_ids):
    from repro.models.recsys import embedding as emb

    if cfg.sharded_tables and cm.current_mesh() is not None:
        e_i = emb.sharded_lookup(params["item_emb"], item_ids)
    else:
        e_i = jnp.take(params["item_emb"], item_ids, axis=0)
    e_c = jnp.take(params["cat_emb"], cat_ids, axis=0)
    return e_i + e_c


def _target_attention(params, e_hist, hist_mask, e_cand):
    """DIN's adaptive interest: a(e_h, e_c) MLP, un-normalized weighted sum."""
    L = e_hist.shape[-2]
    e_c = jnp.broadcast_to(e_cand[..., None, :], e_hist.shape)
    feats = jnp.concatenate(
        [e_hist, e_c, e_hist - e_c, e_hist * e_c], axis=-1)
    w = mlp(params["attn"], feats)[..., 0]               # (..., L)
    w = jax.nn.sigmoid(w) * hist_mask
    return jnp.einsum("...l,...ld->...d", w, e_hist)


def din_scores(params, batch, cfg: DINConfig):
    """Click logits: batch has hist_items/hist_cats (B, L), cand_item/cat (B,)."""
    e_hist = _lookup(params, cfg, batch["hist_items"], batch["hist_cats"])
    e_cand = _lookup(params, cfg, batch["cand_item"], batch["cand_cat"])
    mask = batch.get("hist_mask")
    if mask is None:
        mask = jnp.ones(batch["hist_items"].shape, jnp.float32)
    e_hist = shard(e_hist, dp_spec(None, None))
    user = _target_attention(params, e_hist, mask, e_cand)
    z = jnp.concatenate([user, e_cand, user * e_cand], axis=-1)
    return mlp(params["head"], z)[..., 0]


def din_loss(params, batch, cfg: DINConfig):
    logits = din_scores(params, batch, cfg)
    return cm.bce_with_logits(logits, batch["label"])


def din_retrieval(params, batch, cfg: DINConfig):
    """Score 1M candidates for one user: chunked scan (batched dot+attn)."""
    e_hist = _lookup(params, cfg, batch["hist_items"], batch["hist_cats"])  # (1, L, D)
    mask = batch.get("hist_mask")
    if mask is None:
        mask = jnp.ones(batch["hist_items"].shape, jnp.float32)
    cand_items = batch["cand_items"]          # (Ncand,)
    cand_cats = batch["cand_cats"]
    n = cand_items.shape[0]
    k = cfg.cand_chunks
    if n % k:
        raise ValueError(f"candidate count n={n} must be divisible by "
                         f"cfg.cand_chunks={k}")

    def chunk(carry, ids):
        ci, cc = ids
        e_c = _lookup(params, cfg, ci, cc)                 # (nc, D)
        eh = jnp.broadcast_to(e_hist, (e_c.shape[0],) + e_hist.shape[1:])
        mm = jnp.broadcast_to(mask, (e_c.shape[0],) + mask.shape[1:])
        user = _target_attention(params, eh, mm, e_c)
        z = jnp.concatenate([user, e_c, user * e_c], axis=-1)
        return carry, mlp(params["head"], z)[..., 0]

    _, scores = jax.lax.scan(
        chunk, None,
        (cand_items.reshape(k, n // k), cand_cats.reshape(k, n // k)),
    )
    return scores.reshape(n)
