"""Row-sharded embedding tables (the recsys scale trick).

JAX has no EmbeddingBag / CSR gather; the production pattern is:

* baseline (pjit): ``jnp.take`` on a table constrained P('model', None) —
  XLA typically all-gathers the table (collective ∝ table size);
* optimized (shard_map): mod-sharded rows, each device gathers the ids it
  owns and a psum over 'model' combines — collective ∝ batch·dim, which is
  orders of magnitude smaller for 10M-row tables.  This is the §Perf lever
  for the DIN cells.

Bag lookups (multi-hot -> mean) additionally route through the Pallas
embedding_bag kernel on real TPUs.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import current_mesh, shard


def take_baseline(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """pjit path: constraint + take; XLA chooses the collective."""
    table = shard(table, P("model", None))
    return jnp.take(table, ids, axis=0)


def sharded_lookup(table: jnp.ndarray, ids: jnp.ndarray,
                   mesh=None, axis: str = "model") -> jnp.ndarray:
    """shard_map path: local masked gather + one psum over the table axis.

    table rows are block-sharded over ``axis``; ids/out replicated over it
    (they may be sharded over data axes outside this function).
    """
    mesh = mesh or current_mesh()
    if mesh is None or axis not in mesh.axis_names:
        return jnp.take(table, ids, axis=0)
    p = mesh.shape[axis]
    V = table.shape[0]
    if V % p:
        raise ValueError(f"vocab rows V={V} must be divisible by the "
                         f"{p}-way '{axis}' mesh axis for row sharding")
    rows = V // p
    other = tuple(a for a in mesh.axis_names if a != axis)

    def body(tbl_loc, ids):
        dev = jax.lax.axis_index(axis)
        lo = dev * rows
        loc = jnp.clip(ids - lo, 0, rows - 1)
        vals = jnp.take(tbl_loc, loc, axis=0)
        owned = (ids >= lo) & (ids < lo + rows)
        vals = jnp.where(owned[..., None], vals, 0)
        return jax.lax.psum(vals, axis)

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(axis, None), P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn(table, ids)
