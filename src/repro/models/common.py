"""Shared model building blocks (pure-JAX, pytree params, mesh-aware).

Sharding discipline: model code annotates activations with
``shard(x, PartitionSpec(...))`` which is a no-op when no mesh is active
(CPU smoke tests) and a ``with_sharding_constraint`` under the production
mesh (dry-run / training).  Batch-like dims use ``dp_axes()`` which resolves
to ``('pod', 'data')`` on the multi-pod mesh and ``('data',)`` on one pod.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Mesh helpers
# ---------------------------------------------------------------------------

def current_mesh():
    """The active mesh (physical `with mesh:` or use_mesh), else None."""
    try:
        from jax._src import mesh as mesh_lib

        pm = mesh_lib.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:
        pass
    am = jax.sharding.get_abstract_mesh()
    if am is not None and not am.empty:
        return am
    return None


def mesh_axis_names() -> tuple[str, ...]:
    m = current_mesh()
    return tuple(m.axis_names) if m is not None else ()


def dp_axes() -> tuple[str, ...]:
    """Data-parallel axes present on the active mesh, pod-major."""
    names = mesh_axis_names()
    return tuple(a for a in ("pod", "data") if a in names)


def dp_spec(*rest) -> P:
    """P(dp_axes(), *rest) — batch dim over all data axes."""
    axes = dp_axes()
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return P(lead, *rest)


def shard(x: jnp.ndarray, spec: P) -> jnp.ndarray:
    """with_sharding_constraint when a mesh is active, else identity."""
    if current_mesh() is None:
        return x
    # Drop axes that don't exist on this mesh (e.g. 'pod' on single pod).
    names = set(mesh_axis_names())

    def fix(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    spec = P(*(fix(e) for e in spec))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = -2, dtype=jnp.float32, scale=1.0):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def swiglu(gate, up):
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float64) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., S, H, D) rotary on last dim; positions: (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)        # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Token-mean CE in f32; logits (..., V), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def bce_with_logits(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def finite_check(tree) -> jnp.ndarray:
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)
              if jnp.issubdtype(x.dtype, jnp.floating)]
    return jnp.all(jnp.stack(leaves)) if leaves else jnp.asarray(True)
