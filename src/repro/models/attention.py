"""Memory-bounded attention paths for long sequences (pure jnp).

The Pallas flash kernel targets real TPUs; on the CPU host platform the
dry-run lowers these mathematically identical scan-based formulations:

* ``chunked_attention`` — FlashAttention-style online softmax over
  (q_chunk × k_chunk) tiles via lax.scan: peak memory O(bq·bk) per
  (batch, head) instead of O(S²).  Causal block skipping is done by
  masking; the roofline accounts the full rectangle (see EXPERIMENTS.md
  §Perf for the causal-skip iteration).
* ``banded_attention`` — sliding-window layers (gemma3 local): each q chunk
  attends to a static band [chunk_start - window, chunk_end), gathered with
  dynamic_slice — O(S·(W+bq)) work, the window-limited cost the local
  pattern is designed for.

Both support GQA via head-group reshape without materializing repeated KV.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

_NEG = -1e30


def _gqa_split(q, k, v):
    """(B,S,Hq,D),(B,S,Hk,D) -> grouped (B,Hk,G,S,D) forms."""
    b, sq, hq, d = q.shape
    hk = k.shape[2]
    g = hq // hk
    qg = q.reshape(b, sq, hk, g, d).transpose(0, 2, 3, 1, 4)   # B,Hk,G,Sq,D
    kg = k.transpose(0, 2, 1, 3)                               # B,Hk,Sk,D
    vg = v.transpose(0, 2, 1, 3)
    return qg, kg, vg, g


def chunked_attention(q, k, v, *, causal=True, q_chunk=512, k_chunk=1024,
                      positions_q=None, positions_kv=None):
    """Online-softmax attention; layouts (B, S, H, D) in/out."""
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, skv)
    if sq % q_chunk or skv % k_chunk:
        raise ValueError(f"chunk sizes must divide the sequence lengths: "
                         f"sq={sq} %% q_chunk={q_chunk}, "
                         f"skv={skv} %% k_chunk={k_chunk}")
    nq, nk = sq // q_chunk, skv // k_chunk
    scale = 1.0 / math.sqrt(d)
    qg, kg, vg, g = _gqa_split(q, k, v)
    if positions_q is None:
        positions_q = jnp.arange(sq, dtype=jnp.int32)
    if positions_kv is None:
        positions_kv = jnp.arange(skv, dtype=jnp.int32)

    def q_block(qb, pq):
        # qb: (B,Hk,G,bq,D); scan over k chunks with running (m, l, acc)
        def kv_step(carry, idx):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(kg, idx * k_chunk, k_chunk, 2)
            vb = jax.lax.dynamic_slice_in_dim(vg, idx * k_chunk, k_chunk, 2)
            pk = jax.lax.dynamic_slice_in_dim(positions_kv, idx * k_chunk,
                                              k_chunk, 0)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            if causal:
                mask = pk[None, :] <= pq[:, None]               # (bq, bk)
                s = jnp.where(mask[None, None, None], s, _NEG)
            m2 = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m2)
            p = jnp.exp(s - m2[..., None])
            l2 = l * alpha + p.sum(-1)
            acc2 = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
            return (m2, l2, acc2), None

        m0 = jnp.full((b, kg.shape[1], g, q_chunk), _NEG, jnp.float32)
        l0 = jnp.zeros((b, kg.shape[1], g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kg.shape[1], g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    def q_step(_, i):
        qb = jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, 3)
        pq = jax.lax.dynamic_slice_in_dim(positions_q, i * q_chunk, q_chunk, 0)
        return None, q_block(qb, pq)

    _, blocks = jax.lax.scan(q_step, None, jnp.arange(nq))
    # blocks: (nq, B, Hk, G, bq, D) -> (B, S, Hq, D)
    out = blocks.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, hq, d)
    return out.astype(q.dtype)


def banded_attention(q, k, v, *, window, q_chunk=512):
    """Sliding-window causal attention: q chunk i sees k[i*bq - W, i*bq + bq)."""
    b, s, hq, d = q.shape
    q_chunk = min(q_chunk, s)
    if s % q_chunk:
        raise ValueError(f"q_chunk={q_chunk} must divide the sequence "
                         f"length s={s}")
    nq = s // q_chunk
    scale = 1.0 / math.sqrt(d)
    qg, kg, vg, g = _gqa_split(q, k, v)
    W = window
    band = W + q_chunk                       # static band width
    # pad keys at the front so every band slice is in range
    kp = jnp.pad(kg, ((0, 0), (0, 0), (W, 0), (0, 0)))
    vp = jnp.pad(vg, ((0, 0), (0, 0), (W, 0), (0, 0)))

    def q_step(_, i):
        qb = jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, 3)
        kb = jax.lax.dynamic_slice_in_dim(kp, i * q_chunk, band, 2)
        vb = jax.lax.dynamic_slice_in_dim(vp, i * q_chunk, band, 2)
        s_ = jnp.einsum("bhgqd,bhkd->bhgqk", qb.astype(jnp.float32),
                        kb.astype(jnp.float32)) * scale
        pq = i * q_chunk + jnp.arange(q_chunk)
        pk = i * q_chunk - W + jnp.arange(band)
        mask = (pk[None, :] <= pq[:, None]) & (pk[None, :] > pq[:, None] - W) \
               & (pk[None, :] >= 0)
        s_ = jnp.where(mask[None, None, None], s_, _NEG)
        p = jax.nn.softmax(s_, axis=-1)
        ob = jnp.einsum("bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
        return None, ob

    _, blocks = jax.lax.scan(q_step, None, jnp.arange(nq))
    out = blocks.transpose(1, 0, 4, 2, 3, 5).reshape(b, s, hq, d)
    return out.astype(q.dtype)
