"""Synthetic DIN batches: zipf item popularity, per-user category interest
clusters (so the target-attention signal is learnable), deterministic per
(step, shard) like the token stream."""

from __future__ import annotations

import numpy as np


class RecsysStream:
    def __init__(self, n_items: int, n_cats: int, seq_len: int,
                 global_batch: int, seed: int = 0):
        self.n_items = n_items
        self.n_cats = n_cats
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        rng = np.random.default_rng(seed)
        self.item_cat = rng.integers(0, n_cats, n_items).astype(np.int32)

    def _items(self, rng, shape):
        # zipf-ish via pareto floor
        r = rng.pareto(1.3, shape) + 1
        return np.minimum((r * 17).astype(np.int64), self.n_items - 1).astype(np.int32)

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        b = self.global_batch // n_shards
        rng = np.random.default_rng(
            (self.seed * 9_999_991 + step) * 65_537 + shard)
        hist = self._items(rng, (b, self.seq_len))
        cand = self._items(rng, (b,))
        # label: click iff candidate's category appears often in history
        same = (self.item_cat[hist] == self.item_cat[cand][:, None]).mean(1)
        label = (same + rng.normal(0, 0.1, b) > 0.12).astype(np.float32)
        return {
            "hist_items": hist,
            "hist_cats": self.item_cat[hist],
            "cand_item": cand,
            "cand_cat": self.item_cat[cand],
            "hist_mask": np.ones((b, self.seq_len), np.float32),
            "label": label,
        }

    def retrieval_batch(self, n_candidates: int, seed: int = 0) -> dict:
        rng = np.random.default_rng(seed)
        hist = self._items(rng, (1, self.seq_len))
        cands = self._items(rng, (n_candidates,))
        return {
            "hist_items": hist,
            "hist_cats": self.item_cat[hist],
            "hist_mask": np.ones((1, self.seq_len), np.float32),
            "cand_items": cands,
            "cand_cats": self.item_cat[cands],
        }
