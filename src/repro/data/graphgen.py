"""Synthetic graph generators (the data pipeline for the paper's workload
and for the GNN shapes).  All host-side numpy; deterministic per seed.

* ``rmat`` — power-law graphs (Kronecker / R-MAT), the shape of the paper's
  web/social datasets (heavy-tailed degrees, high clustering in cores);
* ``erdos_renyi`` — flat-degree control;
* ``planted_cliques`` — community graphs with known dense cores (ground
  truth for truss-decomposition sanity: planted q-clique => q-truss);
* ``mesh2d`` — triangulated grid (MeshGraphNet-like geometry);
* per-shape GNN batch builders producing the static padded dict format.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import graph as glib


def erdos_renyi(n: int, m_target: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    m = int(m_target * 1.15) + 16
    u = rng.integers(0, n, m * 2, dtype=np.int64)
    v = rng.integers(0, n, m * 2, dtype=np.int64)
    e = glib.canonical_edges(np.stack([u, v], 1), n)
    return e[:m_target] if len(e) > m_target else e


def rmat(scale: int, edge_factor: int = 16, seed: int = 0,
         a=0.57, b=0.19, c=0.19) -> tuple[int, np.ndarray]:
    """R-MAT generator (Graph500 parameters by default)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    for bit in range(scale):
        r = rng.random(m)
        # quadrant probabilities (a, b, c, d)
        go_right = (r >= a) & (r < a + b) | (r >= a + b + c)
        go_down = r >= a + b
        src |= go_down.astype(np.int64) << bit
        dst |= go_right.astype(np.int64) << bit
    e = glib.canonical_edges(np.stack([src, dst], 1), n)
    return n, e


def planted_cliques(n: int, n_cliques: int, clique_size: int,
                    noise_edges: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    edges = []
    for i in range(n_cliques):
        verts = rng.choice(n, clique_size, replace=False)
        iu = np.triu_indices(clique_size, 1)
        edges.append(np.stack([verts[iu[0]], verts[iu[1]]], 1))
    u = rng.integers(0, n, noise_edges)
    v = rng.integers(0, n, noise_edges)
    edges.append(np.stack([u, v], 1))
    return glib.canonical_edges(np.concatenate(edges), n)


def mesh2d(rows: int, cols: int) -> tuple[int, np.ndarray, np.ndarray]:
    """Triangulated grid: returns (n, edges, positions (n, 3))."""
    n = rows * cols
    idx = np.arange(n).reshape(rows, cols)
    e = []
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], 1))
    e.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], 1))
    e.append(np.stack([idx[:-1, :-1].ravel(), idx[1:, 1:].ravel()], 1))
    edges = glib.canonical_edges(np.concatenate(e), n)
    xy = np.stack(np.meshgrid(np.arange(rows), np.arange(cols), indexing="ij"),
                  -1).reshape(n, 2).astype(np.float32)
    pos = np.concatenate([xy, np.zeros((n, 1), np.float32)], 1)
    return n, edges, pos


# ---------------------------------------------------------------------------
# GNN batch builders (static padded dict format of models/gnn)
# ---------------------------------------------------------------------------

def _directed(edges: np.ndarray) -> np.ndarray:
    return np.concatenate([edges, edges[:, ::-1]]).astype(np.int32)


def gnn_full_batch(n: int, edges: np.ndarray, d_feat: int, n_classes: int,
                   seed: int = 0, positions: Optional[np.ndarray] = None,
                   regression: bool = False) -> dict:
    rng = np.random.default_rng(seed)
    ei = _directed(edges)
    batch = {
        "node_feat": rng.standard_normal((n, d_feat)).astype(np.float32),
        "edge_index": ei,
        "edge_mask": np.ones(len(ei), bool),
        "positions": (positions if positions is not None
                      else rng.standard_normal((n, 3)).astype(np.float32)),
    }
    if regression:
        batch["targets"] = rng.standard_normal(n).astype(np.float32)
        batch["node_mask"] = np.ones(n, np.float32)
    else:
        batch["labels"] = rng.integers(0, n_classes, n).astype(np.int32)
        batch["label_mask"] = (rng.random(n) < 0.5).astype(np.float32)
    # MeshGraphNet extras
    pos = batch["positions"]
    rel = pos[ei[:, 1]] - pos[ei[:, 0]]
    batch["edge_feat"] = np.concatenate(
        [rel, np.linalg.norm(rel, axis=1, keepdims=True)], 1).astype(np.float32)
    batch["targets_vec"] = rng.standard_normal((n, 3)).astype(np.float32)
    return batch


def gnn_molecule_batch(n_graphs: int, n_nodes: int, n_edges: int,
                       d_feat: int, seed: int = 0) -> dict:
    """Batched small graphs flattened into one disjoint padded graph."""
    rng = np.random.default_rng(seed)
    all_edges = []
    for g in range(n_graphs):
        e = erdos_renyi(n_nodes, n_edges // 2, seed + 7 * g + 1)
        all_edges.append(_directed(e) + g * n_nodes)
    ei = np.concatenate(all_edges).astype(np.int32)
    n = n_graphs * n_nodes
    b = gnn_full_batch(n, np.zeros((0, 2), np.int64), d_feat, 2, seed,
                       regression=True)
    b["edge_index"] = ei
    b["edge_mask"] = np.ones(len(ei), bool)
    pos = b["positions"]
    rel = pos[ei[:, 1]] - pos[ei[:, 0]]
    b["edge_feat"] = np.concatenate(
        [rel, np.linalg.norm(rel, axis=1, keepdims=True)], 1).astype(np.float32)
    return b
