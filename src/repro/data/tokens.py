"""Synthetic LM token pipeline: deterministic, shardable, restart-safe.

Zipf-distributed tokens with a simple induced structure (each token biases
the next) so cross-entropy actually decreases during the example training
runs.  Batches are generated per (step, shard) — any host can deterministically
re-produce any shard's batch, which is the straggler/elastic story for the
data layer (DESIGN.md §5): no data server, no state to lose.
"""

from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, zipf_a: float = 1.2):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.zipf_a = zipf_a
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self.probs = ranks ** (-zipf_a)
        self.probs /= self.probs.sum()
        # deterministic "grammar": token t prefers successor perm[t]
        self.perm = rng.permutation(vocab)

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> dict:
        """The (step, shard) batch — identical regardless of which host asks."""
        b = self.global_batch // n_shards
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard)
        toks = np.empty((b, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.choice(self.vocab, size=b, p=self.probs)
        follow = rng.random((b, self.seq_len)) < 0.5
        rand_next = rng.choice(self.vocab, size=(b, self.seq_len), p=self.probs)
        for t in range(self.seq_len):
            toks[:, t + 1] = np.where(
                follow[:, t], self.perm[toks[:, t]], rand_next[:, t])
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
        }
