"""TRK101 donation safety.

PR 4's worst bug: a failed ``PendingPeel`` finalize was retried, re-running
a kernel whose input buffers had been DONATED by ``jax.jit(...,
donate_argnums=...)`` on the first attempt — the retry read dead device
memory.  The fix was the consumed/poisoned handle pattern
(``PendingPeel.result``): clear the callable before invoking it, poison the
handle on failure, never re-invoke.

The static form of that class: once a variable has been passed in a
donated position of a donating call, reading it again (including passing
it to the same call a second time, or looping over the call without
rebuilding the buffer) is a use of donated memory.  Reassignment clears
the taint — rebuilding the buffer every round is exactly the discipline
the peel drivers follow.

Scope and limits (DESIGN.md §14): donating callables are discovered from
module-level ``X = jax.jit(..., donate_argnums=...)`` bindings, donating
``@jax.jit`` / ``@partial(jax.jit, ...)`` decorators, and the configured
cross-module registry; only *bare-name* donated arguments are tracked
(``f(jnp.asarray(x))`` builds a fresh operand and is always safe);
statement order approximates control flow, so a read in an earlier
``except`` branch is out of scope — the runtime consumed/poisoned pattern
covers that half of the class.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.analysis import framework as fw


@dataclasses.dataclass
class _Donor:
    name: str                      # callable name (trailing segment)
    positions: Tuple[int, ...]     # donated positional indices


def _positional_params(args_obj: ast.arguments) -> Tuple[str, ...]:
    """Positional parameter names of a def/lambda, call-position order."""
    return tuple(a.arg for a in (*args_obj.posonlyargs, *args_obj.args))


def _names_from_spec(val: ast.AST) -> Optional[Tuple[str, ...]]:
    """String literal(s) of a ``donate_argnames=`` spec, or None when any
    element is dynamic."""
    if isinstance(val, ast.Constant) and isinstance(val.value, str):
        return (val.value,)
    if isinstance(val, (ast.Tuple, ast.List)):
        out = []
        for elt in val.elts:
            if not (isinstance(elt, ast.Constant)
                    and isinstance(elt.value, str)):
                return None
            out.append(elt.value)
        return tuple(out) if out else None
    return None


def _donate_positions(
        call: ast.Call,
        params: Optional[Tuple[str, ...]] = None,
) -> Optional[Tuple[int, ...]]:
    """Donated arg indices of a ``jax.jit(...)`` call, if any.

    ``donate_argnames`` donates by *name*; ``params`` carries the wrapped
    callable's positional parameter names (from the decorated def, the
    module-level def bound in the same module, or an inline lambda) so the
    names resolve to call positions.  Only when no parameter list is in
    view does the rule fall back to the repo's position-0 convention.
    """
    if fw.call_name(call).split(".")[-1] != "jit":
        return None
    for kw in call.keywords:
        if kw.arg not in ("donate_argnums", "donate_argnames"):
            continue
        val = kw.value
        if kw.arg == "donate_argnames":
            names = _names_from_spec(val)
            if params is None and call.args and isinstance(call.args[0],
                                                           ast.Lambda):
                params = _positional_params(call.args[0].args)
            if names is not None and params is not None:
                resolved = tuple(i for i, p in enumerate(params)
                                 if p in names)
                if resolved:
                    return resolved
            return (0,)        # unresolvable: assume the convention
        if isinstance(val, ast.Constant) and isinstance(val.value, int):
            return (val.value,)
        if isinstance(val, (ast.Tuple, ast.List)):
            out = []
            for elt in val.elts:
                if isinstance(elt, ast.Constant) and isinstance(
                        elt.value, int):
                    out.append(elt.value)
            return tuple(out) if out else (0,)
        return (0,)            # dynamic spec: assume the convention
    return None


def _decorator_donations(func: ast.AST) -> Optional[Tuple[int, ...]]:
    """Donations declared by ``@jax.jit(...)`` or ``@partial(jax.jit, ...)``
    decorators on a function definition.  ``donate_argnames`` resolves
    against the decorated def's own parameter list."""
    params = _positional_params(func.args)
    for dec in getattr(func, "decorator_list", ()):
        if not isinstance(dec, ast.Call):
            continue
        pos = _donate_positions(dec, params=params)
        if pos is not None:
            return pos
        if fw.call_name(dec).split(".")[-1] == "partial" and dec.args:
            inner_name = fw.dotted_name(dec.args[0]).split(".")[-1]
            if inner_name == "jit":
                for kw in dec.keywords:
                    if kw.arg in ("donate_argnums", "donate_argnames"):
                        fake = ast.Call(func=ast.Name(id="jit",
                                                      ctx=ast.Load()),
                                        args=[], keywords=[kw])
                        return _donate_positions(fake,
                                                 params=params) or (0,)
    return None


def _module_donors(module: fw.Module, config) -> Dict[str, _Donor]:
    donors: Dict[str, _Donor] = {}
    # module-level defs, so X = jax.jit(fn, donate_argnames=("b",)) can
    # resolve the names against fn's parameter list
    defs: Dict[str, ast.AST] = {
        n.name: n for n in module.tree.body
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            params = None
            wrapped = node.value.args[0] if node.value.args else None
            if isinstance(wrapped, ast.Name) and wrapped.id in defs:
                params = _positional_params(defs[wrapped.id].args)
            pos = _donate_positions(node.value, params=params)
            if pos is not None:
                for name in fw.assigned_names(node.targets[0]):
                    donors[name] = _Donor(name, pos)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            pos = _decorator_donations(node)
            if pos is not None:
                donors[node.name] = _Donor(node.name, pos)
    for name in config.known_donating_callables:
        donors.setdefault(name, _Donor(name, (0,)))
    return donors


class DonationSafetyRule(fw.Rule):
    """TRK101: reads of a buffer after it was donated to a jitted call."""

    rule_id = "TRK101"
    summary = ("variable read after being passed in a donated position "
               "of a jit(donate_argnums=...) call")

    def check(self, module: fw.Module, config) -> List[fw.Finding]:
        donors = _module_donors(module, config)
        if not donors:
            return []
        findings: List[fw.Finding] = []
        funcs = [n for n in ast.walk(module.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for func in funcs:
            findings.extend(self._check_scope(module, func, donors))
        return findings

    def _check_scope(self, module: fw.Module, func: ast.AST,
                     donors: Dict[str, _Donor]) -> List[fw.Finding]:
        findings: List[fw.Finding] = []
        # nodes belonging to nested defs are a different execution time;
        # exclude them from this scope's linear order
        own_nodes = []
        for node in ast.walk(func):
            if node is func:
                continue
            skip = False
            for p in fw.parents(node):
                if p is func:
                    break
                if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                    skip = True
                    break
            if not skip:
                own_nodes.append(node)

        donating_calls: List[Tuple[ast.Call, _Donor]] = []
        for node in own_nodes:
            if isinstance(node, ast.Call):
                donor = donors.get(fw.call_name(node).split(".")[-1])
                if donor is not None:
                    donating_calls.append((node, donor))

        assign_lines: Dict[str, List[int]] = {}
        for node in own_nodes:
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign,
                                   ast.For, ast.AsyncFor)):
                targets = [node.target]
            elif isinstance(node, ast.withitem) and node.optional_vars:
                targets = [node.optional_vars]
            for t in targets:
                lineno = getattr(node, "lineno", None) or t.lineno
                for name in fw.assigned_names(t):
                    assign_lines.setdefault(name, []).append(lineno)

        for call, donor in donating_calls:
            for idx in donor.positions:
                if idx >= len(call.args):
                    continue
                arg = call.args[idx]
                if not isinstance(arg, ast.Name):
                    continue  # fresh expression: a new buffer every call
                name = arg.id
                rebinds = assign_lines.get(name, [])
                # (a) donated inside a loop without rebuilding the buffer
                # in that loop: iteration 2 donates dead memory
                for loop in fw.enclosing_loops(call):
                    rebuilt = any(loop.lineno <= ln <= loop.end_lineno
                                  for ln in rebinds)
                    if not rebuilt:
                        findings.append(self.finding(
                            module, call,
                            f"`{name}` is donated to `{donor.name}` inside "
                            f"a loop but never rebuilt in the loop body — "
                            f"the second iteration re-donates a consumed "
                            f"buffer; rebuild `{name}` each iteration or "
                            f"use the consumed/poisoned handle pattern "
                            f"(PendingPeel.result)"))
                        break
                # (b) read after the donating call with no rebind between
                for node in own_nodes:
                    if (isinstance(node, ast.Name) and node.id == name
                            and isinstance(node.ctx, ast.Load)
                            and node is not arg
                            and node.lineno > call.lineno):
                        rebound = any(call.lineno < ln <= node.lineno
                                      for ln in rebinds)
                        if not rebound:
                            findings.append(self.finding(
                                module, node,
                                f"`{name}` read after being donated to "
                                f"`{donor.name}` at line {call.lineno} — "
                                f"the buffer is consumed; rebuild it or "
                                f"clear the reference before reuse "
                                f"(the PR-4 PendingPeel retry bug class)"))
                            break  # one finding per donated name per call
        return findings
