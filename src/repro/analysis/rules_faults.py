"""TRK106 fault-site coverage.

PR 6 taught the engines to fail on purpose (``core/faults.py``): every
recovery path is only testable because its failure point carries a
``faults.check(site, **ctx)`` hook with a *registered* site name.  The
coverage rots in two ways this rule pins down statically:

* a new dispatch/finalize/checkpoint/partitioner code path lands without
  its hook (the ROADMAP's open item about ``partitioned_support`` failing
  hard is exactly this gap), so fault plans silently can't reach it;
* a hook is added with an unregistered site string, so plans targeting
  the documented sites never match it.

Checks:

1. every ``faults.check(...)`` call names a site registered in
   ``core/faults.py`` (string literal or ``faults.CONSTANT``);
2. the configured functions (``peel_classes_batched``,
   ``PendingPeel.result``, ``_partition_rounds``, ``manager.save``, ...)
   contain a ``faults.check`` hook for their required site;
3. in the OOC driver modules, every dispatch-capable peel call passes
   ``fault_ctx=`` so injection plans can target it by stage/round/level.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Set

from repro.analysis import framework as fw

# fallback registry when core/faults.py is out of view (fixture tests run
# the rule on snippets in a temp dir); mirrors the module's constants
_BUILTIN_SITES: Dict[str, str] = {
    "DISPATCH": "dispatch",
    "FINALIZE": "finalize",
    "CHECKPOINT_WRITE": "checkpoint-write",
    "PARTITIONER": "partitioner",
    "SUPPORT": "support",
    "CHUNK_READ": "chunk-read",
    "CHUNK_WRITE": "chunk-write",
}


def _registered_sites(module: fw.Module, config) -> Dict[str, str]:
    """Constant-name -> site-string registry parsed from the faults
    module, resolved relative to the checked file's repo root."""
    norm = Path(module.path.replace("\\", "/"))
    for parent in norm.parents:
        cand = parent / config.faults_module
        if cand.is_file():
            parsed = fw.parse_module(cand)
            if parsed is None:
                break
            out: Dict[str, str] = {}
            for node in parsed.tree.body:
                if (isinstance(node, ast.Assign)
                        and isinstance(node.value, ast.Constant)
                        and isinstance(node.value.value, str)):
                    for name in fw.assigned_names(node.targets[0]):
                        if name.isupper():
                            out[name] = node.value.value
            if out:
                return out
            break
    return dict(_BUILTIN_SITES)


def _is_faults_check(call: ast.Call) -> bool:
    name = fw.call_name(call)
    parts = name.split(".")
    return parts[-1] == "check" and len(parts) > 1 and "faults" in parts[-2]


def _site_of(call: ast.Call) -> Optional[ast.AST]:
    if call.args:
        return call.args[0]
    for kw in call.keywords:
        if kw.arg == "site":
            return kw.value
    return None


class FaultSiteCoverageRule(fw.Rule):
    """TRK106: fault-injection hooks present and registered."""

    rule_id = "TRK106"
    summary = ("fault-injection site missing, unregistered, or a "
               "dispatch call without fault_ctx= (DESIGN.md §12)")

    def check(self, module: fw.Module, config) -> List[fw.Finding]:
        findings: List[fw.Finding] = []
        sites = _registered_sites(module, config)
        site_values: Set[str] = set(sites.values())
        norm = module.path.replace("\\", "/")

        # 1. every faults.check names a registered site
        for node in ast.walk(module.tree):
            if not (isinstance(node, ast.Call) and _is_faults_check(node)):
                continue
            site = _site_of(node)
            if site is None:
                findings.append(self.finding(
                    module, node, "faults.check() without a site argument"))
            elif isinstance(site, ast.Constant) and isinstance(site.value,
                                                               str):
                if site.value not in site_values:
                    findings.append(self.finding(
                        module, site,
                        f"fault site {site.value!r} is not registered in "
                        f"{config.faults_module} — plans targeting the "
                        f"documented sites will never match it; register "
                        f"a constant there and reference it"))
            elif isinstance(site, ast.Attribute):
                if site.attr.isupper() and site.attr not in sites:
                    findings.append(self.finding(
                        module, site,
                        f"fault site constant `{fw.dotted_name(site)}` is "
                        f"not defined in {config.faults_module}"))

        # 2. required hooks exist in the configured functions; a plain
        # name matches module-level defs only, `Class.method` matches the
        # method (AsyncWriter.save delegating to the hooked module-level
        # save must not be required to hook twice)
        for (mod_suffix, func_name), const in (
                config.required_fault_hooks.items()):
            if not norm.endswith(mod_suffix):
                continue
            for node in ast.walk(module.tree):
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                owner = next((p.name for p in fw.parents(node)
                              if isinstance(p, ast.ClassDef)), None)
                qual = f"{owner}.{node.name}" if owner else node.name
                if qual != func_name:
                    continue
                want = sites.get(const, _BUILTIN_SITES.get(const, ""))
                if not self._has_hook(node, const, want):
                    findings.append(self.finding(
                        module, node,
                        f"`{func_name}` is a registered fault site but "
                        f"carries no faults.check({const}) hook — "
                        f"injection plans cannot reach this failure "
                        f"point (DESIGN.md §12)"))

        # 3. dispatch-capable peel calls in the drivers carry fault_ctx=
        if any(norm.endswith(suffix)
               for suffix in config.fault_instrumented_modules):
            for node in ast.walk(module.tree):
                if not isinstance(node, ast.Call):
                    continue
                name = fw.call_name(node).split(".")[-1]
                if name not in config.fault_instrumented_apis:
                    continue
                if any(kw.arg is None for kw in node.keywords):
                    continue  # **kwargs forwarding
                if "fault_ctx" not in fw.keyword_names(node):
                    findings.append(self.finding(
                        module, node,
                        f"driver dispatch `{name}` without `fault_ctx=`: "
                        f"this site is invisible to fault plans, so its "
                        f"retry/degrade path is untestable — name it "
                        f"with stage/round context"))
        return findings

    @staticmethod
    def _has_hook(func: ast.AST, const: str, value: str) -> bool:
        for node in ast.walk(func):
            if not (isinstance(node, ast.Call) and _is_faults_check(node)):
                continue
            site = _site_of(node)
            if isinstance(site, ast.Attribute) and site.attr == const:
                return True
            if (isinstance(site, ast.Constant) and value
                    and site.value == value):
                return True
        return False
