"""TRK104 recompilation hazards and TRK105 implicit host syncs.

Both rules police the hot round loops of the out-of-core drivers:

* PR 7 established the shape-cache / shape-ladder discipline — every
  jitted peel dispatched from a per-round or per-level loop keys its
  operand shapes through a caller-owned cache (``shape_cache=``) or packs
  onto an already-compiled shape (``shape_ladder=``), because a
  data-dependent Python shape re-traces pod-wide (the 14→4 compile-count
  fix).  TRK104 flags calls to the shape-disciplined APIs from inside a
  loop that drop the keyword.
* TRK105 flags host synchronisation (``int()``/``float()``/``bool()``/
  ``.item()``/``np.asarray``) on device values inside the round loops of
  the configured hot modules — each one blocks dispatch and serialises
  the double-buffered pipeline (DESIGN.md §9).  Device values are tracked
  by taint: names assigned from module-level jit bindings or the
  configured cross-module producers.
"""

from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis import framework as fw

_SYNC_BUILTINS = {"int", "float", "bool"}


def _loop_varying_names(loops) -> Set[str]:
    """Names rebound somewhere inside the given loop statements: the loop
    targets themselves plus every assignment in their bodies.  An argument
    built from one of these can change shape between iterations."""
    out: Set[str] = set()
    for loop in loops:
        if isinstance(loop, (ast.For, ast.AsyncFor)):
            out.update(fw.assigned_names(loop.target))
        for node in ast.walk(loop):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    out.update(fw.assigned_names(t))
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                out.update(fw.assigned_names(node.target))
            elif isinstance(node, ast.withitem) and node.optional_vars:
                out.update(fw.assigned_names(node.optional_vars))
    return out


def _names_in(expr: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(expr) if isinstance(n, ast.Name)}


class RecompileHazardRule(fw.Rule):
    """TRK104: shape-disciplined API called in a loop without its
    shape-cache/shape-ladder keyword — or a locally defined jitted
    callable called in a loop with loop-varying arguments."""

    rule_id = "TRK104"
    summary = ("jitted peel/pack API called inside a per-round loop "
               "without shape_cache=/shape_ladder= (recompile hazard)")

    def check(self, module: fw.Module, config) -> List[fw.Finding]:
        findings: List[fw.Finding] = []
        apis = config.shape_disciplined_apis
        # module-local jit products: `x = jax.jit(f)` bindings and
        # `@jit`-decorated defs of THIS file (the configured cross-module
        # producers are covered by the API table, not this branch)
        local_jitted = (_module_producers(module, config)
                        - set(config.device_producers) - set(apis))
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            name = fw.call_name(node).split(".")[-1]
            required = apis.get(name)
            loops = fw.enclosing_loops(node)
            if not loops:
                continue
            if required is not None:
                kwargs = fw.keyword_names(node)
                if any(kw.arg is None for kw in node.keywords):
                    continue  # **kwargs forwarding: caller threads it
                if not any(r in kwargs for r in required):
                    findings.append(self.finding(
                        module, node,
                        f"`{name}` called inside a loop without "
                        f"{' / '.join(f'`{r}=`' for r in required)}: each "
                        f"data-dependent operand shape re-traces and "
                        f"recompiles (pod-wide under a mesh) — thread the "
                        f"run's shape cache through this call (PR-7 "
                        f"discipline, DESIGN.md §13)"))
            elif name in local_jitted:
                varying = _loop_varying_names(loops)
                args = list(node.args) + [kw.value for kw in node.keywords]
                hot = sorted(set().union(*[_names_in(a) for a in args])
                             & varying) if args else []
                if hot:
                    findings.append(self.finding(
                        module, node,
                        f"locally jitted `{name}` called inside a loop "
                        f"with loop-varying argument(s) "
                        f"{', '.join(f'`{h}`' for h in hot)}: if their "
                        f"shapes differ between iterations every call "
                        f"re-traces and recompiles — pad the operands to "
                        f"a fixed shape, hoist the call, or allowlist "
                        f"with the shape invariant as rationale "
                        f"(DESIGN.md §13)"))
        return findings


def _module_producers(module: fw.Module, config) -> Set[str]:
    """Names whose call results live on device: module-level ``jax.jit``
    bindings, jit-decorated defs, plus the configured cross-module list."""
    out: Set[str] = set(config.device_producers)
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if fw.call_name(node.value).split(".")[-1] == "jit":
                out.update(fw.assigned_names(node.targets[0]))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                dn = fw.dotted_name(dec if not isinstance(dec, ast.Call)
                                    else dec.func).split(".")[-1]
                if dn == "jit" or (isinstance(dec, ast.Call)
                                   and dn == "partial" and dec.args
                                   and fw.dotted_name(dec.args[0])
                                   .split(".")[-1] == "jit"):
                    out.add(node.name)
    return out


class HostSyncRule(fw.Rule):
    """TRK105: host sync on a device value inside a hot round loop."""

    rule_id = "TRK105"
    summary = ("int()/.item()/np.asarray on a device value inside a hot "
               "round loop (blocks the dispatch pipeline)")

    def check(self, module: fw.Module, config) -> List[fw.Finding]:
        norm = module.path.replace("\\", "/")
        if not any(norm.endswith(suffix) for suffix in config.hot_modules):
            return []
        producers = _module_producers(module, config)
        findings: List[fw.Finding] = []
        for func in ast.walk(module.tree):
            if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            tainted: Set[str] = set()
            for node in ast.walk(func):
                if (isinstance(node, ast.Assign)
                        and isinstance(node.value, ast.Call)
                        and fw.call_name(node.value).split(".")[-1]
                        in producers):
                    for t in node.targets:
                        tainted.update(fw.assigned_names(t))
            if not tainted:
                continue
            for node in ast.walk(func):
                if not isinstance(node, ast.Call):
                    continue
                sync_name = self._synced_name(node, tainted)
                if sync_name and fw.enclosing_loops(node):
                    findings.append(self.finding(
                        module, node,
                        f"host sync on device value `{sync_name}` inside "
                        f"a round loop: this blocks until the device "
                        f"catches up and serialises the double-buffered "
                        f"pipeline — keep the value on device, or sync "
                        f"once outside the loop (DESIGN.md §9)"))
        return findings

    @staticmethod
    def _synced_name(call: ast.Call, tainted: Set[str]) -> str:
        name = fw.call_name(call)
        # int(x) / float(x) / bool(x)
        if name in _SYNC_BUILTINS and call.args:
            arg = call.args[0]
            if isinstance(arg, ast.Name) and arg.id in tainted:
                return arg.id
        # x.item()
        if (isinstance(call.func, ast.Attribute)
                and call.func.attr == "item"
                and isinstance(call.func.value, ast.Name)
                and call.func.value.id in tainted):
            return call.func.value.id
        # np.asarray(x) / numpy.asarray(x) / np.array(x)
        if name.split(".")[-1] in ("asarray", "array") and call.args:
            arg = call.args[0]
            if isinstance(arg, ast.Name) and arg.id in tainted:
                return arg.id
        return ""
