"""TRK102 falsy-zero guards and TRK103 bare asserts.

Both classes shipped here before they were rules:

* PR 3 found ``truss_decompose`` silently routing to the default engine
  because ``if memory_budget:`` conflated ``memory_budget=0`` (a user
  error worth a loud ``ValueError``) with ``memory_budget=None`` (use the
  default) — the decomposition "worked" with the wrong engine.
* PR 6 found ``checkpoint.restore`` validating snapshots with bare
  ``assert``, which the CI ``python -O`` lane compiles out — the corrupt
  snapshot loaded anyway.
"""

from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis import framework as fw


def _suspect_names(func: ast.AST, config) -> Set[str]:
    """Parameter names of ``func`` that are numeric-config shaped: either
    annotated optional-numeric or matching the configured name patterns."""
    out: Set[str] = set()
    if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return out
    pat = config.numeric_config_re()
    args = func.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs):
        if fw.is_optional_numeric_annotation(a.annotation):
            out.add(a.arg)
        elif pat.fullmatch(a.arg):
            out.add(a.arg)
    return out


class FalsyZeroGuardRule(fw.Rule):
    """TRK102: numeric config values tested with bare truthiness.

    ``if budget:`` / ``not budget`` / ``budget or default`` treat a
    legitimate 0 exactly like None — the caller asked for zero and
    silently got the fallback.  Guard with ``is not None`` (and validate
    non-positive values loudly, the PR-3 fix pattern).
    """

    rule_id = "TRK102"
    summary = ("numeric config tested for truthiness instead of "
               "`is not None` (0 silently becomes the fallback)")

    def check(self, module: fw.Module, config) -> List[fw.Finding]:
        findings: List[fw.Finding] = []
        pat = config.numeric_config_re()

        def suspect(expr: ast.AST) -> str:
            """The offending identifier if ``expr`` is a bare truthiness
            read of a numeric-config name ('' otherwise)."""
            if isinstance(expr, ast.UnaryOp) and isinstance(expr.op, ast.Not):
                return suspect(expr.operand)
            if isinstance(expr, ast.Name):
                if pat.fullmatch(expr.id):
                    return expr.id
                func = fw.enclosing_function(expr)
                if func is not None and expr.id in _suspect_names(func,
                                                                  config):
                    return expr.id
            if isinstance(expr, ast.Attribute) and pat.fullmatch(expr.attr):
                return fw.dotted_name(expr)
            return ""

        def flag(expr: ast.AST, context: str) -> None:
            name = suspect(expr)
            if name:
                findings.append(self.finding(
                    module, expr,
                    f"`{name}` is a numeric config value tested for "
                    f"truthiness ({context}); 0 and None take the same "
                    f"branch — use `{name} is not None` and reject "
                    f"non-positive values explicitly"))

        for node in ast.walk(module.tree):
            if isinstance(node, (ast.If, ast.While, ast.IfExp)):
                flag(node.test, "branch condition")
            elif isinstance(node, ast.BoolOp):
                op = "or" if isinstance(node.op, ast.Or) else "and"
                # every operand but the last is a short-circuit *test*;
                # `x or default` / `x and y` both swallow a falsy zero
                for value in node.values[:-1]:
                    flag(value, f"`{op}` short-circuit")
        return findings


class BareAssertRule(fw.Rule):
    """TRK103: ``assert`` in library code — a no-op under ``python -O``.

    CI runs the resilience suite with ``-O`` (PR 6), so an assert in
    ``src/repro`` is a check that silently stops existing in exactly the
    lane meant to prove recovery works.  Raise a typed exception instead
    (``ValueError`` for argument/shape contracts, mirroring the PR-6
    ``checkpoint.restore`` conversion).
    """

    rule_id = "TRK103"
    summary = "bare `assert` in library code (erased under python -O)"

    def check(self, module: fw.Module, config) -> List[fw.Finding]:
        norm = module.path.replace("\\", "/")
        if not any(root in norm for root in config.library_roots):
            return []
        findings: List[fw.Finding] = []
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Assert):
                test_src = ast.get_source_segment(module.source, node.test)
                shown = (test_src or "<condition>").replace("\n", " ")
                if len(shown) > 60:
                    shown = shown[:57] + "..."
                findings.append(self.finding(
                    module, node,
                    f"bare assert `{shown}` is compiled out under -O; "
                    f"raise a typed exception (ValueError/TypeError) so "
                    f"the contract survives every CI lane"))
        return findings
