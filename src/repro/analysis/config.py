"""Repo-native configuration for the trusscheck rules.

The rules are generic AST passes; everything that names THIS repo's
conventions — which modules are the hot round loops, which callables
donate their buffers, which APIs carry the shape-cache discipline, where
the fault-site registry lives — is collected here so adding a module or
an API is a one-line config change, not a rule rewrite (DESIGN.md §14).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Sequence, Tuple


@dataclasses.dataclass
class CheckConfig:
    # --- TRK102: numeric config names whose 0 is a meaningful value, so
    # bare truthiness (`if budget:`) silently conflates 0 with None — the
    # PR-3 `if memory_budget:` fallback class.  Matched as fullmatch
    # against the identifier (parameters, locals and attribute names);
    # parameters annotated `int | None` / `Optional[int]` are covered
    # regardless of name.
    numeric_config_patterns: Tuple[str, ...] = (
        r".*budget.*", r".*_every", r"every", r".*multiple", r".*capacity",
        r".*retries", r".*chunks?", r".*interval", r".*limit", r"max_seq",
        r".*_seed", r"seed", r"n_devices",
    )

    # --- TRK103: bare asserts are no-ops under the CI `python -O` lane
    # (PR 6).  Everything under these roots is library code; tests keep
    # their asserts.
    library_roots: Tuple[str, ...] = ("src/repro",)

    # --- TRK101: callables known to donate buffers when the defining
    # module is out of view (cross-module calls match on the trailing
    # dotted name).  Module-local `X = jax.jit(..., donate_argnums=...)`
    # bindings and donating `@partial(jax.jit, ...)` decorators are
    # discovered from the AST and need no entry here.
    known_donating_callables: Tuple[str, ...] = (
        "peel_classes_fused",           # kernels.frontier_peel.ops (arg 0)
    )

    # --- TRK104: APIs that compile per operand shape and therefore carry
    # the shape-cache / shape-ladder discipline (PR 7).  A call to one of
    # these inside a per-round / per-level loop without the keyword is a
    # recompile hazard: each data-dependent shape re-traces pod-wide.
    shape_disciplined_apis: Dict[str, Tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: {
            "peel_classes_batched": ("shape_cache",),
            "local_threshold_peel": ("shape_cache",),
            "build_partition_batch": ("shape_ladder", "lane_capacity"),
        })

    # --- TRK105: modules whose round loops are the latency-critical path;
    # host syncs (int()/.item()/np.asarray on device values) inside their
    # loops stall the dispatch pipeline.
    hot_modules: Tuple[str, ...] = (
        "core/bottom_up.py", "core/top_down.py", "core/peel.py",
        "core/store.py", "core/maintain.py",
    )
    # Calls whose results live on device (module-local jit bindings are
    # discovered from the AST; these cover cross-module producers).
    device_producers: Tuple[str, ...] = (
        "peel_classes_batched_sharded", "local_threshold_peel_sharded",
        "peel_classes_fused",
    )

    # --- TRK106: the fault-site registry module and the functions that
    # must carry a `faults.check(...)` hook (DESIGN.md §12).  Keyed by
    # (module suffix, function name) -> required site constant name.
    faults_module: str = "core/faults.py"
    required_fault_hooks: Dict[Tuple[str, str], str] = dataclasses.field(
        default_factory=lambda: {
            ("core/peel.py", "peel_classes_batched"): "DISPATCH",
            ("core/peel.py", "local_threshold_peel"): "DISPATCH",
            ("core/peel.py", "PendingPeel.result"): "FINALIZE",
            ("core/bottom_up.py", "_partition_rounds"): "PARTITIONER",
            ("core/bottom_up.py", "_support_credit_triples"): "SUPPORT",
            ("core/maintain.py", "truss_maintain"): "MAINTAIN",
            ("checkpoint/manager.py", "save"): "CHECKPOINT_WRITE",
            ("core/store.py",
             "ChunkedDiskStore._read_chunk"): "CHUNK_READ",
            ("core/store.py",
             "ChunkedDiskStore._write_chunk"): "CHUNK_WRITE",
        })
    # Modules whose dispatch-capable peel calls must name themselves at
    # the fault sites (fault_ctx=) so injection plans can target them.
    fault_instrumented_modules: Tuple[str, ...] = (
        "core/bottom_up.py", "core/top_down.py",
    )
    fault_instrumented_apis: Tuple[str, ...] = (
        "peel_classes_batched", "local_threshold_peel",
    )

    # --- TRK107: Pallas kernel invariants.  Kernel modules must guard
    # tile divisibility with typed raises (asserts vanish under -O) and
    # compare a VMEM working-set estimate against the budget constant.
    kernel_globs: Tuple[str, ...] = ("kernels/",)
    vmem_helper_pattern: str = r".*vmem_bytes.*"
    vmem_budget_pattern: str = r".*(VMEM_BUDGET|budget_bytes).*"
    # Tile-knob parameter names (block sizes fed into BlockSpec shapes).
    tile_param_pattern: str = r"b[a-z][a-z0-9]*|tile.*|block.*"

    def numeric_config_re(self) -> re.Pattern:
        return re.compile("|".join(f"(?:{p})"
                                   for p in self.numeric_config_patterns))

    def tile_param_re(self) -> re.Pattern:
        return re.compile(self.tile_param_pattern)


DEFAULT_CONFIG = CheckConfig()
