"""`trusscheck` rule framework: findings, allowlist pragmas, the runner.

The checker codifies the bug classes this repo has actually shipped (the
PR-3 falsy ``memory_budget`` fallback, the PR-4 ``PendingPeel`` retry on
donated buffers, the PR-6 bare asserts erased under ``python -O``, ...) as
AST rules that run in CI before the tests do.  Everything here is stdlib
only — the pass must run in a bare CI lane without jax installed.

A rule is a subclass of :class:`Rule` with a unique ``rule_id``
(``TRK1xx``), a one-line ``summary``, and a ``check(module) -> findings``
method over a parsed :class:`Module`.  Rules are registered in
:data:`repro.analysis.RULES` (see ``__init__.py``) and selected on the
command line with ``--rules``.

Allowlist pragma
----------------
A finding is suppressed by a pragma on the flagged line or the line
above (rule ids are uppercase; the placeholder here is lowercase so this
docstring is not itself parsed as a pragma)::

    if not bool(overflow):  # trusscheck: allow[TRKnnn] -- <why it is safe>

The rationale after ``--`` is REQUIRED: a pragma without one is itself a
finding (``TRK100``), so every suppression carries its justification in
the source; a pragma that suppresses nothing is flagged as stale.
Multiple ids separate with commas: ``allow[TRKnnn,TRKmmm]``.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence

SEVERITY_ERROR = "error"
SEVERITY_WARNING = "warning"

_PRAGMA_RE = re.compile(
    r"#\s*trusscheck:\s*allow\[(?P<ids>[A-Z0-9, ]+)\]"
    r"(?:\s*--\s*(?P<why>.*\S))?")


@dataclasses.dataclass
class Finding:
    """One rule violation at one source location."""

    rule_id: str
    severity: str
    path: str
    line: int
    col: int
    message: str
    allowlisted: bool = False

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)

    def render(self) -> str:
        tag = " (allowlisted)" if self.allowlisted else ""
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule_id} [{self.severity}]{tag} {self.message}")


@dataclasses.dataclass
class Pragma:
    """A parsed ``# trusscheck: allow[...]`` comment."""

    line: int
    rule_ids: List[str]
    rationale: str
    used: bool = False


class Module:
    """One parsed source file plus the derived context rules share.

    ``tree`` is the ``ast`` module tree; ``lines`` the raw source lines
    (1-indexed through :meth:`line`); ``pragmas`` the allowlist comments
    keyed by the line they suppress.  Parent links are attached to every
    node (``node._trusscheck_parent``) so rules can walk upward —
    :func:`enclosing_loops` and :func:`enclosing_function` build on it.
    """

    def __init__(self, path: str, source: str, tree: ast.AST):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree
        self.pragmas = self._parse_pragmas()
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                child._trusscheck_parent = parent  # type: ignore[attr-defined]

    def line(self, n: int) -> str:
        if 1 <= n <= len(self.lines):
            return self.lines[n - 1]
        return ""

    def _parse_pragmas(self) -> Dict[int, Pragma]:
        out: Dict[int, Pragma] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _PRAGMA_RE.search(text)
            if m is None:
                continue
            ids = [s.strip() for s in m.group("ids").split(",") if s.strip()]
            out[i] = Pragma(line=i, rule_ids=ids,
                            rationale=(m.group("why") or "").strip())
        return out

    def pragma_for(self, line: int, rule_id: str) -> Optional[Pragma]:
        """The pragma suppressing ``rule_id`` at ``line``: same line, or a
        pragma-only line directly above."""
        for cand in (line, line - 1):
            p = self.pragmas.get(cand)
            if p is None:
                continue
            if cand == line - 1 and not self.line(cand).lstrip().startswith("#"):
                continue  # pragma above must be a standalone comment line
            if rule_id in p.rule_ids:
                return p
        return None


class Rule:
    """Base class: subclasses set ``rule_id``/``summary``/``severity`` and
    implement :meth:`check`."""

    rule_id: str = "TRK000"
    summary: str = ""
    severity: str = SEVERITY_ERROR

    def check(self, module: Module, config) -> List[Finding]:
        raise NotImplementedError

    def finding(self, module: Module, node: ast.AST, message: str) -> Finding:
        return Finding(rule_id=self.rule_id, severity=self.severity,
                       path=module.path, line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0) + 1,
                       message=message)


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def parents(node: ast.AST) -> Iterable[ast.AST]:
    """The chain of ancestors from ``node`` to the module root."""
    cur = getattr(node, "_trusscheck_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_trusscheck_parent", None)


def enclosing_loops(node: ast.AST) -> List[ast.AST]:
    """Every for/while statement the node sits inside (function-bounded:
    a loop outside the node's closest enclosing def does not count — the
    closure may run once, elsewhere, later)."""
    out = []
    for p in parents(node):
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            break
        if isinstance(p, (ast.For, ast.AsyncFor, ast.While)):
            out.append(p)
    return out


def enclosing_function(node: ast.AST):
    for p in parents(node):
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return p
    return None


def call_name(call: ast.Call) -> str:
    """Dotted best-effort name of a call target: ``a.b.c(...)`` ->
    ``"a.b.c"``, ``f(...)`` -> ``"f"``, anything else -> ``""``."""
    return dotted_name(call.func)


def dotted_name(node: ast.AST) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def keyword_names(call: ast.Call) -> List[str]:
    return [kw.arg for kw in call.keywords if kw.arg is not None]


def assigned_names(target: ast.AST) -> List[str]:
    """Flat plain names bound by an assignment target (tuples unpacked)."""
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in target.elts:
            out.extend(assigned_names(elt))
        return out
    if isinstance(target, ast.Starred):
        return assigned_names(target.value)
    return []


def is_optional_numeric_annotation(ann: Optional[ast.AST]) -> bool:
    """Whether an annotation spells an optional numeric: ``int | None``,
    ``Optional[int]``, ``Optional[float]``, ``float | None`` (and the
    string-literal forms of the same)."""
    if ann is None:
        return False
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        try:
            ann = ast.parse(ann.value, mode="eval").body
        except SyntaxError:
            return False
    numeric = {"int", "float"}
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        sides = (ann.left, ann.right)
        names = {dotted_name(s) for s in sides}
        has_none = any(isinstance(s, ast.Constant) and s.value is None
                       for s in sides) or "None" in names
        return has_none and bool(names & numeric)
    if isinstance(ann, ast.Subscript) and dotted_name(ann.value).endswith(
            "Optional"):
        inner = ann.slice
        return dotted_name(inner) in numeric
    return False


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Report:
    """The outcome of one run: findings plus unused-pragma diagnostics."""

    findings: List[Finding]
    files_checked: int

    @property
    def active(self) -> List[Finding]:
        return [f for f in self.findings if not f.allowlisted]

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.active if f.severity == SEVERITY_ERROR]

    def as_json(self) -> str:
        return json.dumps(
            {"files_checked": self.files_checked,
             "findings": [f.as_dict() for f in self.findings],
             "active": len(self.active), "errors": len(self.errors)},
            indent=2, sort_keys=True)


def iter_py_files(paths: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    return [p for p in out if "__pycache__" not in p.parts]


def parse_module(path: Path) -> Optional[Module]:
    try:
        source = path.read_text(encoding="utf-8")
    except (OSError, UnicodeDecodeError) as exc:
        print(f"trusscheck: cannot read {path}: {exc}", file=sys.stderr)
        return None
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as exc:
        print(f"trusscheck: syntax error in {path}: {exc}", file=sys.stderr)
        return None
    return Module(str(path), source, tree)


def check_module(module: Module, rules: Sequence[Rule], config) -> List[Finding]:
    """Run ``rules`` over one parsed module, applying allowlist pragmas.

    A pragma with an empty rationale yields a TRK100 finding; a pragma
    that suppressed nothing in this run yields one too (stale allowlists
    rot into silent holes — PR 6's lesson about unexecuted asserts).
    """
    findings: List[Finding] = []
    for rule in rules:
        for f in rule.check(module, config):
            pragma = module.pragma_for(f.line, f.rule_id)
            if pragma is not None and pragma.rationale:
                f.allowlisted = True
                pragma.used = True
            elif pragma is not None:
                pragma.used = True  # counted, but rationale-less: keep live
                findings.append(Finding(
                    rule_id="TRK100", severity=SEVERITY_ERROR,
                    path=module.path, line=pragma.line, col=1,
                    message=("allowlist pragma without a rationale: append "
                             "'-- <why this is safe>'")))
            findings.append(f)
    checked = {r.rule_id for r in rules}
    for pragma in module.pragmas.values():
        if not pragma.used and set(pragma.rule_ids) & checked:
            findings.append(Finding(
                rule_id="TRK100", severity=SEVERITY_ERROR,
                path=module.path, line=pragma.line, col=1,
                message=(f"stale allowlist pragma: no "
                         f"{','.join(pragma.rule_ids)} finding at this line "
                         "— delete it, or it hides the next regression")))
    return findings


def run(paths: Sequence[str], rules: Sequence[Rule], config) -> Report:
    files = iter_py_files(paths)
    findings: List[Finding] = []
    n = 0
    for path in files:
        module = parse_module(path)
        if module is None:
            continue
        n += 1
        findings.extend(check_module(module, rules, config))
    findings.sort(key=lambda f: (f.path, f.line, f.rule_id))
    return Report(findings=findings, files_checked=n)
