"""``trusscheck`` — the repo-native static-analysis pass (DESIGN.md §14).

Codifies the bug classes this reproduction has actually shipped as AST
rules that gate CI: donation safety (PR 4), falsy-zero config guards
(PR 3), bare asserts under ``python -O`` (PR 6), recompile hazards
(PR 7's shape-cache discipline), implicit host syncs in the round loops,
fault-site coverage (DESIGN.md §12) and Pallas kernel invariants
(DESIGN.md §5).  Run it with::

    python -m repro.analysis src/repro [--json report.json] [--fix]

Stdlib only — no jax import, so the CI gate runs before any dependency
install.  The rule catalog:

========  =======================================================
TRK100    allowlist pragma hygiene (rationale required, no stale
          pragmas) — emitted by the framework itself
TRK101    donation safety: reads of a buffer after a
          jit(donate_argnums=...) call consumed it
TRK102    falsy-zero guards: numeric config tested for truthiness
TRK103    bare assert in library code (erased under python -O)
TRK104    recompile hazards: shape-disciplined APIs called in a
          loop without shape_cache=/shape_ladder=
TRK105    implicit host syncs inside the hot round loops
TRK106    fault-site coverage: unregistered sites, missing hooks,
          dispatches without fault_ctx=
TRK107    Pallas invariants: tile divisibility + VMEM budgeting
========  =======================================================
"""

from __future__ import annotations

from typing import List, Optional, Sequence

from repro.analysis.config import DEFAULT_CONFIG, CheckConfig
from repro.analysis.framework import (Finding, Module, Report, Rule,
                                      run)
from repro.analysis.rules_donation import DonationSafetyRule
from repro.analysis.rules_faults import FaultSiteCoverageRule
from repro.analysis.rules_guards import BareAssertRule, FalsyZeroGuardRule
from repro.analysis.rules_jit import HostSyncRule, RecompileHazardRule
from repro.analysis.rules_pallas import PallasInvariantRule

ALL_RULES = (
    DonationSafetyRule,
    FalsyZeroGuardRule,
    BareAssertRule,
    RecompileHazardRule,
    HostSyncRule,
    FaultSiteCoverageRule,
    PallasInvariantRule,
)


def build_rules(only: Optional[Sequence[str]] = None) -> List[Rule]:
    """Instantiate the catalog, optionally restricted to specific ids."""
    rules = [cls() for cls in ALL_RULES]
    if only is None:
        return rules
    wanted = {r.strip().upper() for r in only if r.strip()}
    unknown = wanted - {r.rule_id for r in rules} - {"TRK100"}
    if unknown:
        raise ValueError(f"unknown rule ids: {sorted(unknown)}")
    return [r for r in rules if r.rule_id in wanted]


def check_paths(paths: Sequence[str], *,
                only: Optional[Sequence[str]] = None,
                config: Optional[CheckConfig] = None) -> Report:
    """Programmatic entry point (the tests drive this)."""
    return run(paths, build_rules(only), config or DEFAULT_CONFIG)


__all__ = [
    "ALL_RULES", "CheckConfig", "DEFAULT_CONFIG", "Finding", "Module",
    "Report", "Rule", "build_rules", "check_paths", "run",
]
