"""CLI for the trusscheck pass: ``python -m repro.analysis [paths...]``.

Exit status: 0 when no active (non-allowlisted) error findings, 1 when
there are, 2 on usage errors.  ``--json`` writes the machine report (CI
uploads it); human output always goes to stdout.
"""

from __future__ import annotations

import argparse
import sys
from typing import List

from repro.analysis import build_rules, check_paths, fixes
from repro.analysis import framework as fw
from repro.analysis.config import DEFAULT_CONFIG


def main(argv: List[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="trusscheck",
        description=("repo-native static analysis: codified bug classes "
                     "from PRs 3-7 (see DESIGN.md §14)"))
    parser.add_argument("paths", nargs="*", default=["src/repro"],
                        help="files or directories to check "
                             "(default: src/repro)")
    parser.add_argument("--rules", default=None,
                        help="comma-separated rule ids to run "
                             "(default: all)")
    parser.add_argument("--json", dest="json_path", default=None,
                        metavar="FILE",
                        help="write the JSON report to FILE ('-' = stdout)")
    parser.add_argument("--fix", action="store_true",
                        help="apply mechanical fixes for TRK102/TRK103, "
                             "then re-check")
    parser.add_argument("--show-allowlisted", action="store_true",
                        help="also print allowlisted findings")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in build_rules():
            print(f"{rule.rule_id}  [{rule.severity:7s}]  {rule.summary}")
        return 0

    only = args.rules.split(",") if args.rules else None
    try:
        report = check_paths(args.paths, only=only)
    except ValueError as exc:
        print(f"trusscheck: {exc}", file=sys.stderr)
        return 2

    if args.fix:
        fixed = 0
        for path in sorted({f.path for f in report.active}):
            fixed += fixes.apply_fixes(path, report.active)
        if fixed:
            print(f"trusscheck: applied {fixed} mechanical fix(es); "
                  f"re-checking")
            report = check_paths(args.paths, only=only)

    shown = report.findings if args.show_allowlisted else report.active
    for finding in shown:
        print(finding.render())

    if args.json_path == "-":
        print(report.as_json())
    elif args.json_path:
        fw.Path(args.json_path).write_text(report.as_json() + "\n",
                                           encoding="utf-8")

    n_allow = sum(1 for f in report.findings if f.allowlisted)
    verdict = "clean" if not report.errors else "FAILED"
    print(f"trusscheck: {report.files_checked} files, "
          f"{len(report.errors)} error(s), {n_allow} allowlisted — "
          f"{verdict}")
    return 1 if report.errors else 0


if __name__ == "__main__":
    sys.exit(main())
