"""TRK107 Pallas kernel invariants.

The Pallas kernels (DESIGN.md §5, §13) rest on two statically visible
contracts that have each bitten before:

* **tile divisibility** — every tile knob fed into a ``pl.BlockSpec``
  shape must be checked to divide the dimension it tiles (or be handled
  by an explicit padding path).  An undivisible tile doesn't fail loudly
  on TPU; it reads garbage off the tile edge.  The check must be a typed
  raise or a candidate *filter* (``feasible_tiles``-style) — a bare
  ``assert`` is compiled out in the ``python -O`` CI lane (TRK103).
* **VMEM budgeting** — the working set implied by the block specs must be
  estimated (a ``*vmem_bytes*`` helper) and *compared against the budget
  constant* somewhere in the module (``VMEM_BUDGET_BYTES`` /
  ``budget_bytes``), the ``kernel_vmem_bytes`` discipline of the
  triangle-count and frontier-peel kernels.  A kernel without the
  estimate can't be autotuned and OOMs at whatever tile a caller picks.

Static limits (DESIGN.md §14): the rule proves the *discipline* exists —
a divisibility check per tile knob and a budget comparison per module —
not that the arithmetic inside them is right; the kernel-vs-ref parity
suites own that half.
"""

from __future__ import annotations

import ast
import re
from typing import List, Set

from repro.analysis import framework as fw


def _pallas_calls(module: fw.Module) -> List[ast.Call]:
    return [n for n in ast.walk(module.tree)
            if isinstance(n, ast.Call)
            and fw.call_name(n).split(".")[-1] == "pallas_call"]


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _guard_exprs(module: fw.Module) -> List[ast.AST]:
    """Expressions evaluated as live conditions: if/while/ternary tests
    and comprehension filters.  Asserts are deliberately excluded — they
    vanish under ``python -O`` (the TRK103 class)."""
    out: List[ast.AST] = []
    for node in ast.walk(module.tree):
        if isinstance(node, (ast.If, ast.While, ast.IfExp)):
            out.append(node.test)
        elif isinstance(node, ast.comprehension):
            out.extend(node.ifs)
    return out


class PallasInvariantRule(fw.Rule):
    """TRK107: pallas_call modules must guard tile divisibility and
    budget-check a VMEM estimate."""

    rule_id = "TRK107"
    summary = ("Pallas kernel without a live tile-divisibility guard or "
               "VMEM-budget comparison")

    def check(self, module: fw.Module, config) -> List[fw.Finding]:
        calls = _pallas_calls(module)
        if not calls:
            return []
        findings: List[fw.Finding] = []
        tile_re = config.tile_param_re()
        vmem_re = re.compile(config.vmem_helper_pattern)

        # names that appear inside a % in a live guard expression
        guarded: Set[str] = set()
        for expr in _guard_exprs(module):
            for node in ast.walk(expr):
                if isinstance(node, ast.BinOp) and isinstance(node.op,
                                                              ast.Mod):
                    guarded |= _names_in(node)

        # (a) per kernel function: every tile knob used in the pallas_call
        # subtree has a divisibility guard somewhere in the module
        for call in calls:
            func = fw.enclosing_function(call)
            if func is None:
                continue
            params = {a.arg for a in (func.args.posonlyargs + func.args.args
                                      + func.args.kwonlyargs)}
            used = _names_in(call)
            for p in sorted(params & used):
                if not tile_re.fullmatch(p):
                    continue
                if p not in guarded:
                    findings.append(self.finding(
                        module, call,
                        f"tile knob `{p}` feeds the pallas_call block "
                        f"specs but no live divisibility check "
                        f"(`dim % {p}` in an if/raise or candidate "
                        f"filter) exists in this module — an undivisible "
                        f"tile reads off the block edge on TPU; guard it "
                        f"with a typed raise (asserts are erased under "
                        f"-O) or a feasible_tiles-style filter"))

        # (b) module-level: a VMEM estimate compared against the budget
        has_helper = any(
            vmem_re.fullmatch(node.name)
            for node in ast.walk(module.tree)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
        ) or any(
            vmem_re.fullmatch(alias.name.split(".")[-1])
            for node in ast.walk(module.tree)
            if isinstance(node, (ast.Import, ast.ImportFrom))
            for alias in node.names
        )
        # names bound to a vmem-estimate call count as the estimate too
        # (`need = kernel_vmem_bytes(...); if need > BUDGET:`)
        vmem_names: Set[str] = set()
        for node in ast.walk(module.tree):
            if (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)
                    and vmem_re.fullmatch(
                        fw.call_name(node.value).split(".")[-1])):
                for name in fw.assigned_names(node.targets[0]):
                    vmem_names.add(name)
        has_compare = False
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Compare):
                continue
            sides = [node.left] + list(node.comparators)
            for side in sides:
                if (isinstance(side, ast.Call)
                        and vmem_re.fullmatch(
                            fw.call_name(side).split(".")[-1])):
                    has_compare = True
                elif (isinstance(side, ast.Name)
                        and side.id in vmem_names):
                    has_compare = True
        if not (has_helper and has_compare):
            findings.append(self.finding(
                module, calls[0],
                "pallas_call module without a VMEM working-set estimate "
                "compared against the budget: define a "
                "`kernel_vmem_bytes(...)`-style helper for the block "
                "specs and check it against VMEM_BUDGET_BYTES before "
                "launching (DESIGN.md §5 discipline)"))
        return findings
