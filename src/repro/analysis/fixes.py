"""``trusscheck --fix``: mechanical rewrites for the two mechanical rules.

Fixable classes (DESIGN.md §14 documents the limits):

* **TRK102** — bare truthiness tests on numeric config names in ``if`` /
  ``while`` conditions: ``if budget:`` -> ``if budget is not None:``,
  ``if not budget:`` -> ``if budget is None:``; and two-operand ``or``
  defaults on a suspect name: ``x = budget or 64`` ->
  ``x = 64 if budget is None else budget``.
* **TRK103** — single-line ``assert cond, msg`` -> ``if not (cond):
  raise ValueError(msg)`` (``ValueError`` is the default type; pick a
  more specific exception by hand where one fits).

Deliberate limits: only single-line nodes are rewritten (a multi-line
assert keeps its finding); ``and`` chains, attribute suspects
(``cfg.budget``) and ternary conditions are reported but not fixed —
their correct rewrite depends on surrounding intent; comments inside a
rewritten segment are not preserved.  The fixer is idempotent: the fixed
form no longer matches the rule.  Semantics note: the TRK102 rewrite
intentionally *changes* behaviour for 0 — that is the bug being fixed —
so run the tests after fixing; each historical sweep added a loud
``ValueError`` for non-positive values next to the rewritten guard.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis import framework as fw

FIXABLE_RULES = ("TRK102", "TRK103")


def _segment(module: fw.Module, node: ast.AST) -> Optional[str]:
    return ast.get_source_segment(module.source, node)


def _single_line(node: ast.AST) -> bool:
    return getattr(node, "end_lineno", None) == node.lineno


def _fix_assert(module: fw.Module, node: ast.Assert) -> Optional[Tuple[int, str]]:
    if not _single_line(node):
        return None
    line = module.line(node.lineno)
    indent = line[:len(line) - len(line.lstrip())]
    test_src = _segment(module, node.test)
    if test_src is None:
        return None
    if node.msg is not None:
        msg_src = _segment(module, node.msg)
        if msg_src is None:
            return None
        # a bare tuple message (`assert c, (a, b)`) becomes the exception
        # payload verbatim; anything else is already an expression
        raise_src = f"raise ValueError({msg_src})"
    else:
        raise_src = f"raise ValueError({test_src!r})"
    fixed = (f"{indent}if not ({test_src}):\n"
             f"{indent}    {raise_src}")
    return node.lineno, fixed


def _suspect_test(test: ast.AST) -> Optional[Tuple[str, bool]]:
    """(name_source, negated) when the condition is a bare name or its
    negation — the only forms the fixer rewrites."""
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        inner = _suspect_test(test.operand)
        if inner is not None and not inner[1]:
            return inner[0], True
        return None
    if isinstance(test, ast.Name):
        return test.id, False
    return None


def _fix_truthiness(module: fw.Module, stmt: ast.AST) -> Optional[Tuple[int, str]]:
    test = stmt.test
    if not _single_line(test):
        return None
    got = _suspect_test(test)
    if got is None:
        return None
    name, negated = got
    line = module.line(test.lineno)
    old = _segment(module, test)
    if old is None or old not in line:
        return None
    new = f"{name} is None" if negated else f"{name} is not None"
    return test.lineno, line.replace(old, new, 1)


def _fix_or_default(module: fw.Module, boolop: ast.BoolOp) -> Optional[Tuple[int, str]]:
    if not (isinstance(boolop.op, ast.Or) and len(boolop.values) == 2):
        return None
    first, default = boolop.values
    if not isinstance(first, ast.Name) or not _single_line(boolop):
        return None
    line = module.line(boolop.lineno)
    old = _segment(module, boolop)
    default_src = _segment(module, default)
    if old is None or default_src is None or old not in line:
        return None
    new = f"{default_src} if {first.id} is None else {first.id}"
    return boolop.lineno, line.replace(old, new, 1)


def apply_fixes(path: str, findings: List[fw.Finding]) -> int:
    """Rewrite ``path`` in place for its fixable findings; returns the
    number of fixes applied."""
    wanted: Dict[str, List[fw.Finding]] = {}
    for f in findings:
        if f.path == path and f.rule_id in FIXABLE_RULES and not f.allowlisted:
            wanted.setdefault(f.rule_id, []).append(f)
    if not wanted:
        return 0
    module = fw.parse_module(fw.Path(path))
    if module is None:
        return 0
    lines_102 = {f.line for f in wanted.get("TRK102", ())}
    lines_103 = {f.line for f in wanted.get("TRK103", ())}
    replacements: Dict[int, str] = {}   # lineno -> replacement text

    for node in ast.walk(module.tree):
        if isinstance(node, ast.Assert) and node.lineno in lines_103:
            fix = _fix_assert(module, node)
            if fix is not None:
                replacements[fix[0]] = fix[1]
        elif (isinstance(node, (ast.If, ast.While))
              and node.test.lineno in lines_102
              and node.test.lineno not in replacements):
            fix = _fix_truthiness(module, node)
            if fix is not None:
                replacements[fix[0]] = fix[1]
        elif (isinstance(node, ast.BoolOp) and node.lineno in lines_102
              and node.lineno not in replacements):
            fix = _fix_or_default(module, node)
            if fix is not None:
                replacements[fix[0]] = fix[1]

    if not replacements:
        return 0
    out = list(module.lines)
    for lineno, text in replacements.items():
        out[lineno - 1] = text
    trailing_newline = module.source.endswith("\n")
    new_source = "\n".join(out) + ("\n" if trailing_newline else "")
    fw.Path(path).write_text(new_source, encoding="utf-8")
    return len(replacements)
