"""int8 error-feedback gradient compression for DP all-reduce.

The distributed-optimization trick for bandwidth-bound data parallelism:
per-tensor scale, int8 quantize, all-reduce in int32, dequantize; the
quantization residual is carried to the next step (error feedback keeps
SGD/Adam convergence — Karimireddy et al., arXiv:1901.09847).

``compressed_psum`` is the shard_map building block; 4x less ICI traffic
than f32 psum (2x vs bf16) at <1e-2 relative error per step, with the error
feedback removing the bias over steps.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def quantize(g: jnp.ndarray):
    g = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress(g: jnp.ndarray, error: jnp.ndarray):
    """Error-feedback quantize: returns (q, scale, new_error)."""
    corrected = g.astype(jnp.float32) + error
    q, scale = quantize(corrected)
    new_error = corrected - dequantize(q, scale)
    return q, scale, new_error


def compressed_psum(g: jnp.ndarray, error: jnp.ndarray, axis: str):
    """Inside shard_map: int8-payload all-reduce over ``axis`` with error
    feedback.  One scalar pmax shares the scale, then a single int32
    all-reduce carries the payload (int8 payload semantics; int32 carrier
    avoids overflow for up to 2^23 devices).  Returns (mean f32 grad,
    new local error state)."""
    corrected = g.astype(jnp.float32) + error
    local_max = jnp.max(jnp.abs(corrected))
    global_max = jax.lax.pmax(local_max, axis)
    scale = jnp.maximum(global_max, 1e-12) / 127.0
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    new_error = corrected - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return total.astype(jnp.float32) * scale / n, new_error
