"""AdamW + schedules + ZeRO-style state sharding (pure-JAX pytrees).

Optimizer state is kept in f32 regardless of (bf16) param dtype; master
f32 params are part of the state (mixed-precision training).  ``zero_specs``
derives PartitionSpecs for the state that additionally shard over the data
axes (ZeRO-1): for each param, the largest dim divisible by the data-axis
product that is not already model-sharded gets the data axes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_state(params):
    f32 = lambda x: x.astype(jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params),
        "v": jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params),
    }


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def update(cfg: AdamWConfig, params, state, grads, decay_mask=None):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(master, m, v, g, do_decay):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if do_decay:
            delta = delta + cfg.weight_decay * master
        return master - lr * delta, m, v

    if decay_mask is None:
        decay_mask = jax.tree.map(lambda x: x.ndim >= 2, params)
    flat_p, tree = jax.tree.flatten(state["master"])
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_g = jax.tree.leaves(grads)
    flat_d = jax.tree.leaves(decay_mask)
    new_p, new_m, new_v = [], [], []
    for pp, mm, vv, gg, dd in zip(flat_p, flat_m, flat_v, flat_g, flat_d):
        a, b, c = upd(pp, mm, vv, gg, dd)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    master = jax.tree.unflatten(tree, new_p)
    new_state = {
        "step": step,
        "master": master,
        "m": jax.tree.unflatten(tree, new_m),
        "v": jax.tree.unflatten(tree, new_v),
    }
    cast = jax.tree.map(lambda mst, p: mst.astype(p.dtype), master, params)
    return cast, new_state, {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# ZeRO-1 sharding of the optimizer state
# ---------------------------------------------------------------------------

def zero_specs(param_specs, params_shape, data_axes=("pod", "data"),
               data_size: int = 16):
    """State PartitionSpecs: param spec + data axes on a free divisible dim.

    param_specs / params_shape: pytrees matching params (specs, ShapeDtype).
    """
    def one(spec, arr):
        shape = arr.shape
        entries = list(spec) + [None] * (len(shape) - len(spec))
        for i, (s, dim) in enumerate(zip(entries, shape)):
            if s is None and dim % data_size == 0 and dim > 0:
                entries[i] = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
                break
        return P(*entries)

    st = jax.tree.map(one, param_specs, params_shape,
                      is_leaf=lambda x: isinstance(x, P))
    return {
        "step": P(),
        "master": st,
        "m": st,
        "v": st,
    }
