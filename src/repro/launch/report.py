"""Render dry-run JSON into the EXPERIMENTS.md roofline tables.

Usage: python -m repro.launch.report results/dryrun_pod16x16.json [...]
"""

from __future__ import annotations

import json
import sys

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def render(recs, title):
    lines = [f"### {title}", ""]
    lines.append(
        "| arch | shape | kind | t_compute (s) | t_memory (s) | t_coll (s) |"
        " bottleneck | roofline frac | MODEL/HLO flops | temp GiB | status |")
    lines.append("|" + "---|" * 11)
    for r in recs:
        if not r.get("ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r.get('kind','')} |  |  |  |"
                f"  |  |  |  | FAIL: {str(r.get('error'))[:60]} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['t_compute']:.3e} | {r['t_memory']:.3e} "
            f"| {r['t_collective']:.3e} | {r['bottleneck']} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {r['model_flops_ratio']:.2f} "
            f"| {fmt_bytes(r['temp_bytes'])} | OK |")
    lines.append("")
    ok = [r for r in recs if r.get("ok")]
    if ok:
        by_b = {}
        for r in ok:
            by_b.setdefault(r["bottleneck"], []).append(r)
        lines.append(f"**{len(ok)}/{len(recs)} cells compiled.** Bottlenecks: "
                     + ", ".join(f"{k}: {len(v)}" for k, v in
                                 sorted(by_b.items())))
        worst = sorted(ok, key=lambda r: r["roofline_fraction"])[:3]
        lines.append("Worst roofline fractions: "
                     + ", ".join(f"{r['arch']}×{r['shape']}"
                                 f" ({r['roofline_fraction']:.3f})"
                                 for r in worst))
    lines.append("")
    return "\n".join(lines)


def main():
    out = []
    for path in sys.argv[1:]:
        with open(path) as f:
            recs = json.load(f)
        meshes = sorted({r["mesh"] for r in recs})
        for m in meshes:
            out.append(render([r for r in recs if r["mesh"] == m],
                              f"Mesh {m} ({path})"))
    print("\n".join(out))


if __name__ == "__main__":
    main()
