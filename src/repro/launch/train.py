"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On the CPU container this runs the REDUCED config end-to-end (real data
pipeline, optimizer, checkpointing, restart); on a real cluster the same
loop runs the full config under the production mesh — the step functions
are the ones the dry-run lowers.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.reduced import make_reduced
from repro.optim import adamw
from repro.runtime import train_loop as TL


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(registry.ARCHS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-csv", default=None)
    args = ap.parse_args()

    cfg, init_fn, loss_fn, batch_fn = make_reduced(args.arch)
    ocfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                             total_steps=args.steps)

    def init_state():
        params = init_fn()
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"[train] {args.arch}: {n/1e6:.2f}M params (reduced config)")
        return {"params": params, "opt": adamw.init_state(params)}

    @jax.jit
    def train_step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        params, opt, m = adamw.update(ocfg, state["params"], state["opt"], grads)
        return {"params": params, "opt": opt}, {"loss": loss, **m}

    lcfg = TL.LoopConfig(steps=args.steps, ckpt_dir=f"{args.ckpt_dir}/{args.arch}",
                         ckpt_every=args.ckpt_every, log_every=args.log_every,
                         metrics_csv=args.metrics_csv)
    state, rows = TL.run(lcfg, init_state, train_step, batch_fn)
    losses = [r["loss"] for r in rows if "loss" in r]
    print(f"[train] {args.arch}: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"over {args.steps} steps")
    for r in rows:
        print("  ", r)


if __name__ == "__main__":
    main()
