"""Loop-aware flop/byte accounting from post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE — with
scan-over-layers + microbatch-accumulation + chunked attention, that
under-counts real work by orders of magnitude.  This walker parses the HLO
text and:

  * computes matmul flops per ``dot`` from shapes + contracting dims
    (2 · Π(result dims) · Π(contracting dims));
  * recurses through called computations (fusion / call / conditional
    branches / while bodies);
  * multiplies while bodies by their trip count, recovered from the loop
    condition's comparison constant (lax.scan / fori loops compare the
    induction variable against a literal);
  * accumulates dot operand+result bytes × trips — a streamed-traffic proxy
    used as a lower bound on HBM traffic for the memory roofline term.

Elementwise work is ignored (matmuls dominate the compute term at these
shapes); convolutions are counted like dots when they appear.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_TOKEN = re.compile(r"(pred|[subf]\d+|bf16|f16|f32|f64)\[([\d,]*)\]")


def _shape_list(text):
    out = []
    for m in _SHAPE_TOKEN.finditer(text):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _numel(dims):
    n = 1
    for d in dims:
        n *= d
    return n


@dataclass
class Computation:
    name: str
    lines: list = field(default_factory=list)


_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")


def parse_computations(hlo: str) -> dict:
    comps = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            m = _HEADER_RE.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
            continue
        if line.startswith("}"):
            cur = None
        elif line:
            cur.lines.append(line)
    return comps


_CALL_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)%?([\w\.\-]+)")


_INSTR_RE = re.compile(r"^%?([\w\.\-]+)\s*=\s*(.*)$")


def _instr_shapes(comp: "Computation") -> dict:
    """name -> (dtype, dims) of each instruction's (first) result."""
    table = {}
    for line in comp.lines:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shapes = _shape_list(m.group(2).split("(", 1)[0])
        if shapes:
            table[m.group(1)] = shapes[0]
    return table


def _dot_flops_bytes(line: str, table: dict):
    """(flops, bytes) for a dot/convolution instruction line."""
    if "=" not in line:
        return 0, 0
    _, rhs = line.split("=", 1)
    shapes = _shape_list(rhs.split("(", 1)[0])
    if not shapes:
        return 0, 0
    result = shapes[0]
    # operand shapes come from the instruction table (refs have no types)
    ops_m = re.search(r"\b(?:dot|convolution)\(([^)]*)\)", rhs)
    operands = []
    if ops_m:
        for ref in ops_m.group(1).split(","):
            name = ref.strip().lstrip("%")
            if name in table:
                operands.append(table[name])
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    contract = 1
    if m and operands:
        lhs_dims = operands[0][1]
        for i in (int(x) for x in m.group(1).split(",") if x):
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    flops = 2 * _numel(result[1]) * contract
    byts = _numel(result[1]) * _DTYPE_BYTES.get(result[0], 4)
    byts += sum(_numel(d) * _DTYPE_BYTES.get(t, 4) for t, d in operands[:2])
    return flops, byts


def _while_trip_count(cond: Computation) -> int:
    """Recover the scan/fori trip count from the loop condition.

    lax.scan lowers to ``i < N``: the bound N is a scalar integer literal in
    the condition computation, fed to a compare (possibly via a
    wrapped-compare fusion).  We resolve the constant that is an ARGUMENT of
    the compare/fusion line — taking any max constant in the region can
    catch unrelated folded literals (e.g. clamp bounds).
    """
    consts: dict[str, int] = {}
    for line in cond.lines:
        m = re.match(r"%?([\w\.\-]+)\s*=.*?[su]\d+\[\]\s+constant\((\d+)\)",
                     line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    # candidate compare lines: direct compare or a fusion named *compare*
    for line in cond.lines:
        rhs = line.split("=", 1)[1] if "=" in line else line
        is_cmp = re.search(r"\bcompare\(", rhs) or (
            "fusion(" in rhs and "compare" in line)
        if not is_cmp:
            continue
        m = re.search(r"(?:compare|fusion)\(([^)]*)\)", rhs)
        if not m:
            continue
        vals = [consts[a.strip().lstrip("%")] for a in m.group(1).split(",")
                if a.strip().lstrip("%") in consts]
        if vals:
            return max(max(vals), 1)
    return max(consts.values()) if consts else 1


COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def analyze(hlo: str, entry: str | None = None) -> dict:
    comps = parse_computations(hlo)
    zero_coll = {k: 0.0 for k in COLLECTIVE_OPS}
    if not comps:
        return {"flops": 0.0, "dot_bytes": 0.0, "collective_bytes": zero_coll,
                "collective_counts": dict(zero_coll)}
    if entry is None:
        entry = next((n for n in comps if "main" in n), None) \
            or next(iter(comps))
    cache: dict[str, tuple] = {}

    def _merge(a, b, k=1.0):
        return {key: a[key] + k * b[key] for key in a}

    def walk(name: str) -> tuple:
        if name in cache:
            return cache[name]
        comp = comps.get(name)
        if comp is None:
            return (0.0, 0.0, dict(zero_coll), dict(zero_coll))
        cache[name] = (0.0, 0.0, dict(zero_coll), dict(zero_coll))
        table = _instr_shapes(comp)
        flops = byts = 0.0
        coll = dict(zero_coll)
        cnts = dict(zero_coll)
        for line in comp.lines:
            rhs = line.split("=", 1)[1] if "=" in line else line
            mcoll = _COLL_RE.search(rhs)
            if re.search(r"\bdot\(", rhs) or re.search(r"\bconvolution\(", rhs):
                f, b = _dot_flops_bytes(line, table)
                flops += f
                byts += b
            elif mcoll:
                op = mcoll.group(1)
                sz = sum(_numel(d) * _DTYPE_BYTES.get(t, 4)
                         for t, d in _shape_list(rhs[: mcoll.start()]))
                coll[op] += sz
                cnts[op] += 1
            elif " while(" in rhs or rhs.startswith("while("):
                mb = re.search(r"body=%?([\w\.\-]+)", rhs)
                mc = re.search(r"condition=%?([\w\.\-]+)", rhs)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                trips = _while_trip_count(comps[cond]) if cond in comps else 1
                bf, bb, bc, bn = walk(body) if body else (0, 0, zero_coll, zero_coll)
                flops += trips * bf
                byts += trips * bb
                coll = _merge(coll, bc, trips)
                cnts = _merge(cnts, bn, trips)
            else:
                for m in _CALL_RE.finditer(rhs):
                    sub = m.group(1)
                    if sub in comps and sub != name:
                        f, b, c, n = walk(sub)
                        flops += f
                        byts += b
                        coll = _merge(coll, c)
                        cnts = _merge(cnts, n)
        cache[name] = (flops, byts, coll, cnts)
        return cache[name]

    f, b, c, n = walk(entry)
    return {"flops": f, "dot_bytes": b, "collective_bytes": c,
            "collective_counts": n}
