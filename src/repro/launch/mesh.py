"""Production mesh definition (TPU v5e pods).

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips — the 'pod' axis carries
only data parallelism (gradient all-reduce over DCI), model parallelism
stays inside a pod's ICI.

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n: int | None = None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    if n is not None and n <= 0:
        raise ValueError(f"mesh device count must be positive or None "
                         f"(= all local devices), got {n!r}")
    total = len(jax.devices()) if n is None else n
    nd = total
    if len(axes) == 1:
        return jax.make_mesh((nd,), axes)
    d = 1
    while nd % 2 == 0 and d * d < nd:   # largest power-of-two split
        d *= 2
        nd //= 2
    return jax.make_mesh((d, total // d), axes)
