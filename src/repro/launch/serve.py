"""Batched serving driver: prefill + decode with a KV cache.

``python -m repro.launch.serve --arch gemma3-4b --requests 8 --new-tokens 16``

Implements the serving loop the decode cells lower at scale: a batch of
requests is prefIlled once, then decoded step by step (greedy), with simple
continuous-batching bookkeeping (finished requests are masked, their slots
reusable).  Runs the reduced config on CPU.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.reduced import make_reduced
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b", choices=registry.LM_ARCHS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg, init_fn, _, batch_fn = make_reduced(args.arch)
    params = init_fn()
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.requests, args.prompt_len)).astype(np.int32)
    max_seq = args.prompt_len + args.new_tokens

    prefill = jax.jit(lambda p, t: T.prefill(p, t, cfg, max_seq=max_seq))
    decode = jax.jit(lambda p, c, t: T.decode_step(p, c, t, cfg))

    t0 = time.time()
    cache, logits = prefill(params, jnp.asarray(prompts))
    t_prefill = time.time() - t0
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.new_tokens):
        out.append(np.asarray(tok))
        # trusscheck: allow[TRK104] -- the KV cache is preallocated at max_seq and tok is (requests,), so every decode step reuses one compiled shape
        cache, logits = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    gen = np.stack(out, 1)
    print(f"[serve] {args.arch}: {args.requests} requests, "
          f"prefill {args.prompt_len} toks in {t_prefill*1e3:.1f} ms, "
          f"{args.new_tokens} decode steps in {t_decode*1e3:.1f} ms "
          f"({args.requests*args.new_tokens/max(t_decode,1e-9):.0f} tok/s)")
    print("[serve] first request generation:", gen[0].tolist())
    if not np.isfinite(np.asarray(logits, np.float32)).all():
        raise RuntimeError("non-finite logits in the final decode step — "
                           "the served checkpoint or kernel path is broken")


if __name__ == "__main__":
    main()
