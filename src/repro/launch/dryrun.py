import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (arch × shape × mesh) cell.

For each cell: lower the step on the production mesh with ShapeDtypeStruct
inputs (no allocation), compile, and extract

  * memory_analysis  — per-device arg/output/temp bytes (proves it fits);
  * cost_analysis    — per-device HLO flops / bytes accessed;
  * collective bytes — parsed from the post-SPMD HLO text per collective op
                       (all-gather / all-reduce / reduce-scatter / all-to-all
                        / collective-permute);
  * the three roofline terms against TPU v5e constants
      compute    = flops_dev / 197e12
      memory     = bytes_dev / 819e9
      collective = comm_bytes_dev / 50e9   (per-link ICI, algo-bytes model)

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""

import argparse
import json
import re
import time
import traceback

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"\b(pred|[sbuf]\d+|bf16|f16|f32|f64)\[([\d,]*)\]")


def _type_bytes(text: str) -> int:
    """Sum bytes of every dtype[dims] token in ``text``."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective result bytes from post-SPMD HLO.

    HLO lines look like ``%name = TYPE[dims]{layout} op(args...)`` — the
    result type sits between '=' and the op name; tuple results list several
    TYPE[dims] tokens there.
    """
    out = {k: 0 for k in COLLECTIVE_OPS}
    counts = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1]
        for op in COLLECTIVE_OPS:
            m = re.search(rf"\b{op}(-start)?\(", rhs)
            if m:
                out[op] += _type_bytes(rhs[: m.start()])
                counts[op] += 1
                break
    return {"bytes": out, "counts": counts}


def run_cell(cell, mesh, mesh_name: str) -> dict:
    rec = {"arch": cell.arch, "shape": cell.shape, "kind": cell.kind,
           "mesh": mesh_name, "model_flops": cell.model_flops,
           "notes": cell.notes}
    t0 = time.time()
    try:
        import jax

        with mesh:
            built = cell.build(mesh)
            fn, args, in_sh = built[:3]
            out_sh = built[3] if len(built) > 3 else None
            jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=cell.donate)
            lowered = jfn.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        n_dev = mesh.size
        # cost_analysis counts while bodies once; the loop-aware walker
        # multiplies by trip counts (launch/hlo_analysis.py).
        from repro.launch import hlo_analysis

        loops = hlo_analysis.analyze(hlo)
        coll = {"bytes": loops["collective_bytes"],
                "counts": loops["collective_counts"]}
        flops_dev = max(float(cost.get("flops", 0.0)), float(loops["flops"]))
        bytes_dev = max(float(cost.get("bytes accessed", 0.0)),
                        float(loops["dot_bytes"]))
        comm_dev = float(sum(coll["bytes"].values()))
        rec.update({
            "ok": True,
            "lower_s": round(t_lower - t0, 1),
            "compile_s": round(t_compile - t_lower, 1),
            "n_devices": n_dev,
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "xla_cost_flops": float(cost.get("flops", 0.0)),
            "loop_aware_flops": float(loops["flops"]),
            "collective_bytes_per_device": comm_dev,
            "collectives": coll,
            "arg_bytes": int(mem.argument_size_in_bytes),
            "out_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
            "t_compute": flops_dev / PEAK_FLOPS,
            "t_memory": bytes_dev / HBM_BW,
            "t_collective": comm_dev / ICI_BW,
        })
        terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
                 "collective": rec["t_collective"]}
        rec["bottleneck"] = max(terms, key=terms.get)
        total_flops = flops_dev * n_dev
        rec["model_flops_ratio"] = (cell.model_flops / total_flops
                                    if total_flops else 0.0)
        rec["roofline_fraction"] = (
            rec["t_compute"] / max(max(terms.values()), 1e-30))
    except Exception as e:  # noqa: BLE001 — record and continue
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.configs import registry
    from repro.launch.mesh import make_production_mesh

    meshes = []
    if args.both_meshes:
        meshes = [("pod16x16", make_production_mesh(multi_pod=False)),
                  ("2pod16x16", make_production_mesh(multi_pod=True))]
    else:
        mp = args.multi_pod
        meshes = [("2pod16x16" if mp else "pod16x16",
                   make_production_mesh(multi_pod=mp))]

    cells = []
    for arch in registry.ARCHS:
        if args.arch and arch != args.arch:
            continue
        for shape, cell in registry.get_cells(arch).items():
            if args.shape and shape != args.shape:
                continue
            cells.append(cell)
    if not cells:
        raise SystemExit("no cells matched")

    results = []
    for mesh_name, mesh in meshes:
        for cell in cells:
            print(f"[dryrun] {cell.key} on {mesh_name} ...", flush=True)
            rec = run_cell(cell, mesh, mesh_name)
            status = "OK" if rec.get("ok") else f"FAIL {rec.get('error')}"
            extra = ""
            if rec.get("ok"):
                extra = (f" compute={rec['t_compute']:.3e}s"
                         f" memory={rec['t_memory']:.3e}s"
                         f" coll={rec['t_collective']:.3e}s"
                         f" bottleneck={rec['bottleneck']}"
                         f" temp={rec['temp_bytes']/2**30:.2f}GiB")
            print(f"[dryrun] {cell.key} {mesh_name}: {status}{extra}",
                  flush=True)
            results.append(rec)
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(r.get("ok", False) for r in results)
    print(f"[dryrun] {n_ok}/{len(results)} cells compiled")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
