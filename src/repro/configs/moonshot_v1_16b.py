"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (MHA kv=16) d_ff=1408
vocab=163840, 64 experts top-6 + 2 shared (DeepSeek/Moonlight style).
[hf:moonshotai/Moonlight-16B-A3B]  Deviation: Moonlight's first dense layer
is modeled as MoE like the rest (DESIGN.md §7)."""
import jax.numpy as jnp
from repro.models.transformer import LMConfig
from repro.configs import lm_family

CONFIG = LMConfig(
    name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_q=16, n_kv=16,
    d_head=128, vocab=163840, qkv_bias=False, tie_embed=False,
    pattern=("full",), rope_theta=50_000.0,
    n_experts=64, top_k=6, d_ff_expert=1408, n_shared_experts=2,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat=True, microbatches=8,
)
CELLS = lm_family.make_cells("moonshot-v1-16b-a3b", CONFIG, microbatches=8)
