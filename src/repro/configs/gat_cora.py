"""gat-cora [gnn] — 2 layers, d_hidden=8, 8 heads, attention aggregator.
[arXiv:1710.10903]"""
from repro.models.gnn.models import GATConfig
from repro.configs import gnn_family

CONFIG = GATConfig(n_layers=2, d_hidden=8, n_heads=8)
CELLS = gnn_family.gat_cells("gat-cora", CONFIG)
