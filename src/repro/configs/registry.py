"""Architecture registry: --arch <id> -> config module + cells."""
from __future__ import annotations

import importlib

ARCHS = {
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "granite-8b": "repro.configs.granite_8b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b",
    "meshgraphnet": "repro.configs.meshgraphnet",
    "equiformer-v2": "repro.configs.equiformer_v2",
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "gat-cora": "repro.configs.gat_cora",
    "din": "repro.configs.din",
}

LM_ARCHS = [a for a in ARCHS if a in (
    "qwen2.5-14b", "gemma3-4b", "granite-8b",
    "phi3.5-moe-42b-a6.6b", "moonshot-v1-16b-a3b")]
GNN_ARCHS = ["meshgraphnet", "equiformer-v2", "graphsage-reddit", "gat-cora"]
RECSYS_ARCHS = ["din"]


def get_module(arch: str):
    return importlib.import_module(ARCHS[arch])


def get_config(arch: str):
    return get_module(arch).CONFIG


def get_cells(arch: str) -> dict:
    return get_module(arch).CELLS


def get_cell(arch: str, shape: str):
    return get_cells(arch)[shape]


def all_cells():
    for arch in ARCHS:
        for shape, cell in get_cells(arch).items():
            yield cell
