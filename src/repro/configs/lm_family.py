"""LM-family cells: train_4k / prefill_32k / decode_32k / long_500k.

All four shapes lower for every LM arch.  ``long_500k`` is a decode shape —
per-step attention cost is O(cache), not O(cache²); the sub-quadratic
concern applies to prefill, which is never lowered at 500k (DESIGN.md §4).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import cells as C
from repro.models import transformer as T
from repro.optim import adamw

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1, long=True),
}

OCFG = adamw.AdamWConfig(lr=3e-4, warmup_steps=2000, total_steps=100_000)


def _attn_fwd_flops(cfg: T.LMConfig, batch: int, seq: int) -> float:
    """Causal attention matmul flops (QKᵀ + PV), window-aware per layer."""
    per_layer_full = 2 * 2 * batch * seq * seq * cfg.n_q * cfg.d_head / 2
    per_layer_local = 2 * 2 * batch * seq * min(cfg.window, seq) * cfg.n_q * cfg.d_head
    total = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.pattern[i % len(cfg.pattern)]
        total += per_layer_local if kind == "local" else per_layer_full
    return total


def _decode_attn_flops(cfg: T.LMConfig, batch: int, cache: int) -> float:
    total = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.pattern[i % len(cfg.pattern)]
        s = min(cfg.window, cache) if kind == "local" else cache
        total += 2 * 2 * batch * s * cfg.n_q * cfg.d_head
    return total


def model_flops(cfg: T.LMConfig, shape_id: str) -> float:
    sh = SHAPES[shape_id]
    n_active = cfg.active_param_count()
    if sh["kind"] == "train":
        toks = sh["batch"] * sh["seq"]
        return 3 * (2 * n_active * toks + _attn_fwd_flops(cfg, sh["batch"], sh["seq"]))
    if sh["kind"] == "prefill":
        toks = sh["batch"] * sh["seq"]
        return 2 * n_active * toks + _attn_fwd_flops(cfg, sh["batch"], sh["seq"])
    return 2 * n_active * sh["batch"] + _decode_attn_flops(cfg, sh["batch"], sh["seq"])


def make_cells(arch: str, cfg: T.LMConfig, microbatches: int = 8) -> dict:
    cells = {}
    for shape_id, sh in SHAPES.items():
        cells[shape_id] = C.Cell(
            arch=arch, shape=shape_id, kind=sh["kind"],
            model_flops=model_flops(cfg, shape_id),
            build=partial(_build, cfg, shape_id, microbatches),
            donate=(1,) if sh["kind"] == "decode" else (),
        )
    return cells


def _build(cfg: T.LMConfig, shape_id: str, microbatches: int, mesh):
    sh = SHAPES[shape_id]
    b, s = sh["batch"], sh["seq"]
    params_abs = C.abstract_params(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = T.param_specs(cfg)
    psh, _ = C.train_state_shardings(mesh, pspecs, params_abs)

    if sh["kind"] == "train":
        opt_abs = C.abstract_params(adamw.init_state, params_abs)
        _, osh = C.train_state_shardings(mesh, pspecs, params_abs)
        batch_abs = {"tokens": C.sds((b, s), jnp.int32),
                     "labels": C.sds((b, s), jnp.int32)}
        bsh = C.shardings(mesh, {"tokens": C.dp(mesh, None),
                                 "labels": C.dp(mesh, None)})
        # ZeRO-2: gradient accumulator sharded like the master params
        gspecs = adamw.zero_specs(
            pspecs, params_abs,
            data_axes=tuple(a for a in ("pod", "data") if a in mesh.axis_names),
            data_size=C.data_axis_size(mesh))["master"]
        step = C.make_train_step(
            lambda p, mb: T.loss_fn(p, mb, cfg)[0], OCFG, microbatches,
            grad_specs=gspecs)
        return step, (params_abs, opt_abs, batch_abs), (psh, osh, bsh)

    if sh["kind"] == "prefill":
        toks_abs = C.sds((b, s), jnp.int32)
        tsh = C.shardings(mesh, C.dp(mesh, None))

        def step(params, tokens):
            return T.prefill(params, tokens, cfg)

        return step, (params_abs, toks_abs), (psh, tsh)

    # decode — cache donated (in-place update) with matching out sharding
    long = sh.get("long", False)
    cache_abs = C.abstract_params(
        lambda: T.init_cache(cfg, b, s))
    csh = C.shardings(mesh, T.cache_specs(cfg, long_context=long))
    toks_abs = C.sds((b,), jnp.int32)
    tsh = C.shardings(mesh, P() if long else C.dp(mesh))

    def step(params, cache, tokens):
        return T.decode_step(params, cache, tokens, cfg)

    return (step, (params_abs, cache_abs, toks_abs), (psh, csh, tsh),
            (csh, None))
