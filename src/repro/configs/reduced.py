"""Reduced per-arch configs + synthetic batches for smoke tests and the
CPU-scale example drivers.  Same model code as the full configs — only
depths/widths/vocabulary/graph sizes shrink."""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data import graphgen
from repro.data.recsys_stream import RecsysStream
from repro.data.tokens import TokenStream
from repro.models import transformer as T
from repro.models.gnn import models as G
from repro.models.recsys import din as DIN


def reduced_lm(cfg: T.LMConfig) -> T.LMConfig:
    pat = cfg.pattern
    n_layers = max(2 * len(pat) + (1 if cfg.n_layers % len(pat) else 0),
                   2 + cfg.n_layers % len(pat))
    return dataclasses.replace(
        cfg, n_layers=n_layers, d_model=64,
        n_q=4, n_kv=max(1, 4 * cfg.n_kv // cfg.n_q), d_head=16,
        d_ff=128, d_ff_expert=32 if cfg.moe else 0,
        n_experts=min(cfg.n_experts, 8), vocab=211,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        microbatches=1, attn_chunk=64,
    )


def reduced_gnn(cfg):
    if isinstance(cfg, G.MeshGraphNetConfig):
        return dataclasses.replace(cfg, n_layers=3, d_hidden=32, d_node_in=8)
    if isinstance(cfg, G.GraphSAGEConfig):
        return dataclasses.replace(cfg, d_hidden=32, d_in=8, n_classes=5)
    if isinstance(cfg, G.GATConfig):
        return dataclasses.replace(cfg, d_in=8, n_classes=5)
    if isinstance(cfg, G.EquiformerV2Config):
        return dataclasses.replace(cfg, n_layers=2, d_hidden=16, l_max=2,
                                   n_heads=4, d_in=8)
    raise TypeError(cfg)


def reduced_din(cfg: DIN.DINConfig) -> DIN.DINConfig:
    return dataclasses.replace(cfg, n_items=5000, n_cats=20)


def _gnn_batch(arch, seed=0):
    n = 48
    edges = graphgen.erdos_renyi(n, 160, seed=seed)
    b = graphgen.gnn_full_batch(n, edges, d_feat=8, n_classes=5, seed=seed)
    b["targets_node"] = b.pop("targets", None)
    out = {"node_feat": b["node_feat"], "edge_index": b["edge_index"],
           "edge_mask": b["edge_mask"], "positions": b["positions"],
           "edge_feat": b["edge_feat"]}
    rng = np.random.default_rng(seed)
    if arch == "meshgraphnet":
        out["targets"] = b["targets_vec"]
        out["node_mask"] = np.ones(n, np.float32)
    elif arch == "equiformer-v2":
        out["targets"] = rng.standard_normal(n).astype(np.float32)
        out["node_mask"] = np.ones(n, np.float32)
    else:
        out["labels"] = b["labels"]
        out["label_mask"] = b["label_mask"]
    return {k: jnp.asarray(v) for k, v in out.items() if v is not None}


def make_reduced(arch: str):
    """Returns (cfg, init_fn, loss_fn, batch_fn) at smoke scale."""
    full = registry.get_config(arch)
    if arch in registry.LM_ARCHS:
        cfg = reduced_lm(full)
        stream = TokenStream(cfg.vocab, seq_len=32, global_batch=4, seed=0)

        def batch_fn(step):
            return {k: jnp.asarray(v) for k, v in stream.batch(step).items()}

        return (cfg,
                lambda: T.init_params(jax.random.PRNGKey(0), cfg),
                lambda p, b: T.loss_fn(p, b, cfg)[0],
                batch_fn)
    if arch in registry.GNN_ARCHS:
        cfg = reduced_gnn(full)
        init = {
            "meshgraphnet": G.mgn_init, "equiformer-v2": G.eqv2_init,
            "graphsage-reddit": G.sage_init, "gat-cora": G.gat_init,
        }[arch]
        loss = {
            "meshgraphnet": G.mgn_loss, "equiformer-v2": G.eqv2_loss,
            "graphsage-reddit": G.sage_loss, "gat-cora": G.gat_loss,
        }[arch]
        return (cfg,
                lambda: init(jax.random.PRNGKey(0), cfg),
                lambda p, b: loss(p, b, cfg),
                lambda step: _gnn_batch(arch, seed=step % 7))
    # din
    cfg = reduced_din(full)
    stream = RecsysStream(cfg.n_items, cfg.n_cats, cfg.seq_len,
                          global_batch=8, seed=0)

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in stream.batch(step).items()}

    return (cfg,
            lambda: DIN.din_init(jax.random.PRNGKey(0), cfg),
            lambda p, b: DIN.din_loss(p, b, cfg),
            batch_fn)
