"""meshgraphnet [gnn] — 15 layers, d_hidden=128, sum aggregator,
2-layer MLPs.  [arXiv:2010.03409]"""
from repro.models.gnn.models import MeshGraphNetConfig
from repro.configs import gnn_family

CONFIG = MeshGraphNetConfig(n_layers=15, d_hidden=128, mlp_layers=2,
                            aggregator="sum")
CELLS = gnn_family.mgn_cells("meshgraphnet", CONFIG)
