"""din [recsys] — embed_dim=18, seq_len=100, attn MLP 80-40, MLP 200-80,
target attention.  [arXiv:1706.06978]  Vocabulary 10M items / 1k categories
(DIN-paper scale; DESIGN.md §7)."""
from repro.models.recsys.din import DINConfig
from repro.configs import recsys_family

CONFIG = DINConfig(n_items=10_000_000, n_cats=1_000, embed_dim=18,
                   seq_len=100, attn_mlp=(80, 40), mlp=(200, 80))
CELLS = recsys_family.make_cells("din", CONFIG)
