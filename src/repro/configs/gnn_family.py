"""GNN-family cells: full_graph_sm / minibatch_lg / ogb_products / molecule.

All shapes are training cells.  Input d_feat / n_classes follow the shape's
source dataset (cora / reddit / ogbn-products / synthetic molecules); the
arch configs keep their assigned depths/widths and adapt the input layer.

Sharding: edge arrays shard over all mesh axes (pure edge parallelism),
node arrays replicate (baseline — segment_sum emits psums).  Exceptions:
* equiformer-v2 × ogb_products: node features are 61 GB — runs the ring
  reduce-scatter path (models/gnn/distributed.py) with node-sharded state;
* equiformer-v2 × minibatch_lg: per-seed batched subtrees, vmap over the
  data axes (embarrassingly parallel).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import cells as C
from repro.models.gnn import models as G
from repro.optim import adamw

OCFG = adamw.AdamWConfig(lr=1e-3, warmup_steps=100, total_steps=20_000)

SHAPES = {
    "full_graph_sm": dict(n=2708, e=10556, d_feat=1433, n_classes=7),
    "minibatch_lg": dict(n=232_965, e=114_615_892, d_feat=602, n_classes=41,
                         batch_nodes=1024, fanouts=(15, 10)),
    "ogb_products": dict(n=2_449_029, e=61_859_140, d_feat=100, n_classes=47),
    "molecule": dict(n_graphs=128, nodes=30, edges=64, d_feat=16),
}


_EDGE_PAD = 512   # lcm of both production mesh sizes


def _pad_to(x: int, m: int = _EDGE_PAD) -> int:
    return -(-x // m) * m


def _flat_sizes(shape_id):
    """(n_nodes, n_directed_edges): edges padded to shard over 256/512
    devices (the data pipeline pads with masked entries)."""
    sh = SHAPES[shape_id]
    if shape_id == "minibatch_lg":
        b, (f1, f2) = sh["batch_nodes"], sh["fanouts"]
        n = b * (1 + f1 + f1 * f2)
        e = b * (f1 + f1 * f2)
        return n, _pad_to(e)
    if shape_id == "molecule":
        return sh["n_graphs"] * sh["nodes"], _pad_to(sh["n_graphs"] * sh["edges"] * 2)
    return sh["n"], _pad_to(sh["e"] * 2)


def _batch_abs(shape_id, *, need_edge_feat=False, need_pos=False,
               regression=False):
    sh = SHAPES[shape_id]
    n, e = _flat_sizes(shape_id)
    batch = {
        "node_feat": C.sds((n, sh["d_feat"])),
        "edge_index": C.sds((e, 2), jnp.int32),
        "edge_mask": C.sds((e,), jnp.bool_),
    }
    if need_edge_feat:
        batch["edge_feat"] = C.sds((e, 4))
    if need_pos:
        batch["positions"] = C.sds((n, 3))
    if regression:
        batch["targets"] = C.sds((n, 3) if need_edge_feat else (n,))
        batch["node_mask"] = C.sds((n,))
    else:
        batch["labels"] = C.sds((n,), jnp.int32)
        batch["label_mask"] = C.sds((n,))
    return batch


def _batch_specs(mesh, batch):
    ax = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    edge_spec = P(ax)
    specs = {}
    for k, v in batch.items():
        if k.startswith("edge"):
            specs[k] = P(ax, *([None] * (len(v.shape) - 1)))
        else:
            specs[k] = P(*([None] * len(v.shape)))   # nodes replicated
    return C.shardings(mesh, specs)


def _train_cell(arch, shape_id, cfg, loss_fn, init_fn, flops, batch_builder,
                notes=""):
    def build(mesh):
        params_abs = C.abstract_params(init_fn)
        opt_abs = C.abstract_params(adamw.init_state, params_abs)
        batch_abs, bsh = batch_builder(mesh)
        psh = None   # params replicated (GNN params are small)
        step = C.make_train_step(loss_fn, OCFG, microbatches=1)
        return step, (params_abs, opt_abs, batch_abs), (psh, None, bsh)

    return C.Cell(arch=arch, shape=shape_id, kind="train",
                  model_flops=flops, build=build, notes=notes)


# ---------------------------------------------------------------------------
# flops estimates (documented in EXPERIMENTS.md §Roofline)
# ---------------------------------------------------------------------------

def mgn_flops(cfg, n, e):
    c = cfg.d_hidden
    per_layer = 2 * e * (4 * c * c) + 2 * n * (3 * c * c)
    return 3 * cfg.n_layers * per_layer


def sage_flops(cfg, n, e, d_in):
    total, d = 0.0, d_in
    for _ in range(cfg.n_layers):
        total += 2 * 2 * n * d * cfg.d_hidden + 2 * e * d
        d = cfg.d_hidden
    return 3 * total


def gat_flops(cfg, n, e, d_in, n_classes):
    total, d = 0.0, d_in
    for i in range(cfg.n_layers):
        dh = n_classes if i == cfg.n_layers - 1 else cfg.d_hidden
        total += 2 * n * d * cfg.n_heads * dh + 4 * e * cfg.n_heads * dh
        d = cfg.n_heads * dh
    return 3 * total


def eqv2_flops(cfg, n, e):
    S, Cc = cfg.n_sph, cfg.d_hidden
    rot = 2 * 2 * e * S * S * Cc
    so2 = 0.0
    for m in range(cfg.m_max + 1):
        n_l = cfg.l_max + 1 - m
        so2 += 2 * e * n_l * n_l * Cc * Cc * (2 if m else 1)
    return 3 * cfg.n_layers * (rot + so2)


# ---------------------------------------------------------------------------
# per-arch cell builders
# ---------------------------------------------------------------------------

def mgn_cells(arch, base: G.MeshGraphNetConfig):
    cells = {}
    for shape_id in SHAPES:
        sh = SHAPES[shape_id]
        n, e = _flat_sizes(shape_id)
        cfg = dataclasses.replace(base, d_node_in=sh["d_feat"])

        def builder(mesh, shape_id=shape_id):
            b = _batch_abs(shape_id, need_edge_feat=True, regression=True)
            return b, _batch_specs(mesh, b)

        cells[shape_id] = _train_cell(
            arch, shape_id, cfg,
            lambda p, b, cfg=cfg: G.mgn_loss(p, b, cfg),
            lambda cfg=cfg: G.mgn_init(jax.random.PRNGKey(0), cfg),
            mgn_flops(cfg, n, e), builder)
    return cells


def sage_cells(arch, base: G.GraphSAGEConfig):
    from repro.models.gnn import distributed as D

    cells = {}
    for shape_id in SHAPES:
        sh = SHAPES[shape_id]
        n, e = _flat_sizes(shape_id)
        cfg = dataclasses.replace(base, d_in=sh["d_feat"],
                                  n_classes=sh.get("n_classes", 2))

        if shape_id == "ogb_products":
            # node-sharded ring reduce-scatter (paper-representative
            # hillclimb pair; baseline replicate+psum archived — §Perf P6)
            def build(mesh, cfg=cfg, sh=sh):
                Pn = int(np.prod([mesh.shape[a] for a in ("data", "model")
                                  if a in mesh.axis_names]))
                n_pad = -(-sh["n"] // Pn) * Pn
                e_dir = sh["e"] * 2
                Eb = max(64, int(2 * e_dir / (Pn * Pn)))
                batch_abs = {
                    "node_feat": C.sds((n_pad, sh["d_feat"])),
                    "labels": C.sds((n_pad,), jnp.int32),
                    "label_mask": C.sds((n_pad,)),
                    "src_loc": C.sds((Pn, Pn, Eb), jnp.int32),
                    "dst_loc": C.sds((Pn, Pn, Eb), jnp.int32),
                    "edge_mask": C.sds((Pn, Pn, Eb), jnp.bool_),
                }
                ax = tuple(a for a in ("data", "model") if a in mesh.axis_names)
                bsh = C.shardings(mesh, {
                    k: P(ax, *([None] * (len(v.shape) - 1)))
                    for k, v in batch_abs.items()})
                params_abs = C.abstract_params(
                    lambda: G.sage_init(jax.random.PRNGKey(0), cfg))
                opt_abs = C.abstract_params(adamw.init_state, params_abs)
                step = C.make_train_step(
                    lambda p, b: D.sage_ring_loss(p, b, cfg, mesh), OCFG)
                return step, (params_abs, opt_abs, batch_abs), (None, None, bsh)

            cells[shape_id] = C.Cell(
                arch=arch, shape=shape_id, kind="train",
                model_flops=sage_flops(cfg, sh["n"], sh["e"] * 2, cfg.d_in),
                build=build, notes="ring reduce-scatter node-sharded path")
            continue

        def builder(mesh, shape_id=shape_id):
            b = _batch_abs(shape_id)
            return b, _batch_specs(mesh, b)

        cells[shape_id] = _train_cell(
            arch, shape_id, cfg,
            lambda p, b, cfg=cfg: G.sage_loss(p, b, cfg),
            lambda cfg=cfg: G.sage_init(jax.random.PRNGKey(0), cfg),
            sage_flops(cfg, n, e, cfg.d_in), builder)
    return cells


def gat_cells(arch, base: G.GATConfig):
    cells = {}
    for shape_id in SHAPES:
        sh = SHAPES[shape_id]
        n, e = _flat_sizes(shape_id)
        cfg = dataclasses.replace(base, d_in=sh["d_feat"],
                                  n_classes=sh.get("n_classes", 2))

        def builder(mesh, shape_id=shape_id):
            b = _batch_abs(shape_id)
            return b, _batch_specs(mesh, b)

        cells[shape_id] = _train_cell(
            arch, shape_id, cfg,
            lambda p, b, cfg=cfg: G.gat_loss(p, b, cfg),
            lambda cfg=cfg: G.gat_init(jax.random.PRNGKey(0), cfg),
            gat_flops(cfg, n, e, cfg.d_in, cfg.n_classes), builder)
    return cells


def eqv2_cells(arch, base: G.EquiformerV2Config):
    from repro.models.gnn import distributed as D

    cells = {}
    for shape_id in SHAPES:
        sh = SHAPES[shape_id]
        n, e = _flat_sizes(shape_id)
        cfg = dataclasses.replace(base, d_in=sh["d_feat"])

        if shape_id == "ogb_products":
            # bf16 ring payload: halves the dominant ICI term (§Perf P4)
            cfg = dataclasses.replace(cfg, ring_dtype="bf16")

            def build(mesh, cfg=cfg, sh=sh):
                Pn = int(np.prod([mesh.shape[a] for a in ("data", "model")
                                  if a in mesh.axis_names]))
                n_pad = -(-sh["n"] // Pn) * Pn
                e_dir = sh["e"] * 2
                Eb = max(64, int(2 * e_dir / (Pn * Pn)))
                batch_abs = {
                    "node_feat": C.sds((n_pad, sh["d_feat"])),
                    "positions": C.sds((n_pad, 3)),
                    "targets": C.sds((n_pad,)),
                    "node_mask": C.sds((n_pad,)),
                    "src_loc": C.sds((Pn, Pn, Eb), jnp.int32),
                    "dst_loc": C.sds((Pn, Pn, Eb), jnp.int32),
                    "edge_mask": C.sds((Pn, Pn, Eb), jnp.bool_),
                    "dst_pos": C.sds((Pn, Pn, Eb, 3)),
                }
                ax = tuple(a for a in ("data", "model") if a in mesh.axis_names)
                spec = P(ax)
                bsh = C.shardings(mesh, {
                    k: P(ax, *([None] * (len(v.shape) - 1)))
                    for k, v in batch_abs.items()})
                params_abs = C.abstract_params(
                    lambda: G.eqv2_init(jax.random.PRNGKey(0), cfg))
                opt_abs = C.abstract_params(adamw.init_state, params_abs)
                step = C.make_train_step(
                    lambda p, b: D.eqv2_ring_loss(p, b, cfg, mesh), OCFG)
                return step, (params_abs, opt_abs, batch_abs), (None, None, bsh)

            cells[shape_id] = C.Cell(
                arch=arch, shape=shape_id, kind="train",
                model_flops=eqv2_flops(cfg, sh["n"], sh["e"] * 2), build=build,
                notes="ring reduce-scatter node-sharded path")
            continue

        if shape_id == "minibatch_lg":
            b_seeds = sh["batch_nodes"]
            nt = 1 + sh["fanouts"][0] + sh["fanouts"][0] * sh["fanouts"][1]
            et = nt - 1

            def build(mesh, cfg=cfg, b_seeds=b_seeds, nt=nt, et=et):
                batch_abs = {
                    "node_feat": C.sds((b_seeds, nt, cfg.d_in)),
                    "positions": C.sds((b_seeds, nt, 3)),
                    "edge_index": C.sds((b_seeds, et, 2), jnp.int32),
                    "edge_mask": C.sds((b_seeds, et), jnp.bool_),
                    "targets": C.sds((b_seeds,)),
                }
                bsh = C.shardings(mesh, {
                    k: C.dp(mesh, *([None] * (len(v.shape) - 1)))
                    for k, v in batch_abs.items()})
                params_abs = C.abstract_params(
                    lambda: G.eqv2_init(jax.random.PRNGKey(0), cfg))
                opt_abs = C.abstract_params(adamw.init_state, params_abs)

                def loss(p, batch):
                    def per_tree(nf, pos, ei, em):
                        return G.eqv2_forward(
                            p, {"node_feat": nf, "positions": pos,
                                "edge_index": ei, "edge_mask": em}, cfg)[0, 0]
                    out = jax.vmap(per_tree)(
                        batch["node_feat"], batch["positions"],
                        batch["edge_index"], batch["edge_mask"])
                    return jnp.mean(jnp.square(out - batch["targets"]))

                step = C.make_train_step(loss, OCFG)
                return step, (params_abs, opt_abs, batch_abs), (None, None, bsh)

            cells[shape_id] = C.Cell(
                arch=arch, shape=shape_id, kind="train",
                model_flops=eqv2_flops(cfg, b_seeds * nt, b_seeds * et),
                build=build, notes="per-seed batched subtrees (vmap)")
            continue

        chunks = 8 if shape_id == "molecule" else 1
        cfg_c = dataclasses.replace(cfg, edge_chunks=chunks)

        def builder(mesh, shape_id=shape_id):
            b = _batch_abs(shape_id, need_pos=True, regression=True)
            b["targets"] = C.sds((_flat_sizes(shape_id)[0],))
            return b, _batch_specs(mesh, b)

        cells[shape_id] = _train_cell(
            arch, shape_id, cfg_c,
            lambda p, b, cfg_c=cfg_c: G.eqv2_loss(p, b, cfg_c),
            lambda cfg_c=cfg_c: G.eqv2_init(jax.random.PRNGKey(0), cfg_c),
            eqv2_flops(cfg_c, n, e), builder)
    return cells
