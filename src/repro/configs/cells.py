"""Cell abstraction: one (architecture × input-shape) lowering unit.

A Cell knows how to build, for a given mesh: the step function (train /
prefill / decode / serve / retrieval), abstract inputs (ShapeDtypeStruct —
no allocation), and input shardings.  launch/dryrun.py consumes cells for
``.lower().compile()`` + roofline extraction; launch/train.py and the smoke
tests consume reduced variants of the same configs.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.optim import adamw


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                     # train | prefill | decode | serve | retrieval
    model_flops: float            # analytic useful flops per step (global)
    build: Callable[[Any], tuple]  # mesh -> (fn, args, in_sh[, out_sh])
    notes: str = ""
    donate: tuple = ()            # donated arg indices (decode: the cache)

    @property
    def key(self) -> str:
        return f"{self.arch}×{self.shape}"


def resolve_spec(mesh, spec: P) -> P:
    """Drop axes not present on this mesh (e.g. 'pod' on single pod)."""
    names = set(mesh.axis_names)

    def fix(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    return P(*(fix(e) for e in spec))


def shardings(mesh, spec_tree):
    """Pytree of PartitionSpec -> pytree of NamedSharding (mesh-resolved)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(mesh, s)),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def dp(mesh, *rest) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return P(lead, *rest)


def data_axis_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in ("pod", "data")
                        if a in mesh.axis_names]))


def abstract_params(init_fn, *args) -> Any:
    return jax.eval_shape(init_fn, *args)


def make_train_step(loss_fn, ocfg: adamw.AdamWConfig, microbatches: int = 1,
                    grad_specs=None):
    """Generic train step: grad-accum scan over microbatches + AdamW.

    loss_fn(params, batch) -> scalar.  Gradients accumulate in f32 (the
    fits-in-fast-memory discipline: activation peak is ONE microbatch).

    ``grad_specs``: optional pytree of PartitionSpec for the f32 gradient
    accumulator — ZeRO-2: each microbatch's gradient is reduce-scattered
    onto the data axes instead of kept whole per device (a 14B-param f32
    grad is 3.5 GB/chip model-sharded but 219 MB ZeRO-sharded; the MoE
    42B config doesn't fit HBM without this — EXPERIMENTS.md §Perf P3).
    """

    def _constrain(g):
        if grad_specs is None:
            return g
        from jax.sharding import PartitionSpec as PS
        from repro.models.common import shard

        flat_g, tree = jax.tree.flatten(g)
        flat_s = jax.tree.leaves(grad_specs,
                                 is_leaf=lambda x: isinstance(x, PS))
        return jax.tree.unflatten(
            tree, [shard(a, s) for a, s in zip(flat_g, flat_s)])

    def step(params, opt_state, batch):
        if microbatches > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                g_acc, l_acc = acc
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                g_acc = _constrain(g_acc)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
            g0 = _constrain(g0)
            (grads, loss), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            grads = _constrain(grads)
        params, opt_state, om = adamw.update(ocfg, params, opt_state, grads)
        return params, opt_state, {"loss": loss, **om}

    return step


def train_state_shardings(mesh, cfg_specs, params_abs):
    """(param shardings, ZeRO opt-state shardings) for a param spec tree."""
    psh = shardings(mesh, cfg_specs)
    osp = adamw.zero_specs(
        cfg_specs, params_abs,
        data_axes=tuple(a for a in ("pod", "data") if a in mesh.axis_names),
        data_size=data_axis_size(mesh))
    return psh, shardings(mesh, osp)


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)
