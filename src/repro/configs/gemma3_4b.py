"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144; 5:1 local:global (window 1024), 128k context, tied embeddings.
[hf:google/gemma-3-4b-pt]"""
import jax.numpy as jnp
from repro.models.transformer import LMConfig
from repro.configs import lm_family

CONFIG = LMConfig(
    name="gemma3-4b", n_layers=34, d_model=2560, n_q=8, n_kv=4,
    d_head=256, d_ff=10240, vocab=262144, qkv_bias=False, tie_embed=True,
    pattern=("local",) * 5 + ("global",), window=1024,
    rope_theta=1_000_000.0, rope_theta_local=10_000.0,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat=True, microbatches=8,
)
CELLS = lm_family.make_cells("gemma3-4b", CONFIG, microbatches=8)
