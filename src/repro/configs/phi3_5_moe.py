"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct]"""
import jax.numpy as jnp
from repro.models.transformer import LMConfig
from repro.configs import lm_family

CONFIG = LMConfig(
    name="phi3.5-moe-42b-a6.6b", n_layers=32, d_model=4096, n_q=32, n_kv=8,
    d_head=128, vocab=32064, qkv_bias=False, tie_embed=False,
    pattern=("full",), rope_theta=10_000.0,
    n_experts=16, top_k=2, d_ff_expert=6400, n_shared_experts=0,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat=True, microbatches=8,
)
CELLS = lm_family.make_cells("phi3.5-moe-42b-a6.6b", CONFIG, microbatches=8)
