"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064; GQA with QKV bias.  [hf:Qwen/Qwen2.5-14B]"""
import jax.numpy as jnp
from repro.models.transformer import LMConfig
from repro.configs import lm_family

CONFIG = LMConfig(
    name="qwen2.5-14b", n_layers=48, d_model=5120, n_q=40, n_kv=8,
    d_head=128, d_ff=13824, vocab=152064, qkv_bias=True, tie_embed=False,
    pattern=("full",), rope_theta=1_000_000.0,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat=True, microbatches=8,
)
CELLS = lm_family.make_cells("qwen2.5-14b", CONFIG, microbatches=8)
