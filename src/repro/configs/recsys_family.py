"""DIN cells: train_batch / serve_p99 / serve_bulk / retrieval_cand."""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import cells as C
from repro.models.recsys import din as DIN
from repro.optim import adamw

OCFG = adamw.AdamWConfig(lr=1e-3, warmup_steps=500, total_steps=50_000)

SHAPES = {
    "train_batch": dict(kind="train", batch=65_536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262_144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}


def din_fwd_flops(cfg: DIN.DINConfig, batch: int) -> float:
    d = cfg.embed_dim
    attn = cfg.seq_len * (4 * d * cfg.attn_mlp[0]
                          + cfg.attn_mlp[0] * cfg.attn_mlp[1] + cfg.attn_mlp[1])
    head = 3 * d * cfg.mlp[0] + cfg.mlp[0] * cfg.mlp[1] + cfg.mlp[1]
    return 2.0 * batch * (attn + head)


def model_flops(cfg, shape_id):
    sh = SHAPES[shape_id]
    b = sh.get("n_candidates", sh["batch"])
    f = din_fwd_flops(cfg, b)
    return 3 * f if sh["kind"] == "train" else f


def _serve_batch_abs(cfg, b):
    return {
        "hist_items": C.sds((b, cfg.seq_len), jnp.int32),
        "hist_cats": C.sds((b, cfg.seq_len), jnp.int32),
        "hist_mask": C.sds((b, cfg.seq_len)),
        "cand_item": C.sds((b,), jnp.int32),
        "cand_cat": C.sds((b,), jnp.int32),
    }


def make_cells(arch: str, cfg: DIN.DINConfig) -> dict:
    cells = {}
    for shape_id, sh in SHAPES.items():
        cells[shape_id] = C.Cell(
            arch=arch, shape=shape_id, kind=sh["kind"],
            model_flops=model_flops(cfg, shape_id),
            build=partial(_build, cfg, shape_id),
        )
    return cells


def _build(cfg: DIN.DINConfig, shape_id: str, mesh):
    sh = SHAPES[shape_id]
    b = sh["batch"]
    params_abs = C.abstract_params(
        lambda: DIN.din_init(jax.random.PRNGKey(0), cfg))
    pspecs = DIN.param_specs(cfg)
    psh = C.shardings(mesh, pspecs)

    if sh["kind"] == "train":
        opt_abs = C.abstract_params(adamw.init_state, params_abs)
        _, osh = C.train_state_shardings(mesh, pspecs, params_abs)
        batch_abs = {**_serve_batch_abs(cfg, b), "label": C.sds((b,))}
        bsh = C.shardings(mesh, {
            k: C.dp(mesh, *([None] * (len(v.shape) - 1)))
            for k, v in batch_abs.items()})
        step = C.make_train_step(
            lambda p, mb: DIN.din_loss(p, mb, cfg), OCFG, microbatches=1)
        return step, (params_abs, opt_abs, batch_abs), (psh, osh, bsh)

    if sh["kind"] == "serve":
        batch_abs = _serve_batch_abs(cfg, b)
        bsh = C.shardings(mesh, {
            k: C.dp(mesh, *([None] * (len(v.shape) - 1)))
            for k, v in batch_abs.items()})

        def step(params, batch):
            return DIN.din_scores(params, batch, cfg)

        return step, (params_abs, batch_abs), (psh, bsh)

    # retrieval: 1 user × 1M candidates (exact assigned count — not
    # divisible by 256, so candidates shard over the data axes only and the
    # scan chunk batch (20000) shards inside).
    nc = sh["n_candidates"]
    cfg_r = dataclasses.replace(cfg, cand_chunks=50)
    batch_abs = {
        "hist_items": C.sds((1, cfg.seq_len), jnp.int32),
        "hist_cats": C.sds((1, cfg.seq_len), jnp.int32),
        "hist_mask": C.sds((1, cfg.seq_len)),
        "cand_items": C.sds((nc,), jnp.int32),
        "cand_cats": C.sds((nc,), jnp.int32),
    }
    bsh = C.shardings(mesh, {
        "hist_items": P(None, None), "hist_cats": P(None, None),
        "hist_mask": P(None, None),
        "cand_items": C.dp(mesh),
        "cand_cats": C.dp(mesh),
    })

    def step(params, batch):
        return DIN.din_retrieval(params, batch, cfg_r)

    return step, (params_abs, batch_abs), (psh, bsh)
