"""granite-8b [dense] — 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152; llama-arch code model.  [arXiv:2405.04324]"""
import jax.numpy as jnp
from repro.models.transformer import LMConfig
from repro.configs import lm_family

CONFIG = LMConfig(
    name="granite-8b", n_layers=36, d_model=4096, n_q=32, n_kv=8,
    d_head=128, d_ff=14336, vocab=49152, qkv_bias=False, tie_embed=True,
    pattern=("full",), rope_theta=10_000_000.0,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat=True, microbatches=8,
)
CELLS = lm_family.make_cells("granite-8b", CONFIG, microbatches=8)
