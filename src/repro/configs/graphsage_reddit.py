"""graphsage-reddit [gnn] — 2 layers, d_hidden=128, mean aggregator,
sample sizes 25-10 (training fanout per the minibatch shape is 15-10 as
assigned to the shape).  [arXiv:1706.02216]"""
from repro.models.gnn.models import GraphSAGEConfig
from repro.configs import gnn_family

CONFIG = GraphSAGEConfig(n_layers=2, d_hidden=128, aggregator="mean")
CELLS = gnn_family.sage_cells("graphsage-reddit", CONFIG)
