"""Generic fault-tolerant training loop.

Contract (mirrors production launchers):
  * deterministic data: ``batch_fn(step)`` must be reproducible (see
    data/tokens.py) so any restart or re-shard replays the exact stream;
  * checkpoint every ``ckpt_every`` steps via AsyncWriter (write-behind),
    atomic on disk; on entry the loop resumes from the latest checkpoint;
  * a step failure (device error, preemption, injected fault) triggers
    restore-from-latest and replay, up to ``max_restarts`` times — the
    node-failure story on a real cluster where the launcher re-execs us;
  * metrics stream to a CSV (host-side, cheap).
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import manager as ckpt


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 25
    keep: int = 3
    log_every: int = 10
    max_restarts: int = 3
    metrics_csv: Optional[str] = None


def run(
    cfg: LoopConfig,
    init_fn: Callable[[], Any],
    step_fn: Callable[[Any, dict], tuple[Any, dict]],
    batch_fn: Callable[[int], dict],
    fault_hook: Optional[Callable[[int], None]] = None,
) -> tuple[Any, list[dict]]:
    """Returns (final_state, metric rows)."""
    writer = ckpt.AsyncWriter(cfg.ckpt_dir, cfg.keep)
    rows: list[dict] = []
    restarts = 0

    def make_state():
        start = ckpt.latest_step(cfg.ckpt_dir)
        state = init_fn()
        if start is not None:
            state, meta = ckpt.restore(cfg.ckpt_dir, state)
            return state, int(meta.get("next_step", start))
        return state, 0

    state, step = make_state()
    t0 = time.time()
    while step < cfg.steps:
        try:
            if fault_hook is not None:
                fault_hook(step)
            batch = batch_fn(step)
            state, metrics = step_fn(state, batch)
            if (step % cfg.log_every == 0) or step == cfg.steps - 1:
                row = {"step": step,
                       "time": round(time.time() - t0, 3),
                       **{k: float(np.asarray(v)) for k, v in metrics.items()}}
                rows.append(row)
            step += 1
            if step % cfg.ckpt_every == 0 or step == cfg.steps:
                writer.save(step, state, {"next_step": step})
        except (FloatingPointError, RuntimeError, ValueError) as e:
            restarts += 1
            if restarts > cfg.max_restarts:
                raise
            writer.wait()
            state, step = make_state()
            rows.append({"step": step, "restart": restarts, "error": str(e)[:80]})
    writer.wait()
    if cfg.metrics_csv:
        _write_csv(cfg.metrics_csv, rows)
    return state, rows


def _write_csv(path: str, rows: list[dict]) -> None:
    if not rows:
        return
    keys = sorted({k for r in rows for k in r})
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(str(r.get(k, "")) for k in keys) + "\n")
