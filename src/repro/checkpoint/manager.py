"""Checkpointing: atomic, keep-k, restart- and reshard-safe.

Format: one directory per step containing ``arrays.npz`` (flattened leaves)
and ``manifest.json`` (step, tree structure, shapes/dtypes, user metadata).
Writes go to ``<dir>.tmp`` then ``os.rename`` — a crash mid-write never
corrupts the latest checkpoint (the fault-tolerance contract the train loop
relies on).  ``AsyncWriter`` moves serialization off the step path
(write-behind thread), bounding checkpoint stalls to an array copy.

Elastic re-shard: checkpoints store full (unsharded) arrays; ``restore``
optionally takes ``shardings`` and ``jax.device_put``s each leaf — loading a
256-chip checkpoint onto a 512-chip mesh (or onto 1 CPU) is the same call.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

_EXOTIC = {"bfloat16": (np.uint16, ml_dtypes.bfloat16)}


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """npz can't store ml_dtypes; view them as same-width uints."""
    if arr.dtype.name in _EXOTIC:
        return arr.view(_EXOTIC[arr.dtype.name][0])
    return arr


def _from_savable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return arr.view(_EXOTIC[dtype_name][1])
    return arr


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, metadata: Optional[dict] = None,
         keep: int = 3) -> str:
    """Atomic save of a pytree; prunes to the newest ``keep`` checkpoints."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten_with_paths(tree)
    arrays = {f"a{i}": _to_savable(np.asarray(x)) for i, x in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "shapes": [list(np.asarray(x).shape) for x in leaves],
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (shape/dtype validated).

    ``shardings``: optional matching pytree of Sharding — enables elastic
    re-shard onto a different mesh.  Returns (tree, metadata).
    """
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves = [_from_savable(data[f"a{i}"], manifest["dtypes"][i])
              for i in range(len(manifest["paths"]))]
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    assert len(flat_like) == len(leaves), (len(flat_like), len(leaves))
    out = []
    flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(leaves))
    for ref, arr, sh in zip(flat_like, leaves, flat_sh):
        arr = arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr
        assert tuple(arr.shape) == tuple(ref.shape), (arr.shape, ref.shape)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["metadata"]


class AsyncWriter:
    """Write-behind checkpointing: snapshot on the caller thread (host copy),
    serialize + fsync on a worker thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, metadata, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
