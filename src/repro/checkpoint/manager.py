"""Checkpointing: atomic, keep-k, restart- and reshard-safe.

Format: one directory per step containing ``arrays.npz`` (flattened leaves)
and ``manifest.json`` (step, tree structure, shapes/dtypes, payload sha256,
user metadata).  Writes go to ``<dir>.tmp`` then ``os.rename`` — a crash
mid-write never corrupts the latest checkpoint (the fault-tolerance
contract the train loop and the OOC round journal rely on; the
``"checkpoint-write"`` fault-injection site sits between the payload write
and the rename so tests can tear the write deterministically,
DESIGN.md §12).  ``AsyncWriter`` moves serialization off the step path
(write-behind thread), bounding checkpoint stalls to an array copy.

Integrity: the manifest records the sha256 of ``arrays.npz`` as written, so
a snapshot whose payload was truncated or bit-rotted *after* the atomic
rename (torn disk write, partial copy) is detected at restore time —
``restore(step=None)`` then falls back to the next-newest valid snapshot
instead of crashing, raising :class:`CheckpointCorruptionError` only when
no snapshot survives.  Structural mismatches against the caller's ``like``
tree (leaf count, shapes) raise :class:`CheckpointStructureError` — those
are caller bugs, not disk corruption, so no fallback is attempted (and
unlike the bare ``assert``s they replace, they survive ``python -O``).

Elastic re-shard: checkpoints store full (unsharded) arrays; ``restore``
optionally takes ``shardings`` and ``jax.device_put``s each leaf — loading a
256-chip checkpoint onto a 512-chip mesh (or onto 1 CPU) is the same call.
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import shutil
import threading
import warnings
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

from repro.core import faults

_EXOTIC = {"bfloat16": (np.uint16, ml_dtypes.bfloat16)}


class CheckpointError(RuntimeError):
    """Base class for checkpoint restore failures."""


class CheckpointCorruptionError(CheckpointError):
    """A snapshot's payload is unreadable or fails its manifest checksum."""


class CheckpointStructureError(CheckpointError):
    """A snapshot does not match the structure of the caller's ``like``
    tree (leaf count or leaf shape) — a caller/config bug, not corruption."""


def _to_savable(arr: np.ndarray) -> np.ndarray:
    """npz can't store ml_dtypes; view them as same-width uints."""
    if arr.dtype.name in _EXOTIC:
        return arr.view(_EXOTIC[arr.dtype.name][0])
    return arr


def _from_savable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return arr.view(_EXOTIC[dtype_name][1])
    return arr


def _path_part(k) -> str:
    # plain names ("sup", "opt/mu/0") instead of jax's "['sup']" reprs, so
    # a like=None restore yields a tree keyed by the names save() was given
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(_path_part(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def atomic_file_write(path: str, payload: bytes) -> None:
    """Single-file half of the checkpoint atomicity contract: write to
    ``<path>.tmp`` then ``os.replace``.

    A crash (or SIGKILL) at any point leaves either the previous intact
    file or a stale ``.tmp`` — never a torn ``path``.  The graph store's
    chunk spills ride this exact primitive so chunk I/O and checkpoint I/O
    share one durability story (DESIGN.md §15); :func:`save` applies the
    same tmp+rename discipline at directory granularity.
    """
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(payload)
    os.replace(tmp, path)


def save(ckpt_dir: str, step: int, tree: Any, metadata: Optional[dict] = None,
         keep: int = 3) -> str:
    """Atomic save of a pytree; prunes to the newest ``keep`` checkpoints.

    The payload is serialized in memory first so the manifest can record
    its sha256 — the checksum covers exactly the bytes handed to the OS,
    letting ``restore`` distinguish "renamed but torn on disk" from a good
    snapshot.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    paths, leaves, _ = _flatten_with_paths(tree)
    arrays = {f"a{i}": _to_savable(np.asarray(x)) for i, x in enumerate(leaves)}
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    payload = buf.getvalue()
    npz_path = os.path.join(tmp, "arrays.npz")
    with open(npz_path, "wb") as f:
        f.write(payload)
    # deterministic torn-write / crash injection between payload and commit
    faults.check(faults.CHECKPOINT_WRITE, step=step, path=npz_path,
                 dir=ckpt_dir)
    manifest = {
        "step": step,
        "paths": paths,
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "shapes": [list(np.asarray(x).shape) for x in leaves],
        "arrays_sha256": hashlib.sha256(payload).hexdigest(),
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def _load_step(ckpt_dir: str, step: int) -> tuple[list, dict]:
    """Read + integrity-check one snapshot; returns (leaves, manifest).

    Raises :class:`CheckpointCorruptionError` on any unreadable file or a
    payload whose sha256 disagrees with the manifest.  Snapshots written
    before checksums existed (no ``arrays_sha256`` key) load unchecked.
    """
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise CheckpointCorruptionError(
            f"checkpoint step {step} under {ckpt_dir}: unreadable manifest "
            f"({e})") from e
    npz_path = os.path.join(d, "arrays.npz")
    try:
        with open(npz_path, "rb") as f:
            payload = f.read()
    except OSError as e:
        raise CheckpointCorruptionError(
            f"checkpoint step {step} under {ckpt_dir}: unreadable payload "
            f"({e})") from e
    want = manifest.get("arrays_sha256")
    if want is not None:
        got = hashlib.sha256(payload).hexdigest()
        if got != want:
            raise CheckpointCorruptionError(
                f"checkpoint step {step} under {ckpt_dir}: arrays.npz sha256 "
                f"mismatch (manifest {want[:12]}…, on disk {got[:12]}… — "
                f"truncated or torn write)")
    try:
        data = np.load(io.BytesIO(payload))
        leaves = [_from_savable(data[f"a{i}"], manifest["dtypes"][i])
                  for i in range(len(manifest["paths"]))]
    except Exception as e:
        raise CheckpointCorruptionError(
            f"checkpoint step {step} under {ckpt_dir}: undecodable payload "
            f"({e})") from e
    return leaves, manifest


def restore(ckpt_dir: str, like: Any = None, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore a snapshot; returns ``(tree, metadata)``.

    With ``like`` given, leaves are validated against its structure
    (:class:`CheckpointStructureError` on leaf-count or shape mismatch) and
    cast to its leaf dtypes.  With ``like=None`` the snapshot is returned
    as a flat ``{path: array}`` dict straight from the manifest — the form
    the OOC round journal uses, where the caller inspects the metadata
    before deciding what the arrays mean.

    With ``step=None`` (latest), a snapshot that fails its integrity check
    falls back to the next-newest one (each skip warns), so a torn write of
    the newest snapshot costs one checkpoint interval of progress instead
    of the whole run; an explicit ``step`` never falls back.

    ``shardings``: optional matching pytree of Sharding — enables elastic
    re-shard onto a different mesh.
    """
    if step is not None:
        candidates = [step]
    else:
        candidates = sorted(all_steps(ckpt_dir), reverse=True)
        if not candidates:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    last_err: Optional[CheckpointCorruptionError] = None
    leaves = manifest = None
    for s in candidates:
        try:
            leaves, manifest = _load_step(ckpt_dir, s)
            break
        except CheckpointCorruptionError as e:
            last_err = e
            if step is not None:
                raise
            warnings.warn(f"skipping corrupt checkpoint: {e}", stacklevel=2)
    if manifest is None:
        raise CheckpointCorruptionError(
            f"no intact checkpoint under {ckpt_dir} "
            f"({len(candidates)} candidate(s) failed)") from last_err
    if like is None:
        tree = dict(zip(manifest["paths"], leaves))
        return tree, manifest["metadata"]
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    if len(flat_like) != len(leaves):
        raise CheckpointStructureError(
            f"checkpoint step {manifest['step']} holds {len(leaves)} leaves "
            f"but the restore target has {len(flat_like)} — wrong tree "
            f"structure for this checkpoint")
    out = []
    flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(leaves))
    for i, (ref, arr, sh) in enumerate(zip(flat_like, leaves, flat_sh)):
        arr = arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr
        if tuple(arr.shape) != tuple(ref.shape):
            raise CheckpointStructureError(
                f"checkpoint step {manifest['step']} leaf "
                f"{manifest['paths'][i]!r} has shape {tuple(arr.shape)} but "
                f"the restore target expects {tuple(ref.shape)}")
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["metadata"]


class AsyncWriter:
    """Write-behind checkpointing: snapshot on the caller thread (host copy),
    serialize + fsync on a worker thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, metadata, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
